"""MCU deployment walk-through — the paper's headline experiment.

Act 1: SwiftNet-Cell-like CNN on a simulated NUCLEO-F767ZI (512 KB SRAM,
≈200 KB framework overhead).  The deployment is int8, as on the real
device: the float model is post-training-quantized, then with the default
operator order it does NOT fit the remaining budget; after reordering it
does.  Numerics are verified bit-identical across schedules, and the
defragmenting dynamic allocator's overhead is reported.  For contrast,
the f32 build's 4x working sets are printed too.

Act 2: the 256 KB stretch deployment — MobileNet-1.0@192 int8 on a
256 KB-SRAM part.  Reordering alone needs 864 KB and whole-externals
partial execution floors at ~315 KB; `schedule(arena_budget=256 KB)`
escalates to cascaded Pex streaming (ring-buffer inter-segment execution,
DESIGN.md §7) and lands a 243 KB arena at ~15% extra MACs.  Planned on
the byte-exact scheduling graph here to keep the demo fast;
tests/test_cascade.py pins the executable bit-identity of the same
deployment through the compiled byte-arena executor.

    PYTHONPATH=src python examples/mcu_deploy.py
"""
import numpy as np

import repro.deploy as deploy
from repro.core import ArenaPlanner, schedule, static_plan_size
from repro.graphs import (int8_scheduling_graph, mobilenet_v1_graph,
                          quantize_graph, random_input, swiftnet_cell_graph)
from repro.graphs.cnn_ops import model_weight_bytes
from repro.mcu import MicroInterpreter

SRAM = 512 * 1024
OVERHEAD = 200 * 1024
SRAM_SMALL = 256 * 1024


def main():
    f = swiftnet_cell_graph()
    qm = quantize_graph(f, random_input(f))
    g = qm.graph
    print(f"model: {len(g.operators)} operators, "
          f"{model_weight_bytes(g) / 1024:.0f} KB int8 parameters "
          f"(NOR-flash; f32 would be "
          f"{model_weight_bytes(f) / 1024:.0f} KB)")

    default = g.default_schedule()
    best = schedule(g)
    d_peak = g.peak_usage(default)
    print(f"\npeak SRAM, default order : {d_peak / 1024:7.1f} KB (int8)")
    print(f"peak SRAM, optimal order : {best.peak / 1024:7.1f} KB "
          f"({best.method})")
    print(f"saving                   : {(d_peak - best.peak) / 1024:7.1f} KB")
    print(f"f32 default order        : "
          f"{f.peak_usage(f.default_schedule()) / 1024:7.1f} KB (4x)")
    budget = SRAM - OVERHEAD
    print(f"\nSRAM budget (512 KB - 200 KB overhead): {budget / 1024:.0f} KB")
    print(f"  default order fits: {d_peak <= budget}")
    print(f"  optimal order fits: {best.peak <= budget}")

    x = qm.quantize_inputs(random_input(f))
    interp = MicroInterpreter(g, capacity=budget)
    rep = interp.run(x, schedule=best.schedule)
    print("\nmicro-interpreter run (optimal order):")
    print(f"  peak arena     : {rep.peak_sram / 1024:.1f} KB")
    print(f"  defrag traffic : {rep.bytes_moved / 1024:.0f} KB over "
          f"{rep.defrag_passes} passes")

    out_opt = rep.outputs
    rep_d = MicroInterpreter(g).run(x, schedule=default)
    same = all(np.array_equal(out_opt[o], rep_d.outputs[o])
               for o in g.outputs)
    print(f"  outputs identical across schedules: {same}")

    # the deploy facade runs the same schedule -> plan -> validate ->
    # compile chain in one call and hands back a runnable Deployment
    dep = deploy.build(g)
    out_c = dep.run(x)
    same = all(np.array_equal(out_opt[o], out_c[o]) for o in g.outputs)
    print(f"\noffline arena plan (paper §6): "
          f"{dep.arena_bytes / 1024:.1f} KB"
          f"  (static all-resident: {static_plan_size(g) / 1024:.0f} KB)")
    print(f"  repro.deploy.build(g).run(x) bit-identical: {same}")
    print(f"  deployment stats: {dep.stats.as_json()}")

    # ---- Act 2: 256 KB part via cascaded Pex streaming -----------------
    print("\n=== MobileNet-1.0@192 int8 on a 256 KB-SRAM part ===")
    q = int8_scheduling_graph(mobilenet_v1_graph(alpha=1.0, resolution=192))
    base = schedule(q)
    print(f"best reordering alone     : {base.peak / 1024:7.1f} KB "
          f"(does not fit)")
    res = schedule(q, arena_budget=SRAM_SMALL)
    gq = res.graph if res.graph is not None else q
    plan = ArenaPlanner.plan(gq, res.schedule)
    ArenaPlanner.validate(plan, gq)
    print(f"{res.method:26s}: {res.peak / 1024:7.1f} KB "
          f"(arena plan {plan.arena_size / 1024:.1f} KB)")
    print(f"  fits 256 KB: {plan.arena_size <= SRAM_SMALL}   "
          f"halo-recompute overhead = {res.extra_macs_frac:.1%} extra MACs"
          f" (whole-graph)")
    print("  (ring-buffer streaming of the high-resolution front: no "
          "inter-segment\n   tensor ever exists whole — DESIGN.md §7; "
          "executable bit-identity is\n   pinned in tests/test_cascade.py)")


if __name__ == "__main__":
    main()
