"""MCU deployment walk-through — the paper's headline experiment.

SwiftNet-Cell-like CNN on a simulated NUCLEO-F767ZI (512 KB SRAM, ≈200 KB
framework overhead).  The deployment is int8, as on the real device: the
float model is post-training-quantized, then with the default operator
order it does NOT fit the remaining budget; after reordering it does.
Numerics are verified bit-identical across schedules, and the
defragmenting dynamic allocator's overhead is reported.  For contrast, the
f32 build's 4x working sets are printed too.

    PYTHONPATH=src python examples/mcu_deploy.py
"""
import numpy as np

from repro.core import ArenaPlanner, schedule, static_plan_size
from repro.graphs import quantize_graph, random_input, swiftnet_cell_graph
from repro.graphs.cnn_ops import model_weight_bytes
from repro.mcu import MicroInterpreter

SRAM = 512 * 1024
OVERHEAD = 200 * 1024


def main():
    f = swiftnet_cell_graph()
    qm = quantize_graph(f, random_input(f))
    g = qm.graph
    print(f"model: {len(g.operators)} operators, "
          f"{model_weight_bytes(g) / 1024:.0f} KB int8 parameters "
          f"(NOR-flash; f32 would be "
          f"{model_weight_bytes(f) / 1024:.0f} KB)")

    default = g.default_schedule()
    best = schedule(g)
    d_peak = g.peak_usage(default)
    print(f"\npeak SRAM, default order : {d_peak / 1024:7.1f} KB (int8)")
    print(f"peak SRAM, optimal order : {best.peak / 1024:7.1f} KB "
          f"({best.method})")
    print(f"saving                   : {(d_peak - best.peak) / 1024:7.1f} KB")
    print(f"f32 default order        : "
          f"{f.peak_usage(f.default_schedule()) / 1024:7.1f} KB (4x)")
    budget = SRAM - OVERHEAD
    print(f"\nSRAM budget (512 KB - 200 KB overhead): {budget / 1024:.0f} KB")
    print(f"  default order fits: {d_peak <= budget}")
    print(f"  optimal order fits: {best.peak <= budget}")

    x = qm.quantize_inputs(random_input(f))
    interp = MicroInterpreter(g, capacity=budget)
    rep = interp.run(x, schedule=best.schedule)
    print("\nmicro-interpreter run (optimal order):")
    print(f"  peak arena     : {rep.peak_sram / 1024:.1f} KB")
    print(f"  defrag traffic : {rep.bytes_moved / 1024:.0f} KB over "
          f"{rep.defrag_passes} passes")

    out_opt = rep.outputs
    rep_d = MicroInterpreter(g).run(x, schedule=default)
    same = all(np.array_equal(out_opt[o], rep_d.outputs[o])
               for o in g.outputs)
    print(f"  outputs identical across schedules: {same}")

    plan = ArenaPlanner.plan(g, best.schedule)
    ArenaPlanner.validate(plan, g)
    print(f"\noffline arena plan (paper §6): {plan.arena_size / 1024:.1f} KB"
          f"  (static all-resident: {static_plan_size(g) / 1024:.0f} KB)")


if __name__ == "__main__":
    main()
