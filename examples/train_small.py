"""Train a small LM for a few hundred steps on the synthetic Markov data
pipeline — loss drops well below the unigram entropy, demonstrating the full
training substrate (AdamW + cosine schedule + remat + checkpointing).

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.models.model import Model
from repro.training import make_train_step, train_state_init
from repro.training.checkpoint import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3.2-3b@smoke")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch).replace(num_layers=4, d_model=256)
    model = Model(cfg)
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"arch={cfg.name}  params={n_params / 1e6:.1f}M")

    ds = SyntheticLMDataset(cfg, args.batch, args.seq, seed=0)
    step_fn = jax.jit(make_train_step(model, peak_lr=3e-3, warmup=20,
                                      total_steps=args.steps, remat=True))
    t0 = time.perf_counter()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        state, metrics = step_fn(state, batch)
        if i % 20 == 0 or i == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"step {i:4d}  loss={float(metrics['loss']):.3f}  "
                  f"lr={float(metrics['lr']):.2e}  "
                  f"gnorm={float(metrics['grad_norm']):.2f}  "
                  f"({dt:.1f}s)")
    f = save_checkpoint(args.ckpt_dir, state.params, step=args.steps)
    print(f"checkpoint: {f}")


if __name__ == "__main__":
    main()
