"""Quickstart: the paper's technique in 30 lines.

Builds the paper's Figure-1 computation graph, finds the memory-optimal
operator schedule with Algorithm 1, and prints the Appendix-A working-set
tables — then does the same to a real JAX function via jaxpr reordering.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import minimise_peak_memory, profile
from repro.core.jaxpr_reorder import reorder
from repro.graphs.figure1 import figure1_graph


def main():
    # ---- 1. the paper's Figure-1 graph --------------------------------
    g = figure1_graph()
    default = g.default_schedule()
    optimal = minimise_peak_memory(g)
    print("=== default operator order (paper Figure 2) ===")
    print(profile.usage_table(g, default))
    print("\n=== optimal operator order (paper Figure 3) ===")
    print(profile.usage_table(g, optimal.schedule))
    print()
    print(profile.compare(g, default, optimal.schedule))

    # ---- 2. the same idea on a JAX program ----------------------------
    def branchy(x):
        t = jnp.tanh(x)                # tensor with two consumers
        heavy = jnp.tanh(t @ t.T).sum(axis=1)   # fat branch
        light = t.sum(axis=1)                   # thin branch
        return heavy + light

    reports = []
    y = reorder(branchy, report_to=reports)(jnp.ones((512, 512)))
    print("\n=== jaxpr operator reordering ===")
    print(reports[0])
    print("output checksum:", float(y.sum()))


if __name__ == "__main__":
    main()
