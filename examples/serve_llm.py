"""End-to-end serving driver (the paper's kind is inference): batched
requests through prefill + greedy decode on a small llama-family model, with
the paper's memory machinery active at both levels — KV-block arena
accounting and decode-step operator reordering.

    PYTHONPATH=src python examples/serve_llm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import init_params
from repro.serving import Request, ServingEngine


def main():
    cfg = get_config("llama3.2-3b@smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=4, cache_len=96)

    rng = np.random.default_rng(0)
    requests = [Request(rid=i,
                        prompt=rng.integers(0, 500, rng.integers(8, 32))
                        .astype(np.int32),
                        max_new_tokens=16)
                for i in range(10)]

    t0 = time.perf_counter()
    results = engine.serve(requests)
    dt = time.perf_counter() - t0
    total_toks = sum(len(r.tokens) for r in results)
    print(f"served {len(results)} requests / {total_toks} tokens "
          f"in {dt:.2f}s ({total_toks / dt:.1f} tok/s on CPU)")
    for r in results[:3]:
        print(f"  req {r.rid}: {r.tokens}")

    print("\nKV arena (paper §4 dynamic allocator):")
    print(f"  per-request block : {engine.block_bytes / 1e6:.2f} MB")
    print(f"  peak arena        : "
          f"{engine.stats['arena_peak_bytes'] / 1e6:.2f} MB "
          f"({engine.stats['peak_concurrent']} concurrent)")
    print(f"  static (all 10)   : {engine.stats['static_bytes'] / 1e6:.2f} MB")

    rep = engine.analyse_decode_schedule(batch_size=4)
    print("\ndecode-step jaxpr reordering (paper Algorithm 1 on XLA):")
    print(f"  {rep}")


if __name__ == "__main__":
    main()
