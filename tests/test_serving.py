"""Serving engine: end-to-end batched requests, L1 jaxpr reordering of the
decode step, L2 KV-arena accounting."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import init_params
from repro.serving import Request, ServingEngine

pytestmark = pytest.mark.slow   # integration tier; see pytest.ini


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("llama3.2-3b@smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return ServingEngine(cfg, params, max_batch=2, cache_len=48)


def _reqs(n, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, 500, rng.integers(4, 12))
                    .astype(np.int32), max_new_tokens=6) for i in range(n)]


def test_serve_batches_and_completes(engine):
    res = engine.serve(_reqs(5))
    assert len(res) == 5
    for r in res:
        assert len(r.tokens) == 6
        assert all(0 <= t < engine.cfg.vocab_size for t in r.tokens)
    # L2 stats (typed EngineStats): peak arena is bounded by max_batch
    # blocks, static by all 5; legacy dict-style keys stay readable
    assert engine.stats.kv_arena_peak_bytes == 2 * engine.block_bytes
    assert engine.stats.kv_static_bytes == 5 * engine.block_bytes
    assert engine.stats["arena_peak_bytes"] == engine.stats.kv_arena_peak_bytes
    assert engine.stats.requests == 5
    assert engine.stats.as_json()["requests_per_s"] > 0


def test_decode_step_reorder_analysis(engine):
    rep = engine.analyse_decode_schedule(batch_size=2)
    assert rep.n_eqns > 10
    assert rep.peak_after <= rep.peak_before


def test_serving_deterministic(engine):
    a = engine.serve(_reqs(2, seed=1))
    b = engine.serve(_reqs(2, seed=1))
    assert [r.tokens for r in a] == [r.tokens for r in b]
