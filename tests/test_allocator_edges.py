"""Allocator edge cases that the hypothesis property suite does not reach in
environments without hypothesis: alignment > 1, zero-size tensors, the
inplace alias machinery, and the plan-driven micro-interpreter cross-check.
"""
import random

import numpy as np
import pytest

from repro.core import (ArenaPlanner, DynamicAllocator, Graph,
                        inplace_alias_groups, schedule, tensor_lifetimes)
from repro.graphs import mobilenet_v1_graph
from repro.mcu import MicroInterpreter


def random_dag(seed: int, n_ops: int = 12) -> Graph:
    """Random layered DAG with assorted tensor sizes (incl. zero)."""
    rng = random.Random(seed)
    g = Graph()
    g.add_tensor("in0", rng.choice([64, 100, 128]))
    produced = ["in0"]
    for k in range(n_ops):
        n_in = min(len(produced), rng.randint(1, 2))
        ins = rng.sample(produced, n_in)
        out = f"t{k}"
        size = rng.choice([0, 8, 24, 64, 100, 256])
        g.add_tensor(out, size)
        g.add_operator(f"op{k}", ins, out)
        produced.append(out)
    g.set_outputs([produced[-1]])
    return g


# ------------------------------------------------------------------ alignment
def test_arena_plan_alignment_and_no_overlap():
    for seed in range(6):
        g = random_dag(seed)
        sched = g.default_schedule()
        for alignment in (4, 8, 64):
            plan = ArenaPlanner.plan(g, sched, alignment=alignment)
            ArenaPlanner.validate(plan)
            for p in plan.placements:
                if p.size > 0:
                    assert p.offset % alignment == 0, (seed, alignment, p)
            # aligning can only grow the arena
            assert plan.arena_size >= ArenaPlanner.plan(g, sched).arena_size


def test_arena_plan_alignment_on_real_model():
    g = mobilenet_v1_graph()
    sched = schedule(g).schedule
    plan = ArenaPlanner.plan(g, sched, alignment=16)
    ArenaPlanner.validate(plan)
    assert all(p.offset % 16 == 0 for p in plan.placements if p.size > 0)


# ------------------------------------------------- byte-granular + alignment
def test_odd_int8_sizes_force_alignment_padding():
    """Three co-live odd-sized int8 tensors under a 4-byte policy: every
    offset aligned, and the arena pays exactly the padding the odd sizes
    force (vs the packed byte-granular plan)."""
    g = Graph()
    g.add_tensor("a", 7)
    g.add_tensor("b", 13)
    g.add_tensor("c", 9)
    g.add_operator("op", ["a", "b"], "c")
    g.set_outputs(["c"])
    sched = g.default_schedule()
    packed = ArenaPlanner.plan(g, sched)          # pure int8: auto align 1
    assert packed.arena_size == 7 + 13 + 9
    plan = ArenaPlanner.plan(g, sched, alignment=4)
    ArenaPlanner.validate(plan, g)
    assert all(p.offset % 4 == 0 for p in plan.placements)
    # the multi-order greedy beats the pure by-size order (b@0 pad 16,
    # c@16 pad 28, a@28 -> 35): its by-birth pass yields b@0 (13 -> pad
    # 16), a@16 (23 -> pad 24), c@24 — 9 bytes end at 33, one padding
    # word instead of two
    assert plan.arena_size == 33 > packed.arena_size


def test_dynamic_allocator_respects_alignment():
    a = DynamicAllocator(alignment=8)
    a.alloc("x", 5)
    a.alloc("y", 3)             # first-fit cursor rounds 5 -> 8
    assert a.addresses == {"x": 0, "y": 8}
    a.free("x")
    a.alloc("z", 13)            # 13 > gap [0, 8): placed past y
    assert a.addresses["z"] == 16
    a.defragment()              # compaction keeps offsets aligned
    assert all(off % 8 == 0 for off in a.addresses.values())
    assert a.addresses["y"] == 0 and a.addresses["z"] == 8


def test_mixed_dtype_inplace_chain_aliases_one_buffer():
    """An f32 inplace accumulator chain surrounded by odd-sized int8
    tensors: the chain still folds to one placement, and the auto-aligned
    plan keeps every f32 offset 4-aligned despite the odd int8 sizes."""
    g = Graph()
    g.add_tensor("x", 65)                         # odd int8 input
    for k in range(3):
        g.add_tensor(f"acc{k}", 128, (32,), dtype="float32")
    g.add_tensor("p0", 63)
    g.add_tensor("p1", 63)
    g.add_operator("s0", ["x"], "p0")
    g.add_operator("s1", ["x"], "p1")
    g.add_operator("c0", ["p0"], "acc0")
    g.add_operator("c1", ["acc0", "p1"], "acc1", inplace=True)
    g.add_operator("c2", ["acc1"], "acc2", inplace=True)
    g.set_outputs(["acc2"])
    sched = g.default_schedule()
    assert g.max_itemsize() == 4                  # auto alignment is 4
    plan = ArenaPlanner.plan(g, sched)
    ArenaPlanner.validate(plan, g)
    offs = {plan.offset_of(f"acc{k}") for k in range(3)}
    assert len(offs) == 1                         # one shared buffer
    assert all(plan.offset_of(f"acc{k}") % 4 == 0 for k in range(3))


# ---------------------------------------------------------------- zero sizes
def test_zero_size_tensors_plan_and_dynamic_alloc():
    g = Graph()
    g.add_tensor("x", 32)
    g.add_tensor("z", 0)            # zero-size intermediate
    g.add_tensor("y", 16)
    g.add_operator("a", ["x"], "z")
    g.add_operator("b", ["z", "x"], "y")
    g.set_outputs(["y"])
    sched = g.default_schedule()
    plan = ArenaPlanner.plan(g, sched)
    ArenaPlanner.validate(plan)
    assert plan.offset_of("z") == 0 and plan.arena_size >= 48
    lt = dict((n, (s, e)) for n, s, e in tensor_lifetimes(g, sched))
    assert "z" in lt
    a = DynamicAllocator()
    a.alloc("z", 0)
    a.alloc("x", 32)
    assert a.live_bytes() == 32
    a.free("z")
    assert "z" not in a.addresses


def test_dynamic_allocator_rename():
    a = DynamicAllocator(capacity=64)
    a.alloc("x", 32)
    off = a.rename("x", "y")
    assert off == 0 and a.addresses == {"y": 0}
    with pytest.raises(KeyError):
        a.rename("x", "z")
    a.alloc("x", 16)
    with pytest.raises(ValueError):
        a.rename("x", "y")          # target name still allocated


# -------------------------------------------------------------- alias groups
def _inplace_chain_graph():
    g = Graph()
    g.add_tensor("x", 64)
    for k in range(3):
        g.add_tensor(f"acc{k}", 128)
    g.add_tensor("p0", 64)
    g.add_tensor("p1", 64)
    g.add_operator("s0", ["x"], "p0")
    g.add_operator("s1", ["x"], "p1")
    g.add_operator("c0", ["p0"], "acc0")
    g.add_operator("c1", ["acc0", "p1"], "acc1", inplace=True)
    g.add_operator("c2", ["acc1"], "acc2", inplace=True)
    g.set_outputs(["acc2"])
    return g


def test_inplace_chain_shares_one_buffer():
    g = _inplace_chain_graph()
    sched = g.default_schedule()
    groups = inplace_alias_groups(g, sched)
    rep = groups["acc2"]
    assert groups["acc1"] == rep and groups["acc0"] == rep
    plan = ArenaPlanner.plan(g, sched)
    ArenaPlanner.validate(plan)
    offs = {plan.offset_of(f"acc{k}") for k in range(3)}
    assert len(offs) == 1
    # one 128B buffer, not three: the arena stays small
    assert plan.arena_size <= 64 + 64 + 128
    # without the inplace attr the chain must NOT alias
    g2 = _inplace_chain_graph()
    for op in g2.operators:
        op.attrs.pop("inplace", None)
    assert inplace_alias_groups(g2, g2.default_schedule()) == {}


# ----------------------------------------------- plan-driven interpreter run
def test_interpreter_plan_mode_cross_checks_arena_size():
    g = mobilenet_v1_graph(resolution=64)
    res = schedule(g)
    plan = ArenaPlanner.plan(g, res.schedule)
    ArenaPlanner.validate(plan)
    rng = np.random.default_rng(0)
    h, w, c = g.tensors["input"].shape
    x = {"input": rng.standard_normal((h, w, c)).astype(np.float32)}
    dyn = MicroInterpreter(g).run(x, schedule=res.schedule)
    pl = MicroInterpreter(g).run(x, schedule=res.schedule, plan=plan)
    # the planned execution's high water is exactly the planned arena, and
    # both executions agree on the numbers
    assert pl.peak_sram == plan.arena_size
    assert pl.bytes_moved == 0 and pl.defrag_passes == 0
    for o in g.outputs:
        np.testing.assert_array_equal(dyn.outputs[o], pl.outputs[o])
    # neither model may undercut the liveness lower bound
    live_peak = g.peak_usage(res.schedule)
    assert dyn.peak_sram >= live_peak and pl.peak_sram >= live_peak


def test_interpreter_plan_mode_enforces_capacity():
    g = mobilenet_v1_graph()
    sched = schedule(g).schedule
    plan = ArenaPlanner.plan(g, sched)
    rng = np.random.default_rng(0)
    h, w, c = g.tensors["input"].shape
    x = {"input": rng.standard_normal((h, w, c)).astype(np.float32)}
    interp = MicroInterpreter(g, capacity=plan.arena_size - 1)
    with pytest.raises(MemoryError):
        interp.run(x, schedule=sched, plan=plan)
