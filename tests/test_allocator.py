"""DynamicAllocator + ArenaPlanner invariants (unit + property tests)."""
import random

import pytest
from hypothesis_compat import given, settings, st   # skips @given tests cleanly when hypothesis is absent

from repro.core import (ArenaPlanner, DynamicAllocator, schedule,
                        static_plan_size, tensor_lifetimes)
from repro.graphs import (figure1_graph, mobilenet_v1_graph,
                          swiftnet_cell_graph)


def test_dynamic_allocator_basic():
    a = DynamicAllocator(capacity=100)
    assert a.alloc("x", 40) == 0
    assert a.alloc("y", 40) == 40
    a.free("x")
    # first fit reuses the hole
    assert a.alloc("z", 30) == 0
    assert a.high_water() == 80
    a.defragment()
    assert a.addresses["z"] == 0 and a.addresses["y"] == 30
    assert a.high_water() == 70


def test_dynamic_allocator_overflow():
    a = DynamicAllocator(capacity=64)
    a.alloc("x", 32)
    a.alloc("y", 32)
    with pytest.raises(MemoryError):
        a.alloc("z", 1)


def test_defrag_compacts_to_front():
    a = DynamicAllocator()
    for i in range(8):
        a.alloc(f"t{i}", 16)
    for i in range(0, 8, 2):
        a.free(f"t{i}")
    moved = a.defragment()
    assert moved == 4 * 16  # t1,t3,t5,t7 all shift down
    assert a.high_water() == 4 * 16


@given(st.integers(0, 100_000))
@settings(max_examples=80, deadline=None)
def test_allocator_blocks_never_overlap(seed):
    rng = random.Random(seed)
    a = DynamicAllocator()
    live = []
    for k in range(60):
        if live and rng.random() < 0.4:
            t = live.pop(rng.randrange(len(live)))
            a.free(t)
        else:
            name = f"t{k}"
            a.alloc(name, rng.randint(1, 256))
            live.append(name)
        if rng.random() < 0.3:
            a.defragment()
        blocks = sorted(a.blocks, key=lambda b: b.offset)
        for x, y in zip(blocks, blocks[1:]):
            assert x.offset + x.size <= y.offset
    # defrag leaves no gaps
    a.defragment()
    assert a.high_water() == a.live_bytes()


@pytest.mark.parametrize("graph_fn", [figure1_graph, swiftnet_cell_graph,
                                      mobilenet_v1_graph])
def test_arena_plan_valid_and_tight(graph_fn):
    g = graph_fn()
    res = schedule(g)
    plan = ArenaPlanner.plan(g, res.schedule)
    ArenaPlanner.validate(plan)
    # arena can never beat the schedule's working-set peak ...
    assert plan.arena_size >= res.peak
    # ... and best-fit should stay within 1.25x of it on these graphs
    assert plan.arena_size <= int(res.peak * 1.25)
    # and always beats the static everything-resident plan (+ inputs)
    const_bytes = sum(g.size(c) for c in g.constants())
    assert plan.arena_size <= static_plan_size(g) + const_bytes


def test_lifetimes_cover_all_activations():
    g = figure1_graph()
    sched = g.default_schedule()
    lt = dict((n, (s, e)) for n, s, e in tensor_lifetimes(g, sched))
    assert lt["t0"] == (-1, 0)
    assert lt["t1"] == (0, 3)   # produced by op1(step0), last used by op4
    assert lt["t7"] == (6, 6)   # output pinned to the end
