"""The correctness harness that earns trust in ``core/solver.py``: the
solver is pinned to brute-force enumeration (``tests/oracle.py``) on every
graph small enough to enumerate.

Layered chain of trust:

1. the two oracles agree with each other and with ``Graph.peak_usage`` /
   the paper's exact DP (oracle self-test, including ``inplace`` aliasing);
2. the solver's order search returns the enumeration optimum on random
   DAGs (fixed seeds always; hypothesis on CI);
3. the *joint* solve returns the optimum over all (order × Pex split)
   combinations of small sliceable graphs, and its Pareto front equals the
   oracle's independently-computed non-dominated set.

Every suite runs on fixed seeds without hypothesis (this container has
none); with hypothesis installed the same properties explore fresh
examples (``hypothesis_compat`` pattern).
"""
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from oracle import (dp_min_peak, enumerate_min_peak, oracle_front,
                    oracle_joint_points, random_dag, random_sliceable_chain,
                    random_tiled_chain, sliceable_chain_graph,
                    tiled_chain_graph, tiled_triple_points, topo_orders)

from repro.core import minimise_peak_memory, schedule, solve
from repro.core.solver import _Budget, _Sim, branch_and_bound_order
from repro.graphs.figure1 import OPTIMAL_PEAK, figure1_graph

# K cap for the joint suites: the oracle enumerates every split's rewrite,
# so K (hence rewritten op count) must stay small enough to enumerate.
ORACLE_MAX_K = 3


# ----------------------------------------------------------- oracle self-test
def test_oracles_agree_on_figure1():
    g = figure1_graph()
    peak, count = enumerate_min_peak(g)
    assert peak == OPTIMAL_PEAK == dp_min_peak(g)
    assert count > 1     # figure1 genuinely has reordering freedom


def test_oracles_agree_with_exact_dp_on_random_dags():
    for seed in range(40):
        g = random_dag(seed)
        peak, _ = enumerate_min_peak(g)
        assert peak == dp_min_peak(g)
        assert peak == minimise_peak_memory(g).peak


def test_oracles_agree_on_inplace_dags():
    """The aliasing rule (inplace ops overwrite a dying same-size input)
    must mean the same thing to the enumerator's ground truth
    (``Graph.peak_usage``) and the DP's re-derived step cost."""
    hit_alias = 0
    for seed in range(30):
        g = random_dag(seed, inplace_every=2)
        peak, _ = enumerate_min_peak(g)
        assert peak == dp_min_peak(g)
        if any(op.attrs.get("inplace") for op in g.operators):
            hit_alias += 1
    assert hit_alias > 10    # the variant actually exercises aliasing


def test_topo_orders_are_valid_and_unique():
    g = figure1_graph()
    seen = set()
    for sched in topo_orders(g):
        assert g.is_valid_schedule(sched)
        key = tuple(op.name for op in sched)
        assert key not in seen
        seen.add(key)


# ------------------------------------------------- solver == order optimum
def _assert_solver_matches_order_oracle(g):
    peak, _ = enumerate_min_peak(g)
    res, complete = branch_and_bound_order(g, _Budget(200_000))
    assert complete
    assert g.is_valid_schedule(res.schedule)
    assert res.peak == peak == g.peak_usage(res.schedule)


def test_solver_order_optimum_fixed_seeds():
    for seed in range(40):
        _assert_solver_matches_order_oracle(random_dag(seed))
        _assert_solver_matches_order_oracle(random_dag(seed,
                                                       inplace_every=2))


def test_sim_model_matches_live_sets_on_every_order():
    """The solver's incremental simulator must reproduce the ground-truth
    usage profile step by step, on every topological order."""
    for seed in range(8):
        g = random_dag(seed, inplace_every=3)
        for sched in topo_orders(g):
            sim = _Sim(g)
            profile = []
            for op in sched:
                step, _ = sim.peek(op)
                profile.append(step)
                sim.apply(op)
            assert profile == g.usage_profile(sched)


@st.composite
def dags(draw):
    n_inputs = draw(st.integers(min_value=1, max_value=2))
    n_ops = draw(st.integers(min_value=2, max_value=8))
    sizes = draw(st.lists(st.integers(min_value=1, max_value=64),
                          min_size=3, max_size=6))
    wiring = [draw(st.lists(st.integers(min_value=0, max_value=9),
                            min_size=1, max_size=2))
              for _ in range(n_ops)]
    inplace_every = draw(st.sampled_from([0, 2, 3]))
    from oracle import build_dag
    return build_dag(n_inputs, sizes, wiring, inplace_every)


@given(dags())
@settings(max_examples=25, deadline=None)
def test_solver_order_optimum_hypothesis(g):
    _assert_solver_matches_order_oracle(g)


# --------------------------------------------- joint solve == joint oracle
def _assert_joint_matches_oracle(g):
    sr = solve(g, max_k=ORACLE_MAX_K)
    assert sr.complete
    points = oracle_joint_points(g, max_k=ORACLE_MAX_K)
    opt = min(p for _, p, _ in points)
    assert sr.best.peak == opt
    assert sr.front_json()  # front is never empty
    solver_pairs = sorted((p.extra_macs, p.peak) for p in sr.front)
    assert solver_pairs == oracle_front(points)
    # the schedule itself must be valid against the graph it belongs to
    owner = sr.best.graph if sr.best.graph is not None else g
    assert owner.is_valid_schedule(sr.best.schedule)
    assert owner.peak_usage(sr.best.schedule) == sr.best.peak


def test_joint_optimum_fixed_seeds_fast():
    # a cheap always-on slice of the seed sweep (the rest is `slow`)
    for seed in (2, 3, 4, 8):
        _assert_joint_matches_oracle(random_sliceable_chain(seed))


@pytest.mark.slow
def test_joint_optimum_fixed_seeds():
    for seed in (0, 1, 5, 6, 7, 9, 10, 11):
        _assert_joint_matches_oracle(random_sliceable_chain(seed))


def test_joint_optimum_on_small_chain():
    # fat interior: splitting the middle is the only way down, and the
    # held side branch makes the operator order matter too
    g = sliceable_chain_graph([5, 5, 5], [8, 32, 8], [1, 3],
                              held_bytes=16)
    _assert_joint_matches_oracle(g)


@pytest.mark.slow
def test_joint_optimum_on_handpicked_chain():
    # the larger version: three ops, K up to 3, both axes in play
    g = sliceable_chain_graph([6, 6, 6, 6], [8, 48, 48, 8], [1, 3, 1],
                              held_bytes=32)
    _assert_joint_matches_oracle(g)


@st.composite
def sliceable_chains(draw):
    n = draw(st.integers(min_value=2, max_value=3))
    h = draw(st.sampled_from([4, 5]))
    row_bytes = draw(st.lists(st.sampled_from([4, 8, 16, 24, 32]),
                              min_size=n + 1, max_size=n + 1))
    kernels = draw(st.lists(st.sampled_from([1, 2, 3]),
                            min_size=n, max_size=n))
    held = draw(st.sampled_from([0, 16, 64]))
    return sliceable_chain_graph([h] * (n + 1), row_bytes, kernels, held)


@given(sliceable_chains())
@settings(max_examples=10, deadline=None)
def test_joint_optimum_hypothesis(g):
    _assert_joint_matches_oracle(g)


# ---------------------------------------------------- objective-mode modes
def test_latency_mode_minimises_macs_within_budget():
    g = sliceable_chain_graph([6, 6, 6, 6], [8, 48, 48, 8], [1, 3, 1],
                              held_bytes=32)
    mem = solve(g, max_k=ORACLE_MAX_K)
    for point in mem.front:
        lat = solve(g, mode="latency", arena_budget=point.peak,
                    max_k=ORACLE_MAX_K)
        assert lat.best.peak <= point.peak
        # cheapest in-budget point: no front point fits the budget with
        # fewer extra MACs
        cheaper = [p for p in mem.front if p.peak <= point.peak
                   and p.extra_macs < (lat.best.extra_macs or 0)]
        assert not cheaper


def test_memory_mode_honours_macs_cap():
    g = sliceable_chain_graph([5, 5, 5], [8, 48, 8], [3, 1])
    unbounded = solve(g, max_k=3)
    capped = solve(g, max_k=3, macs_cap=0.0)
    assert capped.best.extra_macs == 0
    assert capped.best.peak >= unbounded.best.peak
    # the zero-cap solve equals the oracle optimum over free configurations
    # (note: a split whose downstream kernels are all 1 recomputes nothing,
    # so this can be *below* the reorder-only optimum)
    free = min(p for _, p, e in oracle_joint_points(g, max_k=3) if e == 0)
    assert capped.best.peak == free
    assert capped.best.peak <= enumerate_min_peak(g)[0]


def test_schedule_api_latency_objective():
    g = sliceable_chain_graph([6, 6, 6, 6], [8, 48, 48, 8], [1, 3, 1],
                              held_bytes=32)
    mem = solve(g, max_k=ORACLE_MAX_K)
    budget = mem.front[0].peak          # loosest point: fits without splits
    res = schedule(g, arena_budget=budget, objective="latency")
    assert res.peak <= budget
    assert (res.extra_macs or 0) == min(
        p.extra_macs for p in mem.front if p.peak <= budget)


# ------------------------------------- 2-D tiled-cascade triple agreement
# The cascade cost model's W-strip branch (``estimate_cascade(strips>1)``)
# is pinned three ways on enumerable tiled chains: its estimate against the
# ground-truth liveness model (``Graph.peak_usage`` of the emitted streaming
# order) and against a validated arena packing.  In the steady-state regime
# (k >= 3: enough slices that the rings are full when the fattest step runs)
# the estimate is EXACT; at k == 2 the warm-up dominates and the estimate
# stays a sound upper bound.

# (h, w, chan_bytes, kernels, strides, kernels_w, strides_w, cuts) — the
# enumerable ground-truth family: uniform and mixed per-axis windows, a
# stride-2 head, asymmetric width kernels, a deeper 4-op chain.
_TILED_EXACT = [
    (12, 12, [4, 4, 4, 4], [3, 3, 3], [1, 1, 1], [3, 3, 3], [1, 1, 1], (1,)),
    (12, 12, [4, 4, 4, 4], [3, 3, 3], [1, 1, 1], [3, 3, 3], [1, 1, 1], (2,)),
    (16, 16, [2, 4, 4, 8], [3, 3, 3], [2, 1, 1], [3, 3, 3], [2, 1, 1], (1,)),
    (12, 16, [4, 4, 2, 2], [3, 1, 3], [1, 1, 1], [2, 3, 3], [1, 1, 1], (2,)),
    (12, 12, [4, 4, 4, 4, 4], [3, 3, 3, 3], [1, 1, 1, 1], [3, 3, 3, 3],
     [1, 1, 1, 1], (2,)),
]


@pytest.mark.parametrize("h,w,cb,ks,ss,kw,sw,cuts", _TILED_EXACT)
def test_tiled_chain_triple_agreement_exact(h, w, cb, ks, ss, kw, sw, cuts):
    g = tiled_chain_graph(h, w, cb, ks, ss, kw, sw)
    points = tiled_triple_points(g, cuts, k_choices=(3, 4, 6),
                                 strips_choices=(1, 2, 3))
    assert len(points) >= 6          # the grid must actually enumerate
    for label, est, live, arena in points:
        assert est == live == arena, (label, est, live, arena)


def _tiled_soundness(seed: int):
    """Random tile/stride/halo combos: the planner cost never underestimates
    the ground-truth liveness (a strips plan sold as in-budget IS in
    budget), and the validated arena packing never beats liveness."""
    g, cuts = random_tiled_chain(seed)
    points = tiled_triple_points(g, cuts)
    assert points
    exact = 0
    for label, est, live, arena in points:
        assert live <= est, (seed, label, est, live)
        assert live <= arena, (seed, label, live, arena)
        exact += est == live == arena
    return exact, len(points)


def test_tiled_chain_cost_model_sound_fixed_seeds():
    exact = total = 0
    for seed in range(40):
        e, t = _tiled_soundness(seed)
        exact += e
        total += t
    # most random combos sit in the exact regime (warm-up-dominated k=2
    # cases and packing fragmentation on irregular byte sizes account for
    # the rest) — a collapse of this ratio means the estimate went slack
    assert exact >= total * 0.4, (exact, total)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_tiled_chain_cost_model_sound_hypothesis(seed):
    _tiled_soundness(seed)
