"""Golden regression tests pinning the headline memory numbers.

These are the numbers DESIGN.md and the benchmarks advertise; a cost-model
or scheduler regression must fail HERE, loudly, instead of silently
inflating peaks until the capacity demos stop fitting.  All assertions are
scheduling-only (no numerics), so they stay in the fast tier.
"""
from repro.core import ArenaPlanner, schedule
from repro.graphs import figure1_graph, mobilenet_v1_graph
from repro.graphs.figure1 import DEFAULT_PEAK, OPTIMAL_PEAK

KB = 1024


def test_figure1_peaks_exact():
    g = figure1_graph()
    assert g.peak_usage(g.default_schedule()) == DEFAULT_PEAK == 5216
    assert schedule(g).peak == OPTIMAL_PEAK == 4960


def test_mobilenet_100_192_headline():
    """The paper-sequel headline: 864 KB reorder-only; <= 330 KB (measured
    315 KB) with reorder + partial execution — fits a 512 KB arena."""
    g = mobilenet_v1_graph(alpha=1.0, resolution=192)
    base = schedule(g)
    assert base.peak == 864 * KB            # 884736 B, reorder-only floor
    res = schedule(g, arena_budget=512 * KB)
    gp = res.graph if res.graph is not None else g
    plan = ArenaPlanner.plan(gp, res.schedule)
    ArenaPlanner.validate(plan)
    assert res.peak <= 330 * KB
    assert plan.arena_size <= 330 * KB
    assert plan.arena_size <= 512 * KB      # the capacity demo itself


def test_mobilenet_050_192_fits_256K():
    g = mobilenet_v1_graph(alpha=0.5, resolution=192)
    base = schedule(g)
    assert base.peak > 256 * KB             # reorder alone cannot fit
    res = schedule(g, arena_budget=256 * KB)
    gp = res.graph if res.graph is not None else g
    plan = ArenaPlanner.plan(gp, res.schedule)
    ArenaPlanner.validate(plan)
    assert res.peak <= 256 * KB
    assert plan.arena_size <= 256 * KB
