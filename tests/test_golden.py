"""Golden regression tests pinning the headline memory numbers, in BYTES.

These are the numbers DESIGN.md and the benchmarks advertise; a cost-model,
scheduler or quantization regression must fail HERE, loudly, instead of
silently inflating peaks until the capacity demos stop fitting.  All
assertions are scheduling-only (``int8_scheduling_graph`` reproduces the
quantized model's exact byte sizes without calibration), so they stay in
the fast tier.

Unit convention (the single place to read it): every peak / arena number in
this repo is **bytes**.  Float graphs carry 4 bytes per element, int8
graphs 1 — so the same MobileNet topology is pinned at both widths, and
the int8 figures are directly comparable with the paper and with Pex /
MCUNet, which report byte budgets.
"""
from repro.core import ArenaPlanner, schedule
from repro.graphs import (figure1_graph, int8_scheduling_graph,
                          mobilenet_v1_graph)
from repro.graphs.figure1 import DEFAULT_PEAK, OPTIMAL_PEAK

KB = 1024


def _plan(res, g):
    gp = res.graph if res.graph is not None else g
    plan = ArenaPlanner.plan(gp, res.schedule)
    ArenaPlanner.validate(plan, gp)
    return plan


def test_figure1_peaks_exact():
    g = figure1_graph()
    assert g.peak_usage(g.default_schedule()) == DEFAULT_PEAK == 5216
    assert schedule(g).peak == OPTIMAL_PEAK == 4960


def test_mobilenet_100_192_headline():
    """The headline composition on MobileNet-1.0@192: f32 reorder-only
    needs 3456 KB; int8 alone cuts that 4x to 864 KB; int8 + reorder +
    partial execution reaches 315 KB — inside a 512 KB MCU arena that no
    other single technique here gets near.

    (A <=256 KB arena is out of reach of the whole-externals segment
    model — the ~280 KB input+accumulator floor — but cascaded Pex
    streaming breaks it: see test_cascade.py's 243 KB golden.)
    """
    g = mobilenet_v1_graph(alpha=1.0, resolution=192)
    assert schedule(g).peak == 3456 * KB     # f32 reorder-only floor
    q = int8_scheduling_graph(g)
    assert schedule(q).peak == 864 * KB      # int8 reorder-only: exactly /4

    res = schedule(q, arena_budget=512 * KB)
    plan = _plan(res, q)
    assert res.peak <= 330 * KB
    assert plan.arena_size <= 330 * KB
    assert plan.arena_size <= 512 * KB       # the capacity demo itself


def test_mobilenet_100_192_cascade2d_221696_headline():
    """The 2-D tiled-cascade headline, pinned to the byte: MobileNet-1.0
    @192 int8 under a 224 KB budget schedules as a W-strip cascade
    (``+cascade2d``) at EXACTLY 221696 B (216.5 KB) — below the 243 KB
    (248832 B) row-ring floor the 1-D cascade golden pins — and the arena
    packing achieves the liveness peak with zero slack."""
    q = int8_scheduling_graph(mobilenet_v1_graph(alpha=1.0, resolution=192))
    res = schedule(q, arena_budget=224 * KB)
    assert "cascade2d" in res.method
    plan = _plan(res, q)
    assert res.peak == 221696
    assert plan.arena_size == 221696
    assert res.extra_macs_frac <= 0.25


def test_mobilenet_050_192_fits_256K():
    """The 256 KB stretch target: int8 + reorder + partial execution on
    MobileNet-0.5@192 (f32 reorder-only is 1728 KB, int8 reorder-only
    432 KB — neither fits)."""
    g = mobilenet_v1_graph(alpha=0.5, resolution=192)
    q = int8_scheduling_graph(g)
    base = schedule(q)
    assert base.peak == 432 * KB             # int8 reorder alone cannot fit
    res = schedule(q, arena_budget=256 * KB)
    plan = _plan(res, q)
    assert res.peak <= 256 * KB
    assert plan.arena_size <= 256 * KB


def test_int8_exactly_quarters_f32_bytes():
    """The quantized rewrite shrinks every schedule's peak and every arena
    plan by exactly the f32 itemsize: byte accounting composes with
    scheduling with no slack."""
    g = mobilenet_v1_graph()                 # 0.25 @ 96
    q = int8_scheduling_graph(g)
    rf, rq = schedule(g), schedule(q)
    assert rf.peak == 4 * rq.peak
    pf = ArenaPlanner.plan(g, rf.schedule)
    pq = ArenaPlanner.plan(q, rq.schedule)
    assert pf.arena_size == 4 * pq.arena_size
