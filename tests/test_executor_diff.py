"""Differential suite: compiled arena executor vs the micro-interpreter.

The compiled executor (one jitted program over one uint8 byte arena) must
be **bit-identical** to the Python-loop ``MicroInterpreter`` under both of
the interpreter's allocators — the §4 dynamic first-fit+defrag allocator
and §6 plan-mode execution against precomputed offsets — across the paper
graphs × {default, greedy, exact/contracted, pex} schedules, and must
execute against exactly ``plan.arena_size`` bytes.  The grid runs each
graph at both element widths: the float build and its post-training int8
quantization (plus a directly-constructed int8 figure1), and a
mixed-dtype graph checks f32 and int8 placements coexist in one arena.
"""
import numpy as np
import pytest

import repro.deploy as deploy
from repro.core import ArenaPlanner, greedy_schedule, partition_graph, schedule
from repro.core.graph import Graph
from repro.graphs import (figure1_executable_graph, figure1_int8_graph,
                          mobilenet_v1_graph, quantize_graph, random_input,
                          swiftnet_cell_graph)
from repro.graphs.cnn_ops import (CNNBuilder, conv2d, dequantize_array,
                                  quantize_array, _weight)
from repro.mcu import MicroInterpreter, compile_schedule
from repro.serving import GraphServingEngine


def _tiny_cnn() -> Graph:
    """A small branchy CNN covering every builder kind (fast tier)."""
    g = Graph()
    b = CNNBuilder(g)
    x = b.input("input", 16, 16, 3)
    x = b.conv(x, 8, k=3)
    a = b.conv(x, 8, k=1)
    a = b.dwconv(a, k=3)
    bb = b.maxpool(x, k=2, stride=2)
    bb = b.conv(bb, 8, k=1)
    # bring branch b back to a's resolution via a second maxpool on a
    a = b.maxpool(a, k=2, stride=2)
    y = b.add(a, bb)
    y = b.concat([y, bb])
    y = b.avgpool(y)
    y = b.fc(y, 4)
    g.set_outputs([y])
    return g


def _quantized(factory):
    """int8 build of a float graph factory (calibrated on its shared
    random input)."""
    def make():
        g = factory()
        return quantize_graph(g, random_input(g)).graph
    return make


_GRAPHS = {
    "figure1": figure1_executable_graph,
    "tiny_cnn": _tiny_cnn,
    "mobilenet": mobilenet_v1_graph,
    "swiftnet": swiftnet_cell_graph,
    "figure1_int8": figure1_int8_graph,
    "tiny_cnn_int8": _quantized(_tiny_cnn),
    "mobilenet_int8": _quantized(mobilenet_v1_graph),
}


def _schedule_cases(g: Graph):
    """(label, schedule, graph-the-schedule-belongs-to) for the diff grid."""
    cases = [("default", g.default_schedule(), g),
             ("greedy", greedy_schedule(g).schedule, g)]
    res = schedule(g)                      # exact / contracted / beam winner
    cases.append((res.method, res.schedule, g))
    pres = schedule(g, partition=True)     # partial-execution rewrite
    gp = pres.graph if pres.graph is not None else g
    cases.append((f"pex:{pres.method}", pres.schedule, gp))
    return cases


@pytest.mark.parametrize("name", [
    "figure1",
    "tiny_cnn",
    "mobilenet",
    pytest.param("swiftnet", marks=pytest.mark.slow),
    "figure1_int8",
    "tiny_cnn_int8",
    "mobilenet_int8",
])
def test_compiled_bit_identical_and_arena_exact(name):
    g = _GRAPHS[name]()
    x = random_input(g)
    ref = MicroInterpreter(g).run(x)       # embedded order, dynamic allocator
    for label, sched, gx in _schedule_cases(g):
        plan = ArenaPlanner.plan(gx, sched)
        ArenaPlanner.validate(plan, gx)
        rep_dyn = MicroInterpreter(gx).run(x, schedule=sched)
        rep_plan = MicroInterpreter(gx).run(x, schedule=sched, plan=plan)
        ex = compile_schedule(gx, sched, plan)
        out = ex.run(x)
        for o in g.outputs:
            np.testing.assert_array_equal(
                ref.outputs[o], rep_dyn.outputs[o],
                err_msg=f"{name}/{label}: dynamic interpreter drifted")
            np.testing.assert_array_equal(
                rep_dyn.outputs[o], rep_plan.outputs[o],
                err_msg=f"{name}/{label}: plan-mode interpreter drifted")
            np.testing.assert_array_equal(
                rep_dyn.outputs[o], out[o],
                err_msg=f"{name}/{label}: compiled executor drifted")
        # the executor's whole memory is the plan's arena, exactly
        assert ex.arena_size == plan.arena_size
        assert rep_plan.peak_sram <= plan.arena_size


def test_pex_slices_roll_into_fori_loops():
    """Uniform Pex slices must compile to fori_loops (code size stays
    O(segment), not O(K * segment)) — and stay bit-identical."""
    g = mobilenet_v1_graph()
    pr = partition_graph(g, budget=48 * 1024)
    assert pr.segments, "partition must trigger on a 48KB budget"
    gp = pr.graph
    sched = gp.default_schedule()          # insertion order = pex order
    ex = compile_schedule(gp, sched)
    assert ex.rolled_loops > 0
    assert ex.rolled_ops > 0
    x = random_input(g)
    ref = MicroInterpreter(gp).run(x, schedule=sched)
    out = ex.run(x)
    for o in g.outputs:
        np.testing.assert_array_equal(ref.outputs[o], out[o])
    # rolling is an optimisation detail: unrolled must agree bit-for-bit
    out_unrolled = compile_schedule(gp, sched, roll_loops=False).run(x)
    for o in g.outputs:
        np.testing.assert_array_equal(out[o], out_unrolled[o])


def test_compiled_pallas_conv_within_tolerance():
    """use_pallas routes MCU-shaped pointwise convs through the fused
    Pallas kernel: fast path, float-tolerance (not bit) contract."""
    g = _tiny_cnn()
    x = random_input(g)
    sched = schedule(g).schedule
    ref = MicroInterpreter(g).run(x, schedule=sched)
    ex = compile_schedule(g, sched, use_pallas=True, interpret=True)
    out = ex.run(x)
    for o in g.outputs:
        np.testing.assert_allclose(ref.outputs[o], out[o],
                                   rtol=2e-5, atol=1e-6)


def test_graph_serving_engine_micro_batches():
    g = _tiny_cnn()
    d = deploy.build(g)                     # the facade path engines ride on
    eng = GraphServingEngine(deployment=d, micro_batch=2)
    rng = np.random.default_rng(3)
    reqs = [{"input": rng.standard_normal((16, 16, 3)).astype(np.float32)}
            for _ in range(5)]
    outs = eng.serve(reqs)
    assert len(outs) == 5
    assert eng.stats.dispatches == 3
    assert eng.stats.padded_lanes == 1      # 5 requests over 3 x 2 lanes
    for r, o in zip(reqs, outs):
        ref = MicroInterpreter(eng.exec_graph).run(
            r, schedule=eng.result.schedule)
        for name in g.outputs:
            np.testing.assert_array_equal(ref.outputs[name], o[name])


def test_deploy_facade_is_the_compiled_chain():
    """repro.deploy.build == schedule -> plan -> validate -> compile, so
    its outputs sit inside the same differential contract: bit-identical
    to the interpreter on the facade's own schedule."""
    for factory in (_tiny_cnn, _quantized(_tiny_cnn)):
        g = factory()
        d = deploy.build(g)
        x = random_input(g)
        ref = MicroInterpreter(d.exec_graph).run(x, schedule=d.schedule)
        out = d.run(x)
        for o in g.outputs:
            np.testing.assert_array_equal(ref.outputs[o], out[o])
        assert d.arena_bytes == d.plan.arena_size == d.executor.arena_size


def _mixed_dtype_graph() -> Graph:
    """int8 -> dequant -> f32 conv -> quant -> int8: both element widths
    resident in the one byte arena, with an odd-sized int8 tensor so the
    4-byte alignment policy actually pads."""
    g = Graph()
    g.add_tensor("x", 9 * 9 * 3, (9, 9, 3), dtype="int8")          # 243 B
    g.add_tensor("xf", 4 * 9 * 9 * 3, (9, 9, 3), dtype="float32")
    g.add_tensor("yf", 4 * 9 * 9 * 5, (9, 9, 5), dtype="float32")
    g.add_tensor("y", 9 * 9 * 5, (9, 9, 5), dtype="int8")          # 405 B
    w = _weight("mixed_w", (3, 3, 3, 5))
    g.add_operator("deq", ["x"], "xf", kind="dequant",
                   fn=lambda q: dequantize_array(q, 0.05, 3),
                   scale=0.05, zp=3)
    g.add_operator("conv", ["xf"], "yf", kind="conv",
                   fn=lambda a, w=w: conv2d(a, w, 1),
                   weight=w, k=3, stride=1)
    g.add_operator("q", ["yf"], "y", kind="quant",
                   fn=lambda v: quantize_array(v, 0.1, -5),
                   scale=0.1, zp=-5)
    g.set_outputs(["y"])
    return g


def test_mixed_dtype_graph_shares_one_byte_arena():
    g = _mixed_dtype_graph()
    x = random_input(g)
    sched = g.default_schedule()
    plan = ArenaPlanner.plan(g, sched)
    ArenaPlanner.validate(plan, g)          # incl. per-dtype alignment
    # both widths really are in this plan
    widths = {g.itemsize(p.tensor) for p in plan.placements}
    assert widths == {1, 4}
    ref = MicroInterpreter(g).run(x, schedule=sched)
    ex = compile_schedule(g, sched, plan)
    out = ex.run(x)
    np.testing.assert_array_equal(ref.outputs["y"], out["y"])
    assert out["y"].dtype == np.int8
    assert ex.arena_size == plan.arena_size


def test_compile_rejects_misaligned_plan():
    """A byte-granular plan that puts an f32 tensor at an odd offset must
    be rejected at compile time (the bitcast-view precondition).  An
    odd-sized int8 input followed by a co-live f32 tensor forces the odd
    offset under alignment=1."""
    g = Graph()
    g.add_tensor("a", 1001, (1001,), dtype="int8")
    g.add_tensor("b", 900, (225,), dtype="float32")
    g.add_operator("op", ["a"], "b")
    g.set_outputs(["b"])
    sched = g.default_schedule()
    plan = ArenaPlanner.plan(g, sched, alignment=1)
    assert plan.offset_of("b") % 4 != 0     # the scenario really happened
    with pytest.raises(ValueError, match="misaligned"):
        compile_schedule(g, sched, plan)
    # the auto-aligned default plan compiles (op has no semantics, so only
    # the pre-trace validation is exercised by the misaligned case)
    aligned = ArenaPlanner.plan(g, sched)
    assert aligned.offset_of("b") % 4 == 0


def test_compile_rejects_invalid_schedule():
    g = _tiny_cnn()
    sched = g.default_schedule()
    with pytest.raises(ValueError):
        compile_schedule(g, sched[::-1])


def test_run_rejects_missing_input():
    g = _tiny_cnn()
    ex = compile_schedule(g)
    with pytest.raises(ValueError, match="missing graph inputs"):
        ex.run({})


def test_compiled_rejects_wrong_dtype_input():
    """make_arena must hold the same dtype-honesty contract as the
    interpreter instead of silently value-casting (an f32 image fed to an
    int8 graph would otherwise saturate to garbage)."""
    g = figure1_int8_graph()
    ex = compile_schedule(g)
    with pytest.raises(ValueError, match="declares int8"):
        ex.run({"t0": np.zeros(g.elements("t0"), np.float32)})
