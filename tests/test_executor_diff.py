"""Differential suite: compiled arena executor vs the micro-interpreter.

The compiled executor (one jitted program over one arena buffer) must be
**bit-identical** to the Python-loop ``MicroInterpreter`` under both of the
interpreter's allocators — the §4 dynamic first-fit+defrag allocator and
§6 plan-mode execution against precomputed offsets — across the paper
graphs × {default, greedy, exact/contracted, pex} schedules, and must
execute against exactly ``plan.arena_size`` elements.
"""
import numpy as np
import pytest

from repro.core import ArenaPlanner, greedy_schedule, partition_graph, schedule
from repro.core.graph import Graph
from repro.graphs import (figure1_executable_graph, mobilenet_v1_graph,
                          random_input, swiftnet_cell_graph)
from repro.graphs.cnn_ops import CNNBuilder
from repro.mcu import MicroInterpreter, compile_schedule
from repro.serving import GraphServingEngine


def _tiny_cnn() -> Graph:
    """A small branchy CNN covering every builder kind (fast tier)."""
    g = Graph()
    b = CNNBuilder(g)
    x = b.input("input", 16, 16, 3)
    x = b.conv(x, 8, k=3)
    a = b.conv(x, 8, k=1)
    a = b.dwconv(a, k=3)
    bb = b.maxpool(x, k=2, stride=2)
    bb = b.conv(bb, 8, k=1)
    # bring branch b back to a's resolution via a second maxpool on a
    a = b.maxpool(a, k=2, stride=2)
    y = b.add(a, bb)
    y = b.concat([y, bb])
    y = b.avgpool(y)
    y = b.fc(y, 4)
    g.set_outputs([y])
    return g


_GRAPHS = {
    "figure1": figure1_executable_graph,
    "tiny_cnn": _tiny_cnn,
    "mobilenet": mobilenet_v1_graph,
    "swiftnet": swiftnet_cell_graph,
}


def _schedule_cases(g: Graph):
    """(label, schedule, graph-the-schedule-belongs-to) for the diff grid."""
    cases = [("default", g.default_schedule(), g),
             ("greedy", greedy_schedule(g).schedule, g)]
    res = schedule(g)                      # exact / contracted / beam winner
    cases.append((res.method, res.schedule, g))
    pres = schedule(g, partition=True)     # partial-execution rewrite
    gp = pres.graph if pres.graph is not None else g
    cases.append((f"pex:{pres.method}", pres.schedule, gp))
    return cases


@pytest.mark.parametrize("name", [
    "figure1",
    "tiny_cnn",
    "mobilenet",
    pytest.param("swiftnet", marks=pytest.mark.slow),
])
def test_compiled_bit_identical_and_arena_exact(name):
    g = _GRAPHS[name]()
    x = random_input(g)
    ref = MicroInterpreter(g).run(x)       # embedded order, dynamic allocator
    for label, sched, gx in _schedule_cases(g):
        plan = ArenaPlanner.plan(gx, sched)
        ArenaPlanner.validate(plan)
        rep_dyn = MicroInterpreter(gx).run(x, schedule=sched)
        rep_plan = MicroInterpreter(gx).run(x, schedule=sched, plan=plan)
        ex = compile_schedule(gx, sched, plan)
        out = ex.run(x)
        for o in g.outputs:
            np.testing.assert_array_equal(
                ref.outputs[o], rep_dyn.outputs[o],
                err_msg=f"{name}/{label}: dynamic interpreter drifted")
            np.testing.assert_array_equal(
                rep_dyn.outputs[o], rep_plan.outputs[o],
                err_msg=f"{name}/{label}: plan-mode interpreter drifted")
            np.testing.assert_array_equal(
                rep_dyn.outputs[o], out[o],
                err_msg=f"{name}/{label}: compiled executor drifted")
        # the executor's whole memory is the plan's arena, exactly
        assert ex.arena_size == plan.arena_size
        assert rep_plan.peak_sram <= plan.arena_size


def test_pex_slices_roll_into_fori_loops():
    """Uniform Pex slices must compile to fori_loops (code size stays
    O(segment), not O(K * segment)) — and stay bit-identical."""
    g = mobilenet_v1_graph()
    pr = partition_graph(g, budget=48 * 1024)
    assert pr.segments, "partition must trigger on a 48KB budget"
    gp = pr.graph
    sched = gp.default_schedule()          # insertion order = pex order
    ex = compile_schedule(gp, sched)
    assert ex.rolled_loops > 0
    assert ex.rolled_ops > 0
    x = random_input(g)
    ref = MicroInterpreter(gp).run(x, schedule=sched)
    out = ex.run(x)
    for o in g.outputs:
        np.testing.assert_array_equal(ref.outputs[o], out[o])
    # rolling is an optimisation detail: unrolled must agree bit-for-bit
    out_unrolled = compile_schedule(gp, sched, roll_loops=False).run(x)
    for o in g.outputs:
        np.testing.assert_array_equal(out[o], out_unrolled[o])


def test_compiled_pallas_conv_within_tolerance():
    """use_pallas routes MCU-shaped pointwise convs through the fused
    Pallas kernel: fast path, float-tolerance (not bit) contract."""
    g = _tiny_cnn()
    x = random_input(g)
    sched = schedule(g).schedule
    ref = MicroInterpreter(g).run(x, schedule=sched)
    ex = compile_schedule(g, sched, use_pallas=True, interpret=True)
    out = ex.run(x)
    for o in g.outputs:
        np.testing.assert_allclose(ref.outputs[o], out[o],
                                   rtol=2e-5, atol=1e-6)


def test_graph_serving_engine_micro_batches():
    g = _tiny_cnn()
    eng = GraphServingEngine(g, micro_batch=2)
    rng = np.random.default_rng(3)
    reqs = [{"input": rng.standard_normal((16, 16, 3)).astype(np.float32)}
            for _ in range(5)]
    outs = eng.serve(reqs)
    assert len(outs) == 5
    assert eng.stats["micro_batches"] == 3
    for r, o in zip(reqs, outs):
        ref = MicroInterpreter(eng.exec_graph).run(
            r, schedule=eng.result.schedule)
        for name in g.outputs:
            np.testing.assert_array_equal(ref.outputs[name], o[name])


def test_compile_rejects_invalid_schedule():
    g = _tiny_cnn()
    sched = g.default_schedule()
    with pytest.raises(ValueError):
        compile_schedule(g, sched[::-1])


def test_run_rejects_missing_input():
    g = _tiny_cnn()
    ex = compile_schedule(g)
    with pytest.raises(ValueError, match="missing graph inputs"):
        ex.run({})
