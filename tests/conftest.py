# Markers and the fast-by-default selection live in pytest.ini.
#
# Hypothesis profiles: the named "ci" profile pins a fixed deadline, keeps
# derandomization OFF (every workflow run explores fresh examples) and
# prints the reproduction blob on failure, so a property-test flake in a
# workflow log is reproducible locally via the printed
# ``@reproduce_failure`` / ``@seed`` decorators.  Select it with
# HYPOTHESIS_PROFILE=ci (the CI workflow does).
import os

try:
    from hypothesis import settings
except ImportError:  # pragma: no cover
    pass
else:
    settings.register_profile("ci", deadline=10_000, derandomize=False, print_blob=True)
    _profile = os.environ.get("HYPOTHESIS_PROFILE")
    if _profile:
        settings.load_profile(_profile)
