# Markers and the fast-by-default selection live in pytest.ini.
