"""``from hypothesis_compat import given, settings, st`` — the real
hypothesis when installed (see requirements-dev.txt), otherwise stubs that
mark each ``@given`` property test skipped while letting the plain tests in
the same module collect and run."""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return pytest.mark.skip(
            reason="property test needs hypothesis "
                   "(pip install -r requirements-dev.txt)")

    def settings(*_a, **_k):
        return lambda f: f

    class _StrategyStub:
        """Absorbs any strategy construction/chaining at decoration time."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    st = _StrategyStub()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
