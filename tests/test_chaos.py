"""Chaos suite for the failure layer (DESIGN.md §12): every injected
fault must end as a retry-success, a typed error result, or a recorded
degradation — never a hang and never a silent wrong answer.

Everything here is deterministic: faults come from a seeded ``FaultPlan``
(same seed → same fault sequence, see serving/faults.py), clocks are
injectable fakes where timing matters, and "no silent wrong answer" is
checked by comparing every successful output bit-for-bit against a
fault-free ``Deployment.run`` of the same request.
"""
import numpy as np
import pytest

import repro.deploy as deploy
from repro.core.graph import Graph
from repro.errors import (DeviceInitError, DispatchFailedError,
                          GuardViolation, NaNActivationError)
from repro.graphs import figure1_int8_graph, random_input
from repro.graphs.cnn_ops import CNNBuilder
from repro.mcu.compile import CANARY_BYTE
from repro.serving import (FaultInjector, FaultPlan, GraphServingEngine,
                           RequestError, ShardedServingEngine)


def _tiny_cnn() -> Graph:
    g = Graph()
    b = CNNBuilder(g)
    x = b.input("input", 12, 12, 3)
    x = b.conv(x, 6, k=3)
    y = b.maxpool(x, k=2, stride=2)
    y = b.fc(y, 4)
    g.set_outputs([y])
    return g


class FakeClock:
    """Injectable clock: time moves only when the test says so."""

    def __init__(self, t: float = 0.0) -> None:
        self.t = t

    def __call__(self) -> float:
        return self.t


@pytest.fixture(scope="module")
def d_int8():
    return deploy.build(figure1_int8_graph())


@pytest.fixture(scope="module")
def d_float():
    return deploy.build(_tiny_cnn())


@pytest.fixture(scope="module")
def d_float_guarded():
    return deploy.build(_tiny_cnn(), guard_bytes=32)


def _reqs(g, n, seed0=0):
    return [random_input(g, seed=seed0 + i) for i in range(n)]


def _assert_ok_lanes_bit_identical(d, reqs, results):
    """Every non-error result must equal the fault-free reference exactly
    — the 'no silent wrong answer' half of the chaos invariant."""
    for r, out in zip(reqs, results):
        if isinstance(out, RequestError):
            continue
        ref = d.run(r)
        for name in d.exec_graph.outputs:
            np.testing.assert_array_equal(ref[name], out[name])


# ----------------------------------------------------------- no-fault base
def test_no_fault_config_is_byte_identical_and_counts_zero(d_int8):
    """The CI chaos gate's premise: with no faults and no guards the
    engine is the pre-failure-layer engine — outputs bit-identical to
    one-shot runs, every robustness counter exactly zero."""
    g = d_int8.exec_graph
    reqs = _reqs(g, 5, seed0=31)
    eng = ShardedServingEngine(d_int8, replicas=1, lanes=2)
    outs = eng.serve(reqs)
    _assert_ok_lanes_bit_identical(d_int8, reqs, outs)
    s = eng.stats
    assert s.admitted == 5
    assert (s.expired, s.shed, s.retried, s.failed,
            s.watchdog_trips) == (0, 0, 0, 0, 0)
    assert s.degraded is None
    j = s.as_json()
    # measured-zero contract: the counters must be PRESENT as 0 in the
    # payload (bench rows feed the compare.py zero gate from these)
    for k in ("expired", "shed", "retried", "failed"):
        assert j[k] == 0


# ------------------------------------------------------------- chaos sweep
def test_chaos_sweep_every_fault_accounted():
    """The headline invariant: a seeded sweep mixing device errors,
    arena corruption and NaN poisoning, with the injector's ledger
    balanced exactly against retries + typed failures."""
    d = deploy.build(_tiny_cnn(), guard_bytes=32)
    g = d.exec_graph
    inj = FaultInjector(FaultPlan(seed=42, device_error_rate=0.15,
                                  corrupt_rate=0.25, nan_rate=0.25))
    eng = ShardedServingEngine(d, replicas=1, lanes=2, max_retries=2,
                               faults=inj)
    reqs = _reqs(g, 12, seed0=7)
    rids = [eng.submit(r) for r in reqs]
    done = eng.drain()
    results = [done[rid] for rid in rids]
    s = eng.stats

    # 1. liveness: every request has exactly one result, typed or ok
    assert len(results) == 12
    codes = [r.code if isinstance(r, RequestError) else "ok"
             for r in results]
    assert set(codes) <= {"ok", "corrupted", "nan_output",
                          "dispatch_failed"}

    # 2. no silent wrong answer
    _assert_ok_lanes_bit_identical(d, reqs, results)

    # 3. the sweep actually exercised every fault kind (seed-pinned)
    led = inj.injected
    assert led["device_error"] > 0 and led["corrupt"] > 0 \
        and led["nan"] > 0

    # 4. ledger balance: each device error consumed one retry (none
    #    exhausted the dispatch budget here), each poisoned lane either
    #    re-queued (a retry) or ended as a typed failure
    poison_failed = sum(1 for c in codes if c in ("corrupted",
                                                  "nan_output"))
    dispatch_failed = sum(1 for c in codes if c == "dispatch_failed")
    assert dispatch_failed == 0        # seed-pinned: budget never spent
    assert s.retried == led["device_error"] + \
        (led["corrupt"] + led["nan"] - poison_failed)
    assert s.failed == poison_failed


# -------------------------------------------------------------- guard bytes
def test_guard_build_bit_identical_and_regions_planned(d_float,
                                                       d_float_guarded):
    """guard_bytes=N must not change a single output byte — only add
    canary-filled never-placed regions to the arena."""
    g = d_float.exec_graph
    assert d_float.executor.guard_regions == ()
    assert d_float.guard_bytes == 0
    regions = d_float_guarded.executor.guard_regions
    assert regions and d_float_guarded.guard_bytes == 32
    assert d_float_guarded.arena_bytes >= d_float.arena_bytes
    for seed in range(3):
        x = random_input(g, seed=seed)
        ref, out = d_float.run(x), d_float_guarded.run(x)
        for name in g.outputs:
            np.testing.assert_array_equal(ref[name], out[name])


def test_guard_regions_are_complement_of_placements(d_float_guarded):
    """Soundness: guard regions never overlap any tensor placement, so a
    canary can only be stomped by an out-of-bounds write."""
    plan = d_float_guarded.plan
    spans = sorted((p.offset, p.offset + p.size) for p in plan.placements)
    for off, size in plan.guard_regions():
        for lo, hi in spans:
            assert off + size <= lo or off >= hi, \
                f"guard [{off},{off + size}) overlaps placement [{lo},{hi})"


def test_guard_canary_detects_stomp(d_float_guarded):
    """A byte flipped inside a guard region raises GuardViolation naming
    the offset; an untouched arena verifies clean."""
    ex = d_float_guarded.executor
    x = random_input(d_float_guarded.exec_graph, seed=3)
    arena = np.array(ex.fn(ex.make_arena(x)))
    ex.verify_guards(arena)                     # clean run passes
    off, size = ex.guard_regions[0]
    assert int(arena[off]) == CANARY_BYTE
    arena[off] ^= 0xFF
    with pytest.raises(GuardViolation, match=str(off)):
        ex.verify_guards(arena)


def test_guarded_golden_graph_serving(d_int8):
    """Guard-byte serving on the golden int8 graph: canaries verified
    every dispatch, outputs still bit-identical to the unguarded build."""
    dg = deploy.build(figure1_int8_graph(), guard_bytes=16)
    assert dg.executor.guard_regions
    g = dg.exec_graph
    reqs = _reqs(g, 4, seed0=50)
    outs = ShardedServingEngine(dg, replicas=1, lanes=2).serve(reqs)
    _assert_ok_lanes_bit_identical(d_int8, reqs, outs)


def test_guard_corruption_detected_by_genuine_canary_check():
    """With guards planned, injected corruption lands in a guard region
    and is caught by verify_guards itself — retries first, then typed."""
    d = deploy.build(_tiny_cnn(), guard_bytes=32)
    eng = ShardedServingEngine(
        d, replicas=1, lanes=2, max_retries=0,
        faults=FaultPlan(seed=9, corrupt_rate=1.0))
    reqs = _reqs(d.exec_graph, 2, seed0=70)
    outs = eng.serve(reqs)
    assert all(isinstance(o, RequestError) and o.code == "corrupted"
               for o in outs)
    assert eng.stats.failed == 2 and eng.stats.retried == 0


def test_guardless_corruption_surfaces_as_ecc_signal(d_int8):
    """Without guards the injector's lane report stands in for the
    hardware ECC/bus-fault line: corruption still becomes a typed error,
    never a silently wrong answer."""
    eng = ShardedServingEngine(d_int8, replicas=1, lanes=2, max_retries=0,
                               faults=FaultPlan(seed=4, corrupt_rate=1.0))
    outs = eng.serve(_reqs(d_int8.exec_graph, 2, seed0=80))
    assert all(isinstance(o, RequestError) and o.code == "corrupted"
               for o in outs)


def test_nan_poison_detected_by_output_scan(d_float):
    """NaN injection on a float output is caught by the genuine
    np.isnan scan; with retries available the request eventually
    succeeds bit-identically (the fault is per-dispatch, not sticky)."""
    eng = ShardedServingEngine(d_float, replicas=1, lanes=2, max_retries=0,
                               faults=FaultPlan(seed=2, nan_rate=1.0))
    reqs = _reqs(d_float.exec_graph, 2, seed0=90)
    outs = eng.serve(reqs)
    assert all(isinstance(o, RequestError) and o.code == "nan_output"
               for o in outs)


# -------------------------------------------------------- retry / watchdog
def test_transient_device_errors_retried_to_success(d_int8):
    eng = ShardedServingEngine(
        d_int8, replicas=1, lanes=2, max_retries=3,
        faults=FaultPlan(seed=11, device_error_rate=0.3))
    reqs = _reqs(d_int8.exec_graph, 8, seed0=100)
    outs = eng.serve(reqs)
    _assert_ok_lanes_bit_identical(d_int8, reqs, outs)
    assert not any(isinstance(o, RequestError) for o in outs)
    s = eng.stats
    assert s.retried > 0 and s.failed == 0


def test_persistent_device_error_becomes_typed_failure(d_int8):
    """rate=1.0: every attempt raises, the budget exhausts, every admitted
    request gets a typed dispatch_failed — the engine never hangs or
    returns garbage."""
    eng = ShardedServingEngine(
        d_int8, replicas=1, lanes=2, max_retries=1,
        faults=FaultPlan(seed=5, device_error_rate=1.0))
    outs = eng.serve(_reqs(d_int8.exec_graph, 2, seed0=110))
    assert all(isinstance(o, RequestError) and o.code == "dispatch_failed"
               for o in outs)
    s = eng.stats
    assert s.failed == 2 and s.retried == 2   # 2 failed attempts counted


def test_watchdog_converts_slow_device_to_typed_failure(d_int8):
    """A persistently slow dispatch trips the post-hoc watchdog: the late
    result is discarded, the retry budget spends, the failure is typed —
    bounded tail latency instead of an unbounded stall."""
    eng = ShardedServingEngine(
        d_int8, replicas=1, lanes=2, max_retries=1, dispatch_timeout=0.005,
        faults=FaultPlan(seed=6, slow_rate=1.0, slow_s=0.03))
    outs = eng.serve(_reqs(d_int8.exec_graph, 2, seed0=120))
    assert all(isinstance(o, RequestError) and o.code == "dispatch_failed"
               for o in outs)
    s = eng.stats
    assert s.watchdog_trips == 2 and s.failed == 2


# ------------------------------------------------- deadlines and shedding
def test_deadline_expiry_fake_clock_never_executes(d_int8):
    """Requests whose deadline passes before admission are expired typed
    — and provably never executed (no dispatch happens when everything
    queued is stale)."""
    clk = FakeClock(0.0)
    eng = ShardedServingEngine(d_int8, replicas=1, lanes=2, clock=clk)
    g = d_int8.exec_graph
    stale = [eng.submit(random_input(g, seed=i), deadline=1.0)
             for i in range(2)]
    fresh = eng.submit(random_input(g, seed=9), deadline=100.0)
    clk.t = 5.0                                # both stale deadlines pass
    eng.step()
    for rid in stale:
        err = eng.take(rid)
        assert isinstance(err, RequestError) and err.code == "expired"
        assert "deadline" in err.detail
    done = eng.drain()
    assert not isinstance(done[fresh], RequestError)
    s = eng.stats
    assert s.expired == 2 and s.admitted == 1 and s.dispatches == 1


def test_all_expired_step_dispatches_nothing(d_int8):
    clk = FakeClock(0.0)
    eng = ShardedServingEngine(d_int8, replicas=1, lanes=2, clock=clk)
    rid = eng.submit(random_input(d_int8.exec_graph, seed=1), deadline=0.5)
    clk.t = 2.0
    assert eng.step() == 0
    assert isinstance(eng.take(rid), RequestError)
    eng.drain()
    assert eng.stats.dispatches == 0 and eng.stats.expired == 1


def test_shedding_beyond_max_pending_exact(d_int8):
    """Submissions over max_pending get an immediate typed shed result;
    the count is exact and admitted requests are unaffected."""
    eng = ShardedServingEngine(d_int8, replicas=1, lanes=2, max_pending=2)
    g = d_int8.exec_graph
    reqs = _reqs(g, 4, seed0=130)
    rids = [eng.submit(r) for r in reqs]
    shed = [eng.take(rid) for rid in rids[2:]]   # shed: result is immediate
    assert all(isinstance(e, RequestError) and e.code == "shed"
               for e in shed)
    done = eng.drain()
    _assert_ok_lanes_bit_identical(d_int8, reqs[:2],
                                   [done[r] for r in rids[:2]])
    s = eng.stats
    assert s.shed == 2 and s.admitted == 2 and s.failed == 0


def test_priority_orders_admission_within_capacity(d_int8):
    """With capacity 2 and 4 queued, the high-priority pair rides the
    first dispatch regardless of arrival order."""
    clk = FakeClock(0.0)
    eng = ShardedServingEngine(d_int8, replicas=1, lanes=2, clock=clk)
    g = d_int8.exec_graph
    low = [eng.submit(random_input(g, seed=1)),
           eng.submit(random_input(g, seed=2))]
    high = [eng.submit(random_input(g, seed=3), priority=5),
            eng.submit(random_input(g, seed=4), priority=5)]
    eng.step()
    for rid in high:
        assert rid in eng._results and not isinstance(
            eng._results[rid], RequestError)
    for rid in low:
        assert rid not in eng._results
    eng.drain()


# ------------------------------------------------------------- degradation
def test_engine_init_failure_degrades_to_single_device(d_int8):
    """An injected replica-mesh init failure falls back to single-device
    serving: a recorded degradation, and outputs still bit-identical."""
    eng = ShardedServingEngine(d_int8, replicas=1, lanes=2,
                               faults=FaultPlan(fail_engine_init=True))
    reqs = _reqs(d_int8.exec_graph, 3, seed0=140)
    outs = eng.serve(reqs)
    _assert_ok_lanes_bit_identical(d_int8, reqs, outs)
    s = eng.stats
    assert s.degraded and any("falling back to single-device" in n
                              for n in s.degraded)


def test_engine_init_failure_strict_raises(d_int8):
    with pytest.raises(DeviceInitError):
        ShardedServingEngine(d_int8, replicas=1, lanes=2,
                             fallback_single_device=False,
                             faults=FaultPlan(fail_engine_init=True))


def test_build_nonstrict_budget_miss_degrades():
    """deploy.build(strict=False) records an impossible budget as a
    degradation note instead of raising; strict raises typed."""
    from repro.errors import BudgetUnreachableError
    g = figure1_int8_graph()
    with pytest.raises(BudgetUnreachableError, match="strict=False"):
        deploy.build(g, arena_budget=1)
    d = deploy.build(g, arena_budget=1, strict=False)
    assert d.degraded and any("arena budget missed" in n
                              for n in d.degraded)
    # degradation propagates into the engine's stats
    eng = ShardedServingEngine(d, replicas=1, lanes=2)
    eng.serve(_reqs(d.exec_graph, 1, seed0=150))
    assert any("arena budget missed" in n for n in eng.stats.degraded)


# --------------------------------------------------- Deployment.run hooks
def test_deployment_run_guard_violation():
    d = deploy.build(_tiny_cnn(), guard_bytes=32)
    x = random_input(d.exec_graph, seed=1)
    with pytest.raises(GuardViolation, match="arena byte"):
        d.run(x, faults=FaultPlan(seed=1, corrupt_rate=1.0))
    # same deployment, faults off: unaffected
    d.run(x)


def test_deployment_run_nan_detection(d_float):
    x = random_input(d_float.exec_graph, seed=2)
    with pytest.raises(NaNActivationError, match="NaN"):
        d_float.run(x, faults=FaultPlan(seed=2, nan_rate=1.0))


def test_deployment_run_retries_then_fails_typed(d_int8):
    x = random_input(d_int8.exec_graph, seed=3)
    # transient errors below the retry budget: answer is bit-identical
    ref = d_int8.run(x)
    out = d_int8.run(x, faults=FaultPlan(seed=8, device_error_rate=0.3))
    for name in d_int8.exec_graph.outputs:
        np.testing.assert_array_equal(ref[name], out[name])
    # persistent errors: typed failure, not a hang
    with pytest.raises(DispatchFailedError):
        d_int8.run(x, faults=FaultPlan(seed=8, device_error_rate=1.0))


# ---------------------------------------------- GraphServingEngine parity
def test_graph_engine_retries_and_guards(d_int8):
    """The micro-batching engine shares the same retry/guard layer."""
    g = d_int8.exec_graph
    reqs = _reqs(g, 6, seed0=160)
    eng = GraphServingEngine(
        deployment=d_int8, micro_batch=2,
        faults=FaultPlan(seed=3, device_error_rate=0.5), max_retries=4)
    outs = eng.serve(reqs)
    for r, o in zip(reqs, outs):
        ref = d_int8.run(r)
        for name in g.outputs:
            np.testing.assert_array_equal(ref[name], o[name])
    assert eng.stats.retried > 0 and eng.stats.admitted == 6

    dg = deploy.build(figure1_int8_graph(), guard_bytes=16)
    eng2 = GraphServingEngine(deployment=dg, micro_batch=2)
    outs2 = eng2.serve(reqs)
    for r, o in zip(reqs, outs2):
        ref = d_int8.run(r)
        for name in g.outputs:
            np.testing.assert_array_equal(ref[name], o[name])
