"""Property tests for the recurrent substrate: the chunked linear-attention
engine must equal the naive sequential recurrence for any chunk size, and
decode steps must continue prefill states exactly."""
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st   # skips @given tests cleanly when hypothesis is absent

from repro.models.ssm import (causal_conv1d, chunked_linear_attention,
                              linear_attention_step, slstm_scan)


def naive_linear_attention(q, k, v, log_decay, in_scale, normalize=False):
    """Sequential reference: state_t = e^ld_t state + s_t k_t v_t^T."""
    B, S, H, N = q.shape
    P = v.shape[-1]
    q, k, v = (np.asarray(a, np.float64) for a in (q, k, v))
    ld = np.asarray(log_decay, np.float64)
    sc = np.asarray(in_scale, np.float64)
    if normalize:
        v = np.concatenate([v, np.ones((B, S, H, 1))], -1)
    state = np.zeros((B, H, N, v.shape[-1]))
    ys = []
    for t in range(S):
        state = state * np.exp(ld[:, t])[..., None, None] \
            + sc[:, t][..., None, None] * (k[:, t][..., :, None]
                                           * v[:, t][..., None, :])
        ys.append(np.einsum("bhn,bhnp->bhp", q[:, t], state))
    y = np.stack(ys, 1)
    if normalize:
        y = y[..., :P] / np.maximum(np.abs(y[..., P:]), 1.0)
    return y, state


@given(st.integers(0, 1000), st.sampled_from([1, 2, 3, 5, 8, 16]),
       st.booleans())
@settings(max_examples=25, deadline=None)
def test_chunked_equals_naive_recurrence(seed, chunk, normalize):
    rng = np.random.default_rng(seed)
    B, S, H, N, P = 2, 13, 3, 4, 5
    q = rng.standard_normal((B, S, H, N)).astype(np.float32)
    k = rng.standard_normal((B, S, H, N)).astype(np.float32)
    v = rng.standard_normal((B, S, H, P)).astype(np.float32)
    ld = -np.abs(rng.standard_normal((B, S, H))).astype(np.float32)
    sc = rng.random((B, S, H)).astype(np.float32)
    y, state = chunked_linear_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(ld),
        jnp.asarray(sc), chunk=chunk, normalize=normalize)
    y_ref, state_ref = naive_linear_attention(q, k, v, ld, sc, normalize)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), state_ref,
                               rtol=2e-4, atol=2e-4)


@given(st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_decode_step_continues_chunked_state(seed):
    rng = np.random.default_rng(seed)
    B, S, H, N, P = 1, 9, 2, 4, 4
    def mk(*sh):
        return jnp.asarray(rng.standard_normal(sh), jnp.float32)
    q, k = mk(B, S + 1, H, N), mk(B, S + 1, H, N)
    v = mk(B, S + 1, H, P)
    ld = -jnp.abs(mk(B, S + 1, H))
    sc = jnp.abs(mk(B, S + 1, H))
    # full sequence in one chunked pass
    y_full, _ = chunked_linear_attention(q, k, v, ld, sc, chunk=4)
    # prefix pass + one decode step
    y_pre, state = chunked_linear_attention(
        q[:, :S], k[:, :S], v[:, :S], ld[:, :S], sc[:, :S], chunk=4)
    y_step, _ = linear_attention_step(
        state, q[:, S], k[:, S], v[:, S], ld[:, S], sc[:, S])
    np.testing.assert_allclose(np.asarray(y_step),
                               np.asarray(y_full[:, S]),
                               rtol=1e-4, atol=1e-4)


def test_causal_conv1d_matches_explicit():
    rng = np.random.default_rng(0)
    B, S, C, W = 2, 10, 3, 4
    x = jnp.asarray(rng.standard_normal((B, S, C)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((W, C)), jnp.float32)
    y, cache = causal_conv1d(x, w)
    xp = np.concatenate([np.zeros((B, W - 1, C)), np.asarray(x)], 1)
    for t in range(S):
        ref = sum(xp[:, t + i] * np.asarray(w)[i] for i in range(W))
        ref = ref / (1 + np.exp(-ref))   # silu
        np.testing.assert_allclose(np.asarray(y[:, t]), ref,
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cache), xp[:, -(W - 1):],
                               rtol=0, atol=0)


def test_causal_conv1d_cache_streaming():
    """conv(x) == conv applied in two halves with the carried cache."""
    rng = np.random.default_rng(1)
    B, S, C, W = 1, 12, 2, 4
    x = jnp.asarray(rng.standard_normal((B, S, C)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((W, C)), jnp.float32)
    y_full, _ = causal_conv1d(x, w)
    y1, c1 = causal_conv1d(x[:, :7], w)
    y2, _ = causal_conv1d(x[:, 7:], w, cache=c1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-6, atol=1e-6)


def test_slstm_stability_long_sequence():
    """Exponential gating with the max-stabiliser must not overflow even
    with large positive input-gate pre-activations."""
    rng = np.random.default_rng(2)
    B, S, H, P = 1, 64, 2, 4
    gates = jnp.asarray(rng.standard_normal((B, S, 4, H, P)) * 8.0,
                        jnp.float32)
    r = jnp.asarray(rng.standard_normal((4, H, P, P)) * 0.2, jnp.float32)
    h, state = slstm_scan(gates, r)
    assert np.isfinite(np.asarray(h)).all()
    assert np.isfinite(np.asarray(state[0])).all()
    assert np.abs(np.asarray(h)).max() <= 1.5   # |o·c/n| bounded
