"""MoE routing invariants: top-k renormalisation, capacity semantics,
correctness of the scatter/gather expert pass against a dense reference."""
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st   # skips @given tests cleanly when hypothesis is absent

from repro.models.moe import _expert_pass, moe_ffn, router_topk


def dense_moe_reference(x, w_router, w_gate, w_up, w_down, k):
    """Every expert on every token, weighted by renormalised top-k probs."""
    B, S, d = x.shape
    E = w_router.shape[-1]
    top_p, top_i, _ = router_topk(x, w_router, k)
    xf = np.asarray(x, np.float64).reshape(-1, d)
    tp = np.asarray(top_p).reshape(-1, k)
    ti = np.asarray(top_i).reshape(-1, k)
    y = np.zeros_like(xf)
    for e in range(E):
        h = xf @ np.asarray(w_gate[e], np.float64)
        h = h / (1 + np.exp(-h)) * (xf @ np.asarray(w_up[e], np.float64))
        out = h @ np.asarray(w_down[e], np.float64)
        gate = np.where(ti == e, tp, 0.0).sum(-1)
        y += out * gate[:, None]
    return y.reshape(B, S, d)


@given(st.integers(0, 500), st.sampled_from([1, 2, 4]))
@settings(max_examples=15, deadline=None)
def test_expert_pass_matches_dense_reference(seed, k):
    rng = np.random.default_rng(seed)
    B, S, d, ff, E = 1, 16, 8, 16, 4
    x = jnp.asarray(rng.standard_normal((B, S, d)) * 0.5, jnp.float32)
    wr = jnp.asarray(rng.standard_normal((d, E)), jnp.float32)
    wg = jnp.asarray(rng.standard_normal((E, d, ff)) * 0.2, jnp.float32)
    wu = jnp.asarray(rng.standard_normal((E, d, ff)) * 0.2, jnp.float32)
    wd = jnp.asarray(rng.standard_normal((E, ff, d)) * 0.2, jnp.float32)
    # ample capacity -> no drops -> must equal the dense reference
    y, _ = moe_ffn(x, wr, wg, wu, wd, k=k, capacity_factor=float(E))
    ref = dense_moe_reference(x, wr, wg, wu, wd, k)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-3, atol=2e-3)


def test_router_topk_renormalised():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)
    wr = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    p, i, aux = router_topk(x, wr, k=2)
    np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, rtol=1e-5)
    assert float(aux) >= 1.0 - 1e-3    # E·Σf·p >= 1 with equality at uniform
    # indices are distinct per token
    assert (np.asarray(i[..., 0]) != np.asarray(i[..., 1])).all()


def test_capacity_drops_tokens_beyond_c():
    """With capacity 1 and all tokens routed to one expert, only the first
    token gets a contribution."""
    d, ff = 4, 8
    T = 6
    x = jnp.ones((T, d), jnp.float32)
    top_p = jnp.ones((T, 1), jnp.float32)
    top_i = jnp.zeros((T, 1), jnp.int32)
    wg = jnp.ones((1, d, ff), jnp.float32)
    wu = jnp.ones((1, d, ff), jnp.float32)
    wd = jnp.ones((1, ff, d), jnp.float32)
    y = _expert_pass(x, top_p, top_i, wg, wu, wd, jnp.int32(0), capacity=1)
    out = np.asarray(y)
    assert np.abs(out[0]).sum() > 0           # first token served
    np.testing.assert_array_equal(out[1:], 0)  # rest dropped


def test_moe_aux_loss_penalises_imbalance():
    rng = np.random.default_rng(1)
    d, E = 16, 4
    x = jnp.asarray(rng.standard_normal((1, 64, d)), jnp.float32)
    wr_uniform = jnp.zeros((d, E), jnp.float32)
    # router that always picks expert 0
    wr_skewed = jnp.zeros((d, E), jnp.float32).at[:, 0].set(5.0)
    _, _, aux_u = router_topk(x, wr_uniform, k=1)
    _, _, aux_s = router_topk(x, wr_skewed, k=1)
    assert float(aux_s) > float(aux_u)
