"""jaxpr-level operator reordering: validity, numerics-invariance, and that
it actually reduces peak liveness on branchy JAX programs."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st   # skips @given tests cleanly when hypothesis is absent

from repro.core.jaxpr_reorder import (peak_liveness,
                                      jaxpr_to_graph, reorder,
                                      reorder_closed_jaxpr)


def branchy_fn(x):
    """Figure-1-shaped JAX program: expensive branch traced first."""
    t1 = jnp.tanh(x)                      # big
    a = (t1 @ t1.T)                       # branch A: big intermediates
    a = jnp.tanh(a)
    a = a.sum(axis=1)
    b = t1.sum(axis=1)                    # branch B: tiny
    return a + b


def test_reorder_reduces_peak_on_branchy_fn():
    x = jnp.ones((128, 128), jnp.float32)
    closed = jax.make_jaxpr(branchy_fn)(x)
    new_closed, rep = reorder_closed_jaxpr(closed)
    assert rep.peak_after <= rep.peak_before
    # verify the rebuilt jaxpr's own liveness matches the report
    assert peak_liveness(new_closed) == rep.peak_after


def test_reorder_numerics_bit_identical():
    x = np.random.default_rng(0).standard_normal((64, 64)).astype(np.float32)
    expected = branchy_fn(jnp.asarray(x))
    got = reorder(branchy_fn)(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(expected), np.asarray(got))


def test_reorder_handles_multi_output_eqns():
    def fn(x):
        a, b = jnp.split(x, 2)
        return jnp.tanh(a).sum() + b.sum()

    x = jnp.ones((32, 8))
    expected = fn(x)
    got = reorder(fn)(x)
    np.testing.assert_allclose(np.asarray(expected), np.asarray(got))


def test_reorder_pytree_outputs():
    def fn(x):
        return {"a": x * 2, "b": (x + 1, x.sum())}

    x = jnp.arange(12.0).reshape(3, 4)
    expected = fn(x)
    got = reorder(fn)(x)
    jax.tree_util.tree_map(
        lambda e, g: np.testing.assert_array_equal(np.asarray(e),
                                                   np.asarray(g)),
        expected, got)


def test_shard_divisor_scales_sizes():
    x = jnp.ones((128, 128), jnp.float32)
    closed = jax.make_jaxpr(branchy_fn)(x)
    p1 = peak_liveness(closed, shard_divisor=1)
    p8 = peak_liveness(closed, shard_divisor=8)
    assert p1 > p8 >= p1 // 8


@given(st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_random_programs_numerics_invariant(seed):
    rng = np.random.default_rng(seed)

    def fn(x):
        vals = [x]
        for k in range(6):
            pick = vals[int(rng.integers(len(vals)))]
            choice = int(rng.integers(4))
            if choice == 0:
                vals.append(jnp.tanh(pick))
            elif choice == 1:
                vals.append(pick * 1.5 + 1.0)
            elif choice == 2:
                other = vals[int(rng.integers(len(vals)))]
                vals.append(pick + other)
            else:
                vals.append(pick.sum(keepdims=True) * jnp.ones_like(pick))
        return sum(v.sum() for v in vals)

    x = jnp.asarray(np.random.default_rng(seed + 1)
                    .standard_normal((16, 16)).astype(np.float32))
    expected = fn(x)           # rng consumed during first trace
    rng = np.random.default_rng(seed)   # reset so retrace is identical
    got = reorder(fn)(x)
    np.testing.assert_allclose(np.asarray(expected), np.asarray(got),
                               rtol=1e-6)


def test_jaxpr_graph_shapes():
    x = jnp.ones((8, 8))
    closed = jax.make_jaxpr(branchy_fn)(x)
    g, idx = jaxpr_to_graph(closed.jaxpr)
    assert len(g.operators) == len(closed.jaxpr.eqns)
    assert g.outputs  # has at least the function output
    # every equation got a distinct index
    assert sorted(idx.values()) == list(range(len(closed.jaxpr.eqns)))
