"""Micro-interpreter simulator: Table-1-style results + numerics invariance
(reordering must not change model outputs — the paper's orthogonality
claim).

The paper's deployments are int8 (TFLite-Micro person detection /
SwiftNet), so these tests run the honest int8 pipeline: the float graphs
are post-training-quantized (``quantize_graph``) and the paper's byte
numbers are asserted against genuinely 1-byte-per-element tensors.
"""
import numpy as np
import pytest

from repro.core import schedule, static_plan_size
from repro.graphs import (figure1_graph, mobilenet_v1_graph, quantize_graph,
                          random_input, swiftnet_cell_graph)
from repro.mcu import MicroInterpreter

SRAM = 512 * 1024          # NUCLEO-F767ZI
FRAMEWORK_OVERHEAD = 200 * 1024   # paper: ≈200KB for SwiftNet Cell

_QCACHE = {}


def _quantized(factory):
    """Quantize once per module run (calibration runs the f32 graph)."""
    if factory not in _QCACHE:
        g = factory()
        _QCACHE[factory] = quantize_graph(g, random_input(g))
    return _QCACHE[factory]


def _q_inputs(qm, seed=0):
    return qm.quantize_inputs(random_input(qm.float_graph, seed=seed))


def test_swiftnet_fits_only_with_optimised_order():
    qm = _quantized(swiftnet_cell_graph)
    g = qm.graph
    default = g.default_schedule()
    opt = schedule(g).schedule
    budget = SRAM - FRAMEWORK_OVERHEAD
    interp = MicroInterpreter(g, capacity=budget)
    x = _q_inputs(qm)
    # default order must NOT fit the remaining SRAM budget ...
    with pytest.raises(MemoryError):
        interp.run(x, schedule=default)
    # ... while the optimised order does — the paper's headline result.
    rep = interp.run(x, schedule=opt)
    assert rep.fits
    assert rep.peak_sram <= budget


def test_reordering_is_output_invariant():
    qm = _quantized(swiftnet_cell_graph)
    g = qm.graph
    x = _q_inputs(qm)
    interp = MicroInterpreter(g)
    a = interp.run(x, schedule=g.default_schedule())
    b = interp.run(x, schedule=schedule(g).schedule)
    for o in g.outputs:
        np.testing.assert_array_equal(a.outputs[o], b.outputs[o])


def test_mobilenet_dynamic_vs_static_alloc():
    """Table 1, MobileNet column: dynamic allocation slashes the footprint
    of a pure-chain model where reordering alone cannot help.  The paper's
    55 KB is an int8 number — and the f32 graph costs exactly 4x."""
    qm = _quantized(mobilenet_v1_graph)
    g = qm.graph
    static = static_plan_size(g)
    rep = MicroInterpreter(g).run(_q_inputs(qm))
    assert rep.peak_sram == 55296            # 54 KB — paper reports 55 KB
    assert static >= 4 * rep.peak_sram       # paper: 241 KB vs 55 KB
    # defrag traffic exists but is bounded (the <1% overhead proxy)
    assert rep.bytes_moved < 40 * static
    # the float model's working sets are exactly 4x everywhere
    f = qm.float_graph
    assert f.peak_usage(f.default_schedule()) == 4 * rep.peak_sram


def test_figure1_interpreter_peaks_match_simulation():
    g = figure1_graph()
    # attach trivial semantics so the interpreter can run this graph
    for op in g.operators:
        if op.kind == "concat":
            op.fn = lambda *xs: np.concatenate([x.ravel() for x in xs])
        else:
            size = g.size(op.output)
            op.fn = (lambda s: lambda *xs: np.zeros(s, np.int8))(size)
    x = {"t0": np.zeros(g.size("t0"), np.int8)}
    rep_d = MicroInterpreter(g).run(x, schedule=g.default_schedule())
    order = [g.op_by_name(n) for n in
             ["op1", "op4", "op6", "op2", "op3", "op5", "op7"]]
    rep_o = MicroInterpreter(g).run(x, schedule=order)
    assert rep_d.peak_sram == 5216
    assert rep_o.peak_sram == 4960
