"""Property tests for the admission queue (serving/admission.py) against a
reference model: priority order within deadlines, expired requests never
admitted, shed counts exact under random interleavings, FIFO at equal
priority (so the default queue is bit-for-bit the old FIFO), and requeue
fairness.  Hypothesis drives the random interleavings when installed; the
fixed-seed fallback tests below cover the same invariants either way.
"""
import random

import pytest

from hypothesis_compat import given, settings, st
from repro.serving import AdmissionQueue, QueuedRequest, RequestError


def _req(rid, priority=0, deadline=None, t=0.0):
    return QueuedRequest(rid, {"x": rid}, t, priority=priority,
                         deadline=deadline)


# ------------------------------------------------------------ plain tests
def test_default_queue_is_exact_fifo():
    """priority=0 everywhere → admission order is submission order, the
    pre-failure-layer deque contract."""
    q = AdmissionQueue()
    for i in range(10):
        assert q.push(_req(i))
    admitted, expired = q.pop_ready(10, now=0.0)
    assert [r.rid for r in admitted] == list(range(10))
    assert expired == [] and q.expired == 0 and q.shed == 0


def test_priority_admits_larger_first_ties_fifo():
    q = AdmissionQueue()
    q.push(_req(0, priority=0))
    q.push(_req(1, priority=5))
    q.push(_req(2, priority=5))
    q.push(_req(3, priority=1))
    admitted, _ = q.pop_ready(4, now=0.0)
    assert [r.rid for r in admitted] == [1, 2, 3, 0]


def test_pop_ready_respects_k_and_leaves_rest_queued():
    q = AdmissionQueue()
    for i in range(5):
        q.push(_req(i))
    admitted, _ = q.pop_ready(2, now=0.0)
    assert [r.rid for r in admitted] == [0, 1]
    assert len(q) == 3
    admitted, _ = q.pop_ready(99, now=0.0)
    assert [r.rid for r in admitted] == [2, 3, 4]
    assert len(q) == 0


def test_expired_never_admitted_and_never_counted_toward_k():
    """A past-deadline request is diverted to the expired side; it must
    not consume an admission slot (the request behind it is admitted)."""
    q = AdmissionQueue()
    q.push(_req(0, deadline=1.0))
    q.push(_req(1))                      # no deadline: never expires
    q.push(_req(2, deadline=99.0))
    admitted, expired = q.pop_ready(2, now=5.0)
    assert [r.rid for r in admitted] == [1, 2]     # k=2 still filled
    assert [r.rid for r in expired] == [0]
    assert q.expired == 1


def test_deadline_boundary_is_inclusive():
    """now == deadline expires: 'by the deadline' means strictly before."""
    q = AdmissionQueue()
    q.push(_req(0, deadline=2.0))
    q.push(_req(1, deadline=2.0 + 1e-9))
    admitted, expired = q.pop_ready(2, now=2.0)
    assert [r.rid for r in admitted] == [1]
    assert [r.rid for r in expired] == [0]


def test_shed_exact_at_max_pending():
    q = AdmissionQueue(max_pending=3)
    assert all(q.push(_req(i)) for i in range(3))
    assert not q.push(_req(3))
    assert not q.push(_req(4))
    assert q.shed == 2 and len(q) == 3
    # draining frees capacity again
    q.pop_ready(2, now=0.0)
    assert q.push(_req(5))
    assert q.shed == 2


def test_max_pending_validated():
    with pytest.raises(ValueError, match="max_pending"):
        AdmissionQueue(max_pending=0)


def test_requeue_bypasses_bound_but_not_fifo_fairness():
    """A retried request re-enters even at max_pending (it was already
    admitted once), but behind same-priority peers — a retry loop must not
    starve fresh requests."""
    q = AdmissionQueue(max_pending=2)
    q.push(_req(0))
    q.push(_req(1))
    retry = _req(9)
    q.requeue(retry)                      # over the bound: still enters
    assert len(q) == 3 and q.shed == 0
    admitted, _ = q.pop_ready(3, now=0.0)
    assert [r.rid for r in admitted] == [0, 1, 9]


def test_request_error_codes_are_machine_checkable():
    e = RequestError(7, "shed", "queue at max_pending=2")
    assert e.rid == 7 and e.code == "shed"
    assert "max_pending" in e.detail


# ------------------------------------------------- fixed-seed model check
def _model_check(events, k, max_pending, now):
    """Run the same event stream through AdmissionQueue and a brute-force
    reference model; compare admitted order, expired set, shed count."""
    q = AdmissionQueue(max_pending=max_pending)
    model = []                            # list of (priority, seq, req)
    model_shed = 0
    seq = 0
    for rid, (priority, deadline) in enumerate(events):
        req = _req(rid, priority=priority, deadline=deadline)
        if max_pending is not None and len(model) >= max_pending:
            model_shed += 1
            assert not q.push(req)
        else:
            model.append((-priority, seq, req))
            seq += 1
            assert q.push(req)
    admitted, expired = q.pop_ready(k, now)
    # reference: sort by (priority desc, arrival), then walk, diverting
    # expired without consuming admission slots; popping stops entirely
    # once k are admitted (deeper expired entries stay queued for the
    # next pop_ready — matching the engine's per-step semantics)
    model.sort()
    want_admitted, want_expired = [], []
    for _, _, req in model:
        if len(want_admitted) >= k:
            break
        if req.deadline is not None and now >= req.deadline:
            want_expired.append(req.rid)
        else:
            want_admitted.append(req.rid)
    assert [r.rid for r in admitted] == want_admitted
    assert [r.rid for r in expired] == want_expired
    assert q.shed == model_shed
    assert q.expired == len(want_expired)


def test_model_check_fixed_seeds():
    """Deterministic sweep of random interleavings — runs even without
    hypothesis installed."""
    for seed in range(20):
        rng = random.Random(seed)
        events = [(rng.randrange(4),
                   rng.choice([None, rng.uniform(0.0, 10.0)]))
                  for _ in range(rng.randrange(1, 25))]
        _model_check(events,
                     k=rng.randrange(1, 12),
                     max_pending=rng.choice([None, 1, 3, 8]),
                     now=rng.uniform(0.0, 10.0))


# -------------------------------------------------------- property tests
@settings(max_examples=200, deadline=None)
@given(
    events=st.lists(st.tuples(st.integers(min_value=-3, max_value=3),
                              st.one_of(st.none(),
                                        st.floats(min_value=0.0,
                                                  max_value=10.0))),
                    min_size=0, max_size=40),
    k=st.integers(min_value=1, max_value=16),
    max_pending=st.one_of(st.none(), st.integers(min_value=1, max_value=10)),
    now=st.floats(min_value=0.0, max_value=10.0),
)
def test_property_queue_matches_model(events, k, max_pending, now):
    _model_check(events, k, max_pending, now)


@settings(max_examples=100, deadline=None)
@given(priorities=st.lists(st.integers(min_value=-5, max_value=5),
                           min_size=1, max_size=30))
def test_property_fifo_within_priority(priorities):
    """Within one priority class admission is strictly submission order,
    whatever the surrounding classes do."""
    q = AdmissionQueue()
    for rid, p in enumerate(priorities):
        q.push(_req(rid, priority=p))
    admitted, _ = q.pop_ready(len(priorities), now=0.0)
    for p in set(priorities):
        rids = [r.rid for r in admitted if r.priority == p]
        assert rids == sorted(rids)


@settings(max_examples=100, deadline=None)
@given(deadlines=st.lists(st.floats(min_value=0.0, max_value=10.0),
                          min_size=1, max_size=30),
       now=st.floats(min_value=0.0, max_value=10.0))
def test_property_expired_never_executed(deadlines, now):
    """No request whose deadline has passed is ever on the admitted side,
    and every queued request is accounted for exactly once."""
    q = AdmissionQueue()
    for rid, dl in enumerate(deadlines):
        q.push(_req(rid, deadline=dl))
    admitted, expired = q.pop_ready(len(deadlines), now)
    assert all(r.deadline > now for r in admitted)
    assert all(now >= r.deadline for r in expired)
    assert len(admitted) + len(expired) == len(deadlines)
    assert q.expired == len(expired)
