"""Partial-execution (Pex) subsystem: slicing correctness, the memory model,
and scheduler/jaxpr integration.

Property tests use plain ``random`` (not hypothesis) so they always run in
tier 1: (a) a partitioned graph evaluates bit-identically to the original
through the micro-interpreter, (b) the arena planner validates sliced
schedules, (c) partitioning never loses to reorder-only scheduling.
"""
import random

import numpy as np
import pytest

from repro.core import (ArenaPlanner, Graph, partition_graph, schedule,
                        sliceable_runs)
from repro.graphs import figure1_graph, mobilenet_v1_graph
from repro.graphs.cnn_ops import CNNBuilder
from repro.graphs.figure1 import DEFAULT_PEAK, OPTIMAL_PEAK
from repro.mcu import MicroInterpreter


def random_cnn_graph(seed: int, h: int = 24, w: int = 24) -> Graph:
    """A random CNN-shaped DAG: sliceable chains (conv/dwconv/maxpool/add)
    interleaved with branch+concat joins and non-sliceable ops."""
    rng = random.Random(seed)
    g = Graph()
    b = CNNBuilder(g)
    x = b.input("input", h, w, rng.choice([3, 4]))
    x = b.conv(x, rng.choice([4, 8]), k=3)

    def chain(t, n):
        for _ in range(n):
            r = rng.random()
            if r < 0.35:
                # MobileNet-style expand→filter→project: fat interior
                t = b.conv(t, rng.choice([16, 24, 32]), k=1)
                t = b.dwconv(t, k=3)
                t = b.conv(t, rng.choice([4, 8]), k=1)
            elif r < 0.6:
                t = b.conv(t, rng.choice([4, 8, 16]), k=rng.choice([1, 3]))
            elif r < 0.85:
                t = b.dwconv(t, k=3)
            else:
                cout = b.shapes[t][2]
                t = b.add(t, b.conv(t, cout, k=1)) \
                    if rng.random() < 0.5 else b.conv(t, cout, k=3)
        return t

    for _ in range(rng.randint(1, 3)):
        if rng.random() < 0.5:
            stem = b.conv(x, rng.choice([8, 16]), k=1)
            a = chain(stem, rng.randint(1, 3))
            c = b.dwconv(stem, k=3)
            x = b.concat([a, c])
        else:
            x = chain(x, rng.randint(1, 4))
        if rng.random() < 0.4:
            x = b.maxpool(x, k=2, stride=2)
    x = b.avgpool(x)
    x = b.fc(x, 4)
    g.set_outputs([x])
    return g


def _inputs(g, seed=0):
    h, w, c = g.tensors["input"].shape
    rng = np.random.default_rng(seed)
    return {"input": rng.standard_normal((h, w, c)).astype(np.float32)}


# ------------------------------------------------------------ core validation
def test_figure1_paper_constants_stay_valid():
    # (kept in the fast tier even when hypothesis is unavailable and the
    # property-test modules skip)
    g = figure1_graph()
    assert g.peak_usage(g.default_schedule()) == DEFAULT_PEAK == 5216
    assert schedule(g).peak == OPTIMAL_PEAK == 4960


def test_ineligible_graph_returned_unchanged():
    g = figure1_graph()           # no shapes, no slice specs
    pr = partition_graph(g)
    assert pr.graph is g and not pr.segments
    res = schedule(g, partition=True)
    assert res.graph is None      # no rewrite happened


def test_sliceable_runs_classification():
    g = mobilenet_v1_graph()      # pure chain of conv/dwconv + avgpool + fc
    runs = sliceable_runs(g)
    assert runs, "mobilenet must expose sliceable runs"
    kinds = {op.kind for run in runs for op in run}
    assert kinds <= {"conv", "dwconv", "maxpool", "add"}
    # the global avgpool and fc must never be inside a run
    assert all(op.kind not in ("avgpool", "fc", "concat")
               for run in runs for op in run)


# -------------------------------------------------------------- property (a)
def test_partitioned_graph_bit_identical_on_random_dags():
    partitioned = 0
    for seed in range(6):
        g = random_cnn_graph(seed)
        # small K set: fewer clone shapes to compile, same properties
        res = schedule(g, partition=True,
                       partition_opts={"k_choices": (2, 4)})
        if res.graph is None:
            continue
        partitioned += 1
        x = _inputs(g, seed)
        ref = MicroInterpreter(g).run(x)
        got = MicroInterpreter(res.graph).run(x, schedule=res.schedule)
        for o in g.outputs:
            np.testing.assert_array_equal(ref.outputs[o], got.outputs[o])
        # the simulator's dynamic-allocator peak must agree with the
        # liveness model on the sliced schedule (inplace concat included)
        assert got.peak_sram == res.graph.peak_usage(res.schedule)
    assert partitioned >= 2, "generator produced too few partitionable DAGs"


# -------------------------------------------------------------- property (b)
def test_arena_planner_validates_sliced_schedules():
    for seed in range(5):
        g = random_cnn_graph(seed)
        res = schedule(g, partition=True)
        gp = res.graph if res.graph is not None else g
        plan = ArenaPlanner.plan(gp, res.schedule)
        ArenaPlanner.validate(plan)
        assert plan.arena_size >= gp.peak_usage(res.schedule) \
            or plan.arena_size == gp.peak_usage(res.schedule)
        if res.graph is not None:
            # the inplace concat chain must share one buffer
            shared = [p for p in plan.placements if p.alias is not None]
            assert shared
            by_alias = {}
            for p in shared:
                by_alias.setdefault(p.alias, set()).add(p.offset)
            assert all(len(offs) == 1 for offs in by_alias.values())


# -------------------------------------------------------------- property (c)
def test_partitioned_peak_never_worse_than_reorder_only():
    for seed in range(8):
        g = random_cnn_graph(seed)
        base = schedule(g)
        res = schedule(g, partition=True)
        assert res.peak <= base.peak


def test_partition_strictly_beats_reorder_on_chain_model():
    # MobileNet is a pure chain: reordering cannot help at all, partial
    # execution can (the Pex claim).
    g = mobilenet_v1_graph()                       # 0.25x @ 96
    base = schedule(g)
    res = schedule(g, partition=True)
    assert res.graph is not None and res.peak < base.peak
    plan = ArenaPlanner.plan(res.graph, res.schedule)
    ArenaPlanner.validate(plan)
    assert plan.arena_size <= base.peak


@pytest.mark.slow
def test_partition_bit_identical_on_mobilenet():
    g = mobilenet_v1_graph()
    res = schedule(g, partition=True)
    x = _inputs(g)
    ref = MicroInterpreter(g).run(x)
    got = MicroInterpreter(res.graph).run(x, schedule=res.schedule)
    for o in g.outputs:
        np.testing.assert_array_equal(ref.outputs[o], got.outputs[o])


def test_budget_mode_only_partitions_when_needed():
    g = mobilenet_v1_graph()
    base = schedule(g)
    # generous budget: reordering alone suffices, graph untouched
    assert schedule(g, arena_budget=base.peak).graph is None
    # tight budget: partitioning must kick in and meet it
    tight = int(base.peak * 0.9)
    res = schedule(g, arena_budget=tight)
    assert res.graph is not None and res.peak <= tight


# ------------------------------------------------------------------ jaxpr pex
def test_jaxpr_partial_execution_mlp():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from jax._src.core import eval_jaxpr
    from repro.core.jaxpr_partial import partial_execute_closed_jaxpr
    from repro.core.jaxpr_reorder import peak_liveness

    rng = np.random.default_rng(0)
    w1 = rng.standard_normal((32, 512)).astype(np.float32)
    w2 = rng.standard_normal((512, 32)).astype(np.float32)

    def mlp(x):
        return jnp.tanh(x @ w1) @ w2       # fat (256, 512) interior

    x = jnp.asarray(rng.standard_normal((256, 32)).astype(np.float32))
    closed = jax.make_jaxpr(mlp)(x)
    pc, n_runs = partial_execute_closed_jaxpr(closed)
    assert n_runs == 1
    assert peak_liveness(pc) < peak_liveness(closed)
    ref = np.asarray(eval_jaxpr(closed.jaxpr, closed.consts, x)[0])
    got = np.asarray(eval_jaxpr(pc.jaxpr, pc.consts, x)[0])
    # sliced dot_general: float-tolerance equivalence (GEMM kernel selection
    # depends on the row count; see jaxpr_partial docstring)
    np.testing.assert_allclose(got, ref, rtol=2e-6, atol=1e-6)


def test_jaxpr_elementwise_slicing_bit_identical():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from jax._src.core import eval_jaxpr
    from repro.core.jaxpr_partial import _expand_run

    def f(x):
        return jnp.exp(jnp.tanh(x))

    x = jnp.asarray(np.random.default_rng(1)
                    .standard_normal((64, 16)).astype(np.float32))
    closed = jax.make_jaxpr(f)(x)
    jaxpr = closed.jaxpr
    new_eqns = _expand_run(list(jaxpr.eqns), 4)
    new_jaxpr = jaxpr.replace(eqns=new_eqns)
    ref = np.asarray(eval_jaxpr(jaxpr, closed.consts, x)[0])
    got = np.asarray(eval_jaxpr(new_jaxpr, closed.consts, x)[0])
    np.testing.assert_array_equal(ref, got)


def test_jaxpr_reorder_with_partition_budget():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from jax._src.core import eval_jaxpr
    from repro.core.jaxpr_reorder import reorder_closed_jaxpr

    rng = np.random.default_rng(2)
    w1 = rng.standard_normal((32, 512)).astype(np.float32)
    w2 = rng.standard_normal((512, 32)).astype(np.float32)

    def mlp(x):
        return jnp.tanh(x @ w1) @ w2

    x = jnp.asarray(rng.standard_normal((256, 32)).astype(np.float32))
    closed = jax.make_jaxpr(mlp)(x)
    _, base = reorder_closed_jaxpr(closed)
    budget = base.peak_after // 2
    nc, rep = reorder_closed_jaxpr(closed, partition_budget=budget)
    assert rep.method.endswith("+pex") and rep.peak_after < base.peak_after
    ref = np.asarray(eval_jaxpr(closed.jaxpr, closed.consts, x)[0])
    got = np.asarray(eval_jaxpr(nc.jaxpr, nc.consts, x)[0])
    np.testing.assert_allclose(got, ref, rtol=2e-6, atol=1e-6)
    # without a budget the behaviour is unchanged
    _, plain = reorder_closed_jaxpr(closed)
    assert plain.peak_after == base.peak_after
