"""Validation of the paper's Algorithm 1 against its own published numbers
(Figure 1 / Appendix A), plus property tests over random DAGs."""
import random

from hypothesis_compat import given, settings, st   # skips @given tests cleanly when hypothesis is absent

from repro.core import (Graph, beam_schedule, greedy_schedule,
                        minimise_peak_memory, minimise_peak_memory_contracted,
                        schedule)
from repro.graphs.figure1 import (DEFAULT_PEAK, OPTIMAL_PEAK,
                                  figure1_graph)


# --------------------------------------------------------------- Figure 1 / 2
def test_figure1_default_order_matches_paper_figure2():
    g = figure1_graph()
    sched = g.default_schedule()
    # Appendix A, Figure 2 per-row working sets
    expected_sets = [{"t0", "t1"}, {"t1", "t2"}, {"t1", "t2", "t3"},
                     {"t1", "t3", "t4"}, {"t3", "t4", "t5"},
                     {"t4", "t5", "t6"}, {"t5", "t6", "t7"}]
    expected_usage = [4704, 4704, 5216, 4160, 1280, 1024, 1024]
    sets = g.live_sets(sched)
    assert [set(s) for s in sets] == expected_sets
    assert g.usage_profile(sched) == expected_usage
    assert g.peak_usage(sched) == DEFAULT_PEAK == 5216


def test_figure1_optimal_order_matches_paper_figure3():
    g = figure1_graph()
    order = ["op1", "op4", "op6", "op2", "op3", "op5", "op7"]
    sched = [g.op_by_name(n) for n in order]
    assert g.is_valid_schedule(sched)
    expected_sets = [{"t0", "t1"}, {"t1", "t4"}, {"t1", "t4", "t6"},
                     {"t1", "t2", "t6"}, {"t2", "t3", "t6"},
                     {"t3", "t5", "t6"}, {"t5", "t6", "t7"}]
    expected_usage = [4704, 3648, 3904, 4960, 2336, 1024, 1024]
    assert [set(s) for s in g.live_sets(sched)] == expected_sets
    assert g.usage_profile(sched) == expected_usage
    assert g.peak_usage(sched) == OPTIMAL_PEAK == 4960


def test_algorithm1_finds_paper_optimum():
    g = figure1_graph()
    res = minimise_peak_memory(g)
    assert res.peak == OPTIMAL_PEAK
    assert g.is_valid_schedule(res.schedule)
    assert g.peak_usage(res.schedule) == OPTIMAL_PEAK


def test_contracted_dp_matches_exact_on_figure1():
    g = figure1_graph()
    res = minimise_peak_memory_contracted(g)
    assert res is not None
    assert res.peak == OPTIMAL_PEAK


def test_schedule_api_on_figure1():
    g = figure1_graph()
    res = schedule(g)
    assert res.peak == OPTIMAL_PEAK


# ----------------------------------------------------------------- generators
def random_dag(seed: int, n_ops: int) -> Graph:
    rng = random.Random(seed)
    g = Graph()
    g.add_tensor("in", rng.randint(1, 100) * 16)
    produced = ["in"]
    for k in range(n_ops):
        out = f"a{k}"
        g.add_tensor(out, rng.randint(1, 100) * 16)
        n_in = rng.randint(1, min(2, len(produced)))
        ins = rng.sample(produced, n_in)
        g.add_operator(f"op{k}", ins, out)
        produced.append(out)
    # outputs: every tensor with no consumer
    sinks = [t for t in g.tensors
             if not g.consumers(t) and g.producer(t) is not None]
    g.set_outputs(sinks or [produced[-1]])
    return g


@given(st.integers(0, 10_000), st.integers(1, 9))
@settings(max_examples=60, deadline=None)
def test_exact_is_lower_bound_over_random_topo_orders(seed, n_ops):
    g = random_dag(seed, n_ops)
    res = minimise_peak_memory(g)
    assert g.is_valid_schedule(res.schedule)
    assert g.peak_usage(res.schedule) == res.peak
    # exact optimum <= any sampled topological order (incl. insertion order)
    assert res.peak <= g.peak_usage(g.default_schedule())
    rng = random.Random(seed + 1)
    for _ in range(10):
        order = topo_sample(g, rng)
        assert g.is_valid_schedule(order)
        assert res.peak <= g.peak_usage(order)


def topo_sample(g: Graph, rng: random.Random):
    pending = list(g.operators)
    produced = set()
    out = []
    while pending:
        ready = [op for op in pending
                 if all(i in produced or g.producer(i) is None
                        for i in op.inputs)]
        op = rng.choice(ready)
        pending.remove(op)
        produced.add(op.output)
        out.append(op)
    return out


@given(st.integers(0, 10_000), st.integers(1, 10))
@settings(max_examples=60, deadline=None)
def test_heuristics_valid_and_bounded_by_exact(seed, n_ops):
    g = random_dag(seed, n_ops)
    exact = minimise_peak_memory(g)
    for r in (greedy_schedule(g), beam_schedule(g, width=16)):
        assert g.is_valid_schedule(r.schedule)
        assert g.peak_usage(r.schedule) == r.peak
        assert r.peak >= exact.peak


@given(st.integers(0, 10_000), st.integers(1, 12))
@settings(max_examples=40, deadline=None)
def test_contracted_dp_valid_and_bounded(seed, n_ops):
    """Chain contraction restricts chains to run contiguously, so it is an
    upper bound on the true optimum (and equals it on most graphs)."""
    g = random_dag(seed, n_ops)
    exact = minimise_peak_memory(g)
    contracted = minimise_peak_memory_contracted(g)
    assert contracted is not None
    assert g.is_valid_schedule(contracted.schedule)
    assert g.peak_usage(contracted.schedule) == contracted.peak
    assert contracted.peak >= exact.peak


@given(st.integers(0, 10_000), st.integers(1, 14))
@settings(max_examples=40, deadline=None)
def test_schedule_api_never_worse_than_embedded_order(seed, n_ops):
    g = random_dag(seed, n_ops)
    res = schedule(g)
    assert g.is_valid_schedule(res.schedule)
    assert res.peak <= g.peak_usage(g.default_schedule())


@given(st.integers(0, 10_000), st.integers(1, 10))
@settings(max_examples=30, deadline=None)
def test_branch_and_bound_preserves_optimum(seed, n_ops):
    g = random_dag(seed, n_ops)
    exact = minimise_peak_memory(g)
    ub = greedy_schedule(g).peak
    bounded = minimise_peak_memory(g, upper_bound=ub + 1)
    assert bounded.peak == exact.peak


def test_beam_finds_optimum_on_figure1():
    g = figure1_graph()
    assert beam_schedule(g, width=64).peak == OPTIMAL_PEAK


def test_inplace_accumulation_paper_s6_extension():
    """Paper §6: 'if one of the inputs to the addition operator is not used
    elsewhere, the result can be accumulated into it, eliminating the need
    for an output buffer.'"""
    def build(inplace):
        g = Graph()
        for n, sz in (("x", 10), ("a", 100), ("b", 100), ("y", 100)):
            g.add_tensor(n, sz)
        g.add_operator("opA", ["x"], "a")
        g.add_operator("opB", ["x"], "b")
        g.add_operator("add", ["a", "b"], "y",
                       **({"inplace": True} if inplace else {}))
        g.set_outputs(["y"])
        return g

    plain = build(False)
    acc = build(True)
    sched = plain.default_schedule()
    # peak at `add`: {a, b, y} = 300 without the trick; with accumulation
    # the output reuses a dying input, so the peak moves to opB (x,a,b=210)
    assert plain.peak_usage(sched) == 300
    assert acc.peak_usage(acc.default_schedule()) == 210
    # the optimum also benefits
    assert minimise_peak_memory(acc).peak <= minimise_peak_memory(plain).peak
