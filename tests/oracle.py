"""Brute-force oracles for the joint scheduler (``core/solver.py``).

Chain of trust, pinned by ``test_solver_oracle.py``:

1. ``enumerate_min_peak`` — the literal brute force: every topological
   order (DFS enumeration — identical to filtering all permutations by
   ``is_valid_schedule``, without generating invalid ones), each priced by
   ``Graph.peak_usage`` (the ground-truth memory model, no shared code with
   the solver's incremental simulator).
2. ``dp_min_peak`` — an independent subset DP (min over orders of the max
   step cost) that re-derives the ``live_sets`` step-cost rule from
   scratch; cross-checked against (1) on small graphs, then used where the
   order count explodes (partitioned rewrites are mostly chains, but k
   slices of j ops interleave).
3. ``oracle_joint_points`` — the exhaustive (order × Pex split) space:
   the unsplit graph plus *every* contiguous sub-run of every sliceable
   run split into *every* feasible K, each solved by (1)/(2).  The MACs
   axis reuses the solver's cost model (``segment_extra_macs``) by design:
   the oracle verifies peak-optimality and non-domination *given* that
   cost model, not the cost model itself.
4. ``oracle_front`` — non-dominated points by a quadratic all-pairs
   domination check (independent of the solver's sort-and-sweep).

Also hosts the deterministic random-graph builders shared by the oracle
and property suites, so fixed-seed fallbacks run without hypothesis.
"""
from __future__ import annotations

import random
from typing import Dict, FrozenSet, Iterator, List, Optional, Sequence, Tuple

from repro.core.allocator import ArenaPlanner
from repro.core.graph import Graph, Operator, inplace_candidates
from repro.core.partition import (PEX_ATTR, Cascade, Segment, SliceSpec,
                                  _strips_eligible, apply_cascade,
                                  apply_partition, estimate_cascade,
                                  estimate_segment, same_pads,
                                  sliceable_runs)
from repro.core.solver import segment_extra_macs


class OracleBlowup(RuntimeError):
    """The enumeration cap was hit — the graph is too big for this oracle."""


# ------------------------------------------------------- order enumeration
def topo_orders(graph: Graph) -> Iterator[List[Operator]]:
    """Yield every topological order of ``graph`` exactly once."""
    ops = graph.operators
    n = len(ops)
    used: List[bool] = [False] * n
    produced: set = set()
    order: List[int] = []

    def rec() -> Iterator[List[Operator]]:
        if len(order) == n:
            yield [ops[k] for k in order]
            return
        for k, op in enumerate(ops):
            if used[k]:
                continue
            if not all(i in produced or graph.producer(i) is None
                       for i in op.inputs):
                continue
            used[k] = True
            produced.add(op.output)
            order.append(k)
            yield from rec()
            order.pop()
            produced.discard(op.output)
            used[k] = False

    return rec()


def enumerate_min_peak(graph: Graph, cap: int = 300_000) -> Tuple[int, int]:
    """(optimal peak, number of topological orders) by full enumeration,
    each order priced with the ground-truth ``Graph.peak_usage``."""
    best: Optional[int] = None
    count = 0
    for sched in topo_orders(graph):
        count += 1
        if count > cap:
            raise OracleBlowup(f"more than {cap} topological orders")
        p = graph.peak_usage(sched)
        if best is None or p < best:
            best = p
    assert best is not None, "graph has no operators"
    return best, count


# --------------------------------------------------------------- subset DP
def _mem_state(graph: Graph, done: FrozenSet[int]) -> Tuple[set, Dict[str, int], int]:
    """(produced tensors, remaining uses, live bytes) after executing
    exactly the ops of ``done`` — rebuilt from scratch (no incremental
    bookkeeping to share bugs with)."""
    uses: Dict[str, int] = {}
    for op in graph.operators:
        for i in op.inputs:
            uses[i] = uses.get(i, 0) + 1
    for o in graph.outputs:
        uses[o] = uses.get(o, 0) + 1
    produced: set = set()
    for k in done:
        op = graph.operators[k]
        produced.add(op.output)
        for i in op.inputs:
            uses[i] -= 1
    live_bytes = 0
    for t in graph.tensors:
        if uses.get(t, 0) > 0 and (t in produced
                                   or graph.producer(t) is None):
            live_bytes += graph.size(t)
    return produced, uses, live_bytes


def _step_cost(graph: Graph, produced: set, uses: Dict[str, int],
               live_bytes: int, op: Operator) -> int:
    """Cost of executing ``op`` next, re-derived from the ``live_sets``
    rule: live bytes plus the output buffer, unless an ``inplace`` op may
    overwrite a producible input that dies at this very step."""
    if op.attrs.get("inplace"):
        out_b = graph.size(op.output)
        for i in inplace_candidates(op):
            if (graph.producer(i) is not None and graph.size(i) == out_b
                    and uses.get(i, 0) - op.inputs.count(i) == 0):
                return live_bytes
    return live_bytes + graph.size(op.output)


def dp_min_peak(graph: Graph, max_states: int = 500_000) -> int:
    """Optimal peak by memoized DP over done-sets:
    ``g(done) = min over ready op of max(step_cost, g(done + op))``."""
    ops = graph.operators
    n = len(ops)
    memo: Dict[FrozenSet[int], int] = {}
    full = frozenset(range(n))

    def g(done: FrozenSet[int]) -> int:
        if done == full:
            return 0
        hit = memo.get(done)
        if hit is not None:
            return hit
        if len(memo) > max_states:
            raise OracleBlowup(f"more than {max_states} DP states")
        produced, uses, live_bytes = _mem_state(graph, done)
        best: Optional[int] = None
        for k, op in enumerate(ops):
            if k in done:
                continue
            if not all(i in produced or graph.producer(i) is None
                       for i in op.inputs):
                continue
            step = _step_cost(graph, produced, uses, live_bytes, op)
            sub = max(step, g(done | {k}))
            if best is None or sub < best:
                best = sub
        assert best is not None, "graph has a cycle"
        memo[done] = best
        return best

    return g(frozenset())


# --------------------------------------------------- joint (order × split)
def oracle_joint_points(graph: Graph, max_k: int = 16,
                        k_choices: Optional[Sequence[int]] = None,
                        order_cap: int = 100_000
                        ) -> List[Tuple[str, int, int]]:
    """Exhaustive (label, optimal peak, extra MACs) over the joint space:
    the unsplit graph, plus every contiguous sliceable sub-run split into
    every feasible K.  Mirrors the solver's search space definition but
    enumerates it independently (its own i/j/k loops)."""

    def best_order_peak(g: Graph) -> int:
        try:
            return enumerate_min_peak(g, cap=order_cap)[0]
        except OracleBlowup:
            return dp_min_peak(g)

    points = [("base", best_order_peak(graph), 0)]
    for run in sliceable_runs(graph):
        for i in range(len(run)):
            for j in range(i + 1, len(run)):
                ops = run[i:j + 1]
                h = int(graph.tensors[ops[-1].output].shape[0])
                cap_k = min(max_k, h)
                ks = (sorted(set(k_choices)) if k_choices is not None
                      else range(2, cap_k + 1))
                for k in ks:
                    if not 2 <= k <= cap_k:
                        continue
                    est, frac = estimate_segment(graph, ops, k)
                    rg = apply_partition(
                        graph, [Segment(list(ops), k, est, frac)])
                    extra = segment_extra_macs(graph, ops, k)
                    points.append((f"pex[{ops[0].name}..{ops[-1].name}/k{k}]",
                                   best_order_peak(rg), extra))
    return points


def oracle_front(points: Sequence[Tuple[str, int, int]]
                 ) -> List[Tuple[int, int]]:
    """Non-dominated (extra MACs, peak) pairs by all-pairs domination —
    independent of the solver's sort-and-sweep."""
    front = set()
    for _, peak, extra in points:
        dominated = any(
            p2 <= peak and e2 <= extra and (p2 < peak or e2 < extra)
            for _, p2, e2 in points)
        if not dominated:
            front.add((extra, peak))
    return sorted(front)


# --------------------------------------------------- random graph builders
def build_dag(n_inputs: int, sizes: Sequence[int],
              wiring: Sequence[Sequence[int]],
              inplace_every: int = 0) -> Graph:
    """Deterministic DAG from drawn data (the property-suite shape):
    ``wiring[i]`` picks operator i's inputs (indices into the tensors
    created so far, modulo-folded).  With ``inplace_every`` = m > 0, every
    m-th operator is marked ``inplace`` and its output sized to match its
    first input — exercising the aliasing rule of the memory model."""
    g = Graph()
    tensors: List[str] = []
    for i in range(n_inputs):
        g.add_tensor(f"c{i}", sizes[i % len(sizes)])
        tensors.append(f"c{i}")
    for i, picks in enumerate(wiring):
        ins = sorted({tensors[p % len(tensors)] for p in picks})
        out = f"t{i}"
        attrs = {}
        size = sizes[(n_inputs + i) % len(sizes)]
        if inplace_every and (i + 1) % inplace_every == 0:
            size = g.size(ins[0])
            attrs["inplace"] = True
        g.add_tensor(out, size)
        g.add_operator(f"op{i}", ins, out, **attrs)
        tensors.append(out)
    sinks = [t for t in g.tensors
             if not g.consumers(t) and g.producer(t) is not None]
    g.set_outputs(sinks or [tensors[-1]])
    return g


def random_dag(seed: int, max_ops: int = 8, inplace_every: int = 0) -> Graph:
    """Fixed-seed companion of the hypothesis ``dags()`` strategy."""
    rng = random.Random(seed)
    n_inputs = rng.randint(1, 2)
    n_ops = rng.randint(2, max_ops)
    sizes = [rng.randint(1, 64) for _ in range(rng.randint(3, 6))]
    wiring = [[rng.randint(0, 9) for _ in range(rng.randint(1, 2))]
              for _ in range(n_ops)]
    return build_dag(n_inputs, sizes, wiring, inplace_every)


def sliceable_chain_graph(heights: Sequence[int], row_bytes: Sequence[int],
                          kernels: Sequence[int],
                          held_bytes: int = 0) -> Graph:
    """A scheduling-only sliceable chain: op i maps ``heights[i] ->
    heights[i+1]`` rows (stride 1 SAME, so heights must be constant) with
    ``kernels[i]``-row windows; tensor i holds ``row_bytes[i]`` per row.
    ``held_bytes`` adds a side branch (in -> aux, consumed by the final
    join) so reordering interacts with the split choice."""
    n = len(kernels)
    assert len(heights) == n + 1 and len(row_bytes) == n + 1
    assert len(set(heights)) == 1, "stride-1 SAME keeps the height"
    g = Graph()
    g.add_tensor("in", heights[0] * row_bytes[0], shape=(heights[0],))
    prev = "in"
    for i, k in enumerate(kernels):
        out = f"t{i}"
        g.add_tensor(out, heights[i + 1] * row_bytes[i + 1],
                     shape=(heights[i + 1],))
        op = g.add_operator(f"op{i}", [prev], out)
        op.attrs[PEX_ATTR] = SliceSpec(kernel=k, stride=1,
                                       sliced_inputs=(0,),
                                       macs_per_row=row_bytes[i + 1])
        prev = out
    if held_bytes:
        g.add_tensor("aux", held_bytes)
        g.add_operator("aux_op", ["in"], "aux")
        g.add_tensor("join", g.size(prev) + held_bytes)
        g.add_operator("join_op", [prev, "aux"], "join")
        prev = "join"
    g.set_outputs([prev])
    return g


# ------------------------------------------------- 2-D tiled-chain oracle
def tiled_chain_graph(h: int, w: int, chan_bytes: Sequence[int],
                      kernels: Sequence[int], strides: Sequence[int],
                      kernels_w: Sequence[int], strides_w: Sequence[int]
                      ) -> Graph:
    """A scheduling-only 2-D chain: op i maps its ``(h_i, w_i)`` input to
    the SAME-padded output of a per-axis ``(kernel, stride)`` /
    ``(kernel_w, stride_w)`` window; tensor i holds ``chan_bytes[i]`` per
    spatial element.  The shapes carry both axes so the W-strip planner
    (``_strips_eligible`` / ``_backprop_cols``) sees a real width map."""
    n = len(kernels)
    assert (len(strides) == len(kernels_w) == len(strides_w) == n
            and len(chan_bytes) == n + 1)
    hs, ws = [h], [w]
    for i in range(n):
        hs.append(same_pads(hs[-1], kernels[i], strides[i])[0])
        ws.append(same_pads(ws[-1], kernels_w[i], strides_w[i])[0])
    g = Graph()
    g.add_tensor("in", h * w * chan_bytes[0], shape=(h, w))
    prev = "in"
    for i in range(n):
        out = f"t{i}"
        g.add_tensor(out, hs[i + 1] * ws[i + 1] * chan_bytes[i + 1],
                     shape=(hs[i + 1], ws[i + 1]))
        op = g.add_operator(f"op{i}", [prev], out)
        op.attrs[PEX_ATTR] = SliceSpec(
            kernel=kernels[i], stride=strides[i], sliced_inputs=(0,),
            macs_per_row=ws[i + 1] * chan_bytes[i + 1],
            kernel_w=kernels_w[i], stride_w=strides_w[i])
        prev = out
    g.set_outputs([prev])
    return g


def random_tiled_chain(seed: int, max_len: int = 4
                       ) -> Tuple[Graph, Tuple[int, ...]]:
    """Fixed-seed random tiled chain plus a valid cut set for it.  Strides
    are clamped to 1 whenever another halving would push that axis below 3
    rows/cols — every drawn graph stays cascade-eligible (final height and
    width >= 2) without rejection sampling."""
    rng = random.Random(seed)
    n = rng.randint(2, max_len)
    h = rng.choice([8, 9, 12])
    w = rng.choice([8, 10, 12])
    chan = [rng.choice([1, 2, 4, 8]) for _ in range(n + 1)]
    kernels, strides, kernels_w, strides_w = [], [], [], []
    hh, ww = h, w
    for _ in range(n):
        k = rng.choice([1, 2, 3])
        s = rng.choice([1, 1, 2])
        if same_pads(hh, k, s)[0] < 3:
            s = 1
        kw = rng.choice([1, 2, 3])
        sw = rng.choice([1, 1, 2])
        if same_pads(ww, kw, sw)[0] < 3:
            sw = 1
        kernels.append(k)
        strides.append(s)
        kernels_w.append(kw)
        strides_w.append(sw)
        hh = same_pads(hh, k, s)[0]
        ww = same_pads(ww, kw, sw)[0]
    g = tiled_chain_graph(h, w, chan, kernels, strides, kernels_w, strides_w)
    cuts = (rng.randint(1, n - 1),)
    return g, cuts


def forced_cascade(graph: Graph, cuts: Sequence[int], k: int,
                   min_rows: int = 1, rate_div: int = 1, strips: int = 1
                   ) -> Tuple[Graph, Cascade]:
    """Emit the exact ``(cuts, k, strips)`` cascade of the graph's single
    sliceable run — no planner in the loop, so oracle enumerations control
    every knob the cost model prices."""
    run = sliceable_runs(graph)[0]
    segs: List[List[Operator]] = []
    lo = 0
    for c in list(cuts) + [len(run)]:
        segs.append(list(run[lo:c]))
        lo = c
    est, frac, rings, extra = estimate_cascade(graph, segs, k, min_rows,
                                               rate_div, strips)
    casc = Cascade(segs, k, rings, est, frac, min_rows, rate_div, extra,
                   strips)
    return apply_cascade(graph, [casc]), casc


def tiled_triple_points(graph: Graph, cuts: Sequence[int],
                        k_choices: Sequence[int] = (2, 3, 4),
                        strips_choices: Sequence[int] = (1, 2, 3),
                        min_rows: int = 1, rate_div: int = 1
                        ) -> List[Tuple[str, int, int, int]]:
    """(label, planner est, liveness peak, arena bytes) for every feasible
    ``(k, strips)`` forced cascade of a pure chain.  Liveness is
    ``Graph.peak_usage`` of the emitted streaming order (the ground-truth
    memory model), arena is a validated ``ArenaPlanner`` packing — three
    independent computations the triple-agreement property pins equal."""
    run = sliceable_runs(graph)[0]
    members = list(run)
    h_final = int(graph.tensors[members[-1].output].shape[0])
    points: List[Tuple[str, int, int, int]] = []
    for k in k_choices:
        if not 2 <= k <= h_final:
            continue
        for strips in strips_choices:
            if not _strips_eligible(graph, members, strips):
                continue
            rg, casc = forced_cascade(graph, cuts, k, min_rows, rate_div,
                                      strips)
            sched = list(rg.operators)
            live = rg.peak_usage(sched)
            plan = ArenaPlanner.plan(rg, sched)
            ArenaPlanner.validate(plan, rg)
            points.append((f"tile[k{k}/s{strips}]", casc.est_peak, live,
                           plan.arena_size))
    return points


def random_sliceable_chain(seed: int, max_len: int = 3) -> Graph:
    """Fixed-seed random sliceable chain, small enough for the oracles
    (the joint oracle enumerates every split's rewrite: keep chains short
    and heights small so K stays low and order counts tractable)."""
    rng = random.Random(seed)
    n = rng.randint(2, max_len)
    h = rng.choice([4, 5])
    row_bytes = [rng.choice([4, 8, 16, 24, 32]) for _ in range(n + 1)]
    kernels = [rng.choice([1, 2, 3]) for _ in range(n)]
    held = rng.choice([0, 0, 16, 64])
    return sliceable_chain_graph([h] * (n + 1), row_bytes, kernels, held)
