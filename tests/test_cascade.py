"""Cascaded Pex streaming: ring-buffer inter-segment execution.

The cascade rewrite (``core/partition.py``) chains adjacent sliceable
segments through ring buffers so no inter-segment tensor ever exists
whole.  These tests pin the contract end to end:

* the rewritten graph evaluates **bit-identically** to the original
  through the micro-interpreter (both allocators) and the compiled arena
  executor (rolled and unrolled);
* the memory model triple-agrees: dynamic-interpreter peak ==
  liveness-model peak == ``plan.arena_size`` (ring pushes are inplace
  rolling writes, so the existing accounting prices them);
* the golden headline: MobileNet-1.0@192 int8 + reorder + cascade fits a
  256 KB arena (the ROADMAP "cascaded Pex streaming" item), strictly
  below the whole-externals Pex floor, at <= 25% extra MACs.

Numerics contract (same caveat as ``jaxpr_partial``'s sliced
dot_general): the int8 path is **bit-identical** — int32 accumulation and
round-half-even requantization are exact, so streaming cannot drift — and
it is the deployment path the golden pins.  The f32 path is bit-identical
*per shape* (compiled executor vs interpreter on the same cascaded
graph), but XLA CPU's conv algorithm is not bit-stable across input
heights at larger channel counts, so f32 cascade outputs are compared to
the unsliced graph within accumulation tolerance.

The hypothesis property (random sliceable chains) runs when hypothesis
is installed; a fixed-seed sweep of the same property always runs.
"""
import random

import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import ArenaPlanner, Graph, cascade_graph, partition_graph, schedule
from repro.graphs import (int8_scheduling_graph, mobilenet_v1_graph,
                          quantize_graph, random_input)
from repro.graphs.cnn_ops import (CNNBuilder, grow_kernel,
                                  redistribute_receptive_field)
from repro.mcu import MicroInterpreter, compile_schedule

KB = 1024


def random_chain_graph(seed: int, h: int = 24) -> Graph:
    """A random sliceable chain: conv/dwconv/maxpool at mixed strides —
    the cascade planner's element (cascades live inside chains)."""
    rng = random.Random(seed)
    g = Graph()
    b = CNNBuilder(g)
    x = b.input("input", h, h, rng.choice([3, 4]))
    for _ in range(rng.randint(3, 7)):
        r = rng.random()
        if r < 0.4:
            x = b.conv(x, rng.choice([4, 8, 16]), k=rng.choice([1, 3]),
                       stride=rng.choice([1, 1, 2]))
        elif r < 0.8:
            x = b.dwconv(x, k=3, stride=rng.choice([1, 1, 2]))
        else:
            x = b.maxpool(x, k=2, stride=2)
        if b.shapes[x][0] < 4:
            break
    x = b.avgpool(x)
    x = b.fc(x, 4)
    g.set_outputs([x])
    return g


def _inputs(g, seed=0):
    h, w, c = g.tensors["input"].shape
    rng = np.random.default_rng(seed)
    return {"input": rng.standard_normal((h, w, c)).astype(np.float32)}


def _triple_agreement(g, gp, sched, x):
    """dynamic peak == liveness peak == plan arena, outputs bit-identical
    to the original graph across both interpreter allocators.

    The arena leg is exact: the planner's multi-order greedy (allocator.py)
    closes the fragmentation best-fit-by-size alone used to leave on
    irregular random cascade chains, so ``arena == liveness`` holds for
    the random property graphs too, not just the structured goldens."""
    plan = ArenaPlanner.plan(gp, sched)
    ArenaPlanner.validate(plan, gp)
    ref = MicroInterpreter(g).run(x)
    dyn = MicroInterpreter(gp).run(x, schedule=sched)
    pln = MicroInterpreter(gp).run(x, schedule=sched, plan=plan)
    for o in g.outputs:
        if np.asarray(ref.outputs[o]).dtype == np.int8:
            np.testing.assert_array_equal(ref.outputs[o], dyn.outputs[o])
        else:     # f32: XLA conv is not bit-stable across input heights
            np.testing.assert_allclose(ref.outputs[o], dyn.outputs[o],
                                       rtol=2e-6, atol=1e-7)
        # both interpreter allocators agree exactly (same shapes)
        np.testing.assert_array_equal(dyn.outputs[o], pln.outputs[o])
    live_peak = gp.peak_usage(sched)
    assert dyn.peak_sram == live_peak, (dyn.peak_sram, live_peak)
    assert plan.arena_size == live_peak, (plan.arena_size, live_peak)
    return plan


# --------------------------------------------------------------- bit-identity
def test_cascade_f32_memory_model_and_numerics():
    g = mobilenet_v1_graph()                      # 0.25 @ 96, executable
    base = schedule(g)
    cr = cascade_graph(g, budget=int(base.peak * 0.5))
    assert cr.cascades, "mobilenet chain must cascade"
    assert cr.extra_macs_frac <= 0.25
    gp = cr.graph
    sched = gp.default_schedule()                 # insertion order = stream
    x = _inputs(g)
    plan = _triple_agreement(g, gp, sched, x)
    assert plan.arena_size < base.peak
    # ring states alias to one buffer through the inplace chain
    ring_places = [p for p in plan.placements if "__ring" in p.tensor]
    assert ring_places
    by_alias = {}
    for p in ring_places:
        assert p.alias is not None
        by_alias.setdefault(p.alias, set()).add(p.offset)
    assert all(len(offs) == 1 for offs in by_alias.values())


def test_cascade_int8_compiled_bit_identical_rolled_and_unrolled():
    """Quantized cascade: zero-point SAME padding and per-tensor requant
    must survive ring streaming bit-for-bit — through both interpreter
    allocators and the compiled byte-arena executor, with the rolled
    fori_loop form agreeing with the unrolled one."""
    g = mobilenet_v1_graph()
    qm = quantize_graph(g, random_input(g))
    q = qm.graph
    base = schedule(q)
    cr = cascade_graph(q, budget=int(base.peak * 0.5))
    assert cr.cascades
    gp = cr.graph
    sched = gp.default_schedule()
    x = qm.quantize_inputs(random_input(g))
    plan = _triple_agreement(q, gp, sched, x)
    ex = compile_schedule(gp, sched, plan)
    assert ex.rolled_loops > 0, "steady-state iterations must roll"
    assert ex.arena_size == plan.arena_size
    out = ex.run(x)
    out_u = compile_schedule(gp, sched, plan, roll_loops=False).run(x)
    ref = MicroInterpreter(q).run(x)
    for o in q.outputs:
        np.testing.assert_array_equal(ref.outputs[o], out[o])
        np.testing.assert_array_equal(out[o], out_u[o])
        assert out[o].dtype == np.int8


# ------------------------------------------------------------------ scheduler
def test_schedule_escalates_to_cascade_only_when_needed():
    g = mobilenet_v1_graph()
    base = schedule(g)
    # a budget whole-externals pex meets (same budget test_partition pins):
    # no cascade — the escalation must not fire when pex suffices
    pex = schedule(g, arena_budget=int(base.peak * 0.9))
    assert pex.peak <= int(base.peak * 0.9)
    assert "cascade" not in pex.method
    # a budget pex cannot meet: cascade fires and beats pex's peak
    tight = int(pex.peak * 0.6)
    res = schedule(g, arena_budget=tight)
    assert "cascade" in res.method
    assert res.peak < pex.peak


# ------------------------------------------------------ golden (fast tier)
def test_golden_mobilenet_100_192_cascade_fits_256K():
    """THE ROADMAP item: cascaded Pex streaming breaks the ~280 KB
    whole-externals floor on MobileNet-1.0@192 int8 — a <= 256 KB arena,
    strictly below the whole-externals Pex result, at <= 25% extra MACs.
    Scheduling-only (int8_scheduling_graph reproduces the quantized
    model's exact byte sizes); the executable golden is the slow-tier
    test below."""
    q = int8_scheduling_graph(mobilenet_v1_graph(alpha=1.0, resolution=192))
    res = schedule(q, arena_budget=256 * KB)
    assert "cascade" in res.method
    assert 0.0 < res.extra_macs_frac <= 0.25
    gp = res.graph
    assert gp is not None
    plan = ArenaPlanner.plan(gp, res.schedule)
    ArenaPlanner.validate(plan, gp)
    assert res.peak <= 256 * KB
    assert plan.arena_size <= 256 * KB
    assert plan.arena_size == gp.peak_usage(res.schedule)
    # strictly below the ~280 KB whole-externals floor (and a fortiori the
    # 315 KB whole-externals arena test_golden pins at the 512 KB budget)
    assert plan.arena_size < 280 * KB


@pytest.mark.slow
def test_golden_mobilenet_100_192_cascade_executable():
    """The executable form of the golden: real int8 weights, compiled
    byte-arena executor, bit-identical to the MicroInterpreter under both
    allocators, inside 256 KB."""
    g = mobilenet_v1_graph(alpha=1.0, resolution=192)
    qm = quantize_graph(g, random_input(g))
    q = qm.graph
    res = schedule(q, arena_budget=256 * KB)
    assert "cascade" in res.method and res.graph is not None
    gp = res.graph
    x = qm.quantize_inputs(random_input(g))
    plan = _triple_agreement(q, gp, res.schedule, x)
    assert plan.arena_size <= 256 * KB
    ex = compile_schedule(gp, res.schedule, plan)
    out = ex.run(x)
    ref = MicroInterpreter(q).run(x)
    for o in q.outputs:
        np.testing.assert_array_equal(ref.outputs[o], out[o])


# ------------------------------------------------- ring liveness property
def _ring_liveness_property(seed: int) -> bool:
    """The satellite property on one random chain: cascade triple
    agreement (dynamic peak == liveness peak, arena validated against
    both) + the budget escalation never loses to whole-externals Pex
    alone (cascades are only selected when they win; on tiny chains pex
    can beat the rings' overhead and must then be kept).  Returns True
    when the seed produced a cascade."""
    g = random_chain_graph(seed)
    base = schedule(g)
    budget = int(base.peak * 0.6)
    cr = cascade_graph(g, budget=budget)
    if not cr.cascades:
        return False
    gp = cr.graph
    sched = gp.default_schedule()
    x = _inputs(g, seed)
    _triple_agreement(g, gp, sched, x)
    res = schedule(g, arena_budget=budget)
    assert res.peak <= base.peak
    pr = partition_graph(g, budget=budget)
    if pr.segments:
        pex_peak = pr.graph.peak_usage(pr.graph.default_schedule())
        assert res.peak <= pex_peak, (res.peak, pex_peak)
    return True


def test_ring_liveness_fixed_seeds():
    cascaded = sum(_ring_liveness_property(seed) for seed in range(8))
    assert cascaded >= 2, "generator produced too few cascadable chains"


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=8, deadline=None)    # each example runs a planner +
@given(st.integers(min_value=0, max_value=10_000))   # 3 interpreter passes
def test_ring_liveness_hypothesis(seed):
    _ring_liveness_property(seed)


# ------------------------------------------------------- 2-D tiled cascades
def test_cascade2d_forced_strips_bit_identical_compiled():
    """W-strips forced on the small quantized MobileNet: memory-model
    triple agreement plus compiled rolled/unrolled bit-identity —
    zero-point column padding, per-strip halo windows and the
    strip-spanning output accumulator must survive 2-D streaming
    bit-for-bit."""
    g = mobilenet_v1_graph()
    qm = quantize_graph(g, random_input(g))
    q = qm.graph
    base = schedule(q)
    cr = cascade_graph(q, budget=int(base.peak * 0.5), strips_choices=(2,))
    assert cr.cascades and all(c.strips == 2 for c in cr.cascades)
    gp = cr.graph
    sched = gp.default_schedule()
    x = qm.quantize_inputs(random_input(g))
    plan = _triple_agreement(q, gp, sched, x)
    ex = compile_schedule(gp, sched, plan)
    assert ex.rolled_loops > 0
    assert ex.arena_size == plan.arena_size
    out = ex.run(x)
    out_u = compile_schedule(gp, sched, plan, roll_loops=False).run(x)
    ref = MicroInterpreter(q).run(x)
    for o in q.outputs:
        np.testing.assert_array_equal(ref.outputs[o], out[o])
        np.testing.assert_array_equal(out[o], out_u[o])
        assert out[o].dtype == np.int8


def test_cascade2d_degenerate_strips1_identical_to_row_path():
    """strips == 1 must leave the 1-D row-ring path byte-identical: the
    243 KB golden's plan, structural counts, liveness peak and the absence
    of every 2-D artifact are pinned.  (The emission itself was verified
    op-by-op — names, wiring, attrs, tensor sizes — against the pre-2-D
    emitter at its last commit; these pins keep that equivalence from
    regressing.)"""
    q = int8_scheduling_graph(mobilenet_v1_graph(alpha=1.0, resolution=192))
    cr = cascade_graph(q, budget=256 * KB)         # default strips (1,)
    c = cr.cascades[0]
    assert (c.k, c.strips, c.ring_rows, c.min_rows, c.rate_div) == \
        (12, 1, [3, 3, 3, 3], 1, 4)
    gp = cr.graph
    assert (len(gp.operators), len(gp.tensors)) == (773, 774)
    assert gp.peak_usage(gp.default_schedule()) == 248832   # 243 KB golden
    for op in gp.operators:
        for a in ("pex_cols", "pex_wpads", "pex_cstart"):
            assert a not in op.attrs, (op.name, a)


def test_golden_mobilenet_100_192_cascade2d_fits_224K():
    """THE 2-D headline: W-strip tiling of the early stage breaks the
    243 KB row-ring floor on MobileNet-1.0@192 int8 — a 224 KB arena the
    1-D planner cannot reach, at <= 25% extra MACs.  Scheduling-only; the
    executable form is the slow-tier test below."""
    q = int8_scheduling_graph(mobilenet_v1_graph(alpha=1.0, resolution=192))
    res = schedule(q, arena_budget=224 * KB)
    assert "cascade2d" in res.method
    assert 0.0 < res.extra_macs_frac <= 0.25
    gp = res.graph
    assert gp is not None
    plan = ArenaPlanner.plan(gp, res.schedule)
    ArenaPlanner.validate(plan, gp)
    assert res.peak <= 224 * KB
    assert plan.arena_size == res.peak == gp.peak_usage(res.schedule)
    # strictly below the 1-D row-ring result (248832 B), not just the cap
    assert plan.arena_size < 243 * KB


@pytest.mark.slow
def test_golden_mobilenet_100_192_cascade2d_executable():
    """Executable form of the 2-D golden: real int8 weights, compiled
    byte-arena executor, bit-identical to the MicroInterpreter under both
    allocators, inside 224 KB."""
    g = mobilenet_v1_graph(alpha=1.0, resolution=192)
    qm = quantize_graph(g, random_input(g))
    q = qm.graph
    res = schedule(q, arena_budget=224 * KB)
    assert "cascade2d" in res.method and res.graph is not None
    gp = res.graph
    x = qm.quantize_inputs(random_input(g))
    plan = _triple_agreement(q, gp, res.schedule, x)
    assert plan.arena_size <= 224 * KB
    ex = compile_schedule(gp, res.schedule, plan)
    assert ex.rolled_loops > 0
    out = ex.run(x)
    ref = MicroInterpreter(q).run(x)
    for o in q.outputs:
        np.testing.assert_array_equal(ref.outputs[o], out[o])


# ------------------------------------------- receptive-field redistribution
def test_grow_kernel_zero_embed_bit_identical():
    """Growing a kernel by zero-embedding (k3 -> k5, SAME pads re-derived
    per axis) is function-preserving: the whole quantized network must
    produce identical bits, and the op carries the audit flag."""
    g = mobilenet_v1_graph()
    qm = quantize_graph(g, random_input(g))
    q = qm.graph
    x = qm.quantize_inputs(random_input(g))
    names = [op.name for op in q.operators if op.kind == "qdwconv"]
    gg = grow_kernel(q, names[1])
    ref = MicroInterpreter(q).run(x)
    got = MicroInterpreter(gg).run(x)
    for o in q.outputs:
        np.testing.assert_array_equal(ref.outputs[o], got.outputs[o])
    gop = next(op for op in gg.operators if op.name == names[1])
    assert gop.attrs["k"] == 5 and gop.attrs["rf_edit"] == "grow"
    assert gop.attrs["weight_bytes"] > 0


def test_redistribute_receptive_field_flags_and_lowers_tile_halo():
    """The MCUNetV2-style planner option: moving kernel reach from an
    early (halo-expensive) depthwise to a later one keeps the graph
    executable, flags both edited ops, and strictly lowers the 2-D
    cascade's halo-recompute MACs at the same budget."""
    g = mobilenet_v1_graph()
    qm = quantize_graph(g, random_input(g))
    q = qm.graph
    x = qm.quantize_inputs(random_input(g))
    names = [op.name for op in q.operators if op.kind == "qdwconv"]
    rd = redistribute_receptive_field(q, names[0], names[2])
    sop = next(op for op in rd.operators if op.name == names[0])
    top = next(op for op in rd.operators if op.name == names[2])
    assert sop.attrs["k"] == 1 and sop.attrs["rf_edit"] == "shrink"
    assert top.attrs["k"] == 5 and top.attrs["rf_edit"] == "grow"
    out = MicroInterpreter(rd).run(x)      # flagged model edit still runs
    assert all(np.asarray(out.outputs[o]).dtype == np.int8
               for o in rd.outputs)
    budget = int(schedule(q).peak * 0.5)
    plain = cascade_graph(q, budget=budget, strips_choices=(2,))
    rf = cascade_graph(q, budget=budget, strips_choices=(2,),
                       rf_redistribute=(names[0], names[2]))
    assert plain.cascades and rf.cascades
    assert rf.extra_macs < plain.extra_macs
