"""Training substrate: optimizer semantics, loss decreases on learnable
synthetic data, microbatching equivalence, checkpoint round-trip."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.models.model import Model
from repro.training import (adamw_init, adamw_update, cosine_lr,
                            make_train_step, train_state_init)
from repro.training.checkpoint import (latest_checkpoint, restore_checkpoint,
                                       save_checkpoint)

pytestmark = pytest.mark.slow   # integration tier; see pytest.ini


def test_cosine_lr_shape():
    assert float(cosine_lr(jnp.int32(0), peak=1.0, warmup=10,
                           total=100)) == 0.0
    assert abs(float(cosine_lr(jnp.int32(10), peak=1.0, warmup=10,
                               total=100)) - 1.0) < 1e-6
    end = float(cosine_lr(jnp.int32(100), peak=1.0, warmup=10, total=100))
    assert 0.0 < end <= 0.11


def test_adamw_moves_params_toward_gradient():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    grads = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    st = adamw_init(params)
    new, st2, m = adamw_update(params, grads, st, lr=jnp.float32(0.1))
    assert float(new["w"][0, 0]) < 1.0            # moved against gradient
    assert int(st2.step) == 1
    assert float(m["grad_norm"]) > 0


def test_loss_decreases_on_markov_data():
    cfg = get_config("llama3.2-3b@smoke")
    model = Model(cfg)
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    ds = SyntheticLMDataset(cfg, batch_size=8, seq_len=64, seed=0)
    step = jax.jit(make_train_step(model, peak_lr=3e-3, warmup=5,
                                   total_steps=60, remat=False))
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.25, (first, last)


def test_microbatching_matches_single_batch():
    cfg = get_config("granite-moe-1b-a400m@smoke")
    model = Model(cfg)
    state = train_state_init(cfg, jax.random.PRNGKey(1))
    ds = SyntheticLMDataset(cfg, batch_size=8, seq_len=32, seed=1)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    s1 = jax.jit(make_train_step(model, remat=False, microbatches=1))
    s2 = jax.jit(make_train_step(model, remat=False, microbatches=2))
    _, m1 = s1(state, batch)
    _, m2 = s2(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)


def test_remat_matches_no_remat():
    cfg = get_config("llama3.2-3b@smoke")
    model = Model(cfg)
    state = train_state_init(cfg, jax.random.PRNGKey(2))
    ds = SyntheticLMDataset(cfg, batch_size=4, seq_len=32, seed=2)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    _, m_a = jax.jit(make_train_step(model, remat=False))(state, batch)
    _, m_b = jax.jit(make_train_step(model, remat=True))(state, batch)
    np.testing.assert_allclose(float(m_a["loss"]), float(m_b["loss"]),
                               rtol=1e-5)


def test_checkpoint_roundtrip():
    cfg = get_config("xlstm-350m@smoke")
    state = train_state_init(cfg, jax.random.PRNGKey(3))
    with tempfile.TemporaryDirectory() as d:
        f = save_checkpoint(d, state.params, step=7)
        assert latest_checkpoint(d) == f
        restored = restore_checkpoint(f, state.params)
        for a, b in zip(jax.tree_util.tree_leaves(state.params),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic():
    cfg = get_config("llama3.2-3b@smoke")
    a = SyntheticLMDataset(cfg, 4, 32, seed=5).batch(3)
    b = SyntheticLMDataset(cfg, 4, 32, seed=5).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLMDataset(cfg, 4, 32, seed=6).batch(3)
    assert (a["tokens"] != c["tokens"]).any()
