"""Unit tests for the CI benchmark-regression gate (benchmarks/compare.py):
bytes are gated exactly, time with tolerance + slack, coverage loss fails,
new rows pass with a note, Pareto fronts are gated point-by-point.  Also
pins that the committed baseline is well-formed and carries the
byte/dtype/Pareto metadata the gate needs.
"""

import pathlib

import pytest

from benchmarks.compare import compare_rows, front_covers, load_rows

BASELINE = pathlib.Path(__file__).parent.parent / "benchmarks" / "BENCH_baseline.json"


def _row(name, us=100.0, arena=None, dtypes=None, pareto=None):
    row = {"name": name, "us_per_call": us, "arena_bytes": arena, "dtypes": dtypes}
    if pareto is not None:
        row["pareto"] = pareto
    return row


def _index(rows):
    return {r["name"]: r for r in rows}


def test_identical_runs_pass():
    rows = _index([_row("a", 100, 4096), _row("b", 50, None)])
    failures, notes = compare_rows(rows, dict(rows), us_tol=0.2, us_slack=0)
    assert failures == [] and notes == []


def test_arena_growth_fails_exactly():
    base = _index([_row("a", 100, 4096)])
    ok = _index([_row("a", 100, 4096)])
    shrunk = _index([_row("a", 100, 4000)])
    grown = _index([_row("a", 100, 4097)])
    assert compare_rows(base, ok, 0.2, 0)[0] == []
    assert compare_rows(base, shrunk, 0.2, 0)[0] == []
    failures, _ = compare_rows(base, grown, 0.2, 0)
    assert len(failures) == 1 and "bytes grew" in failures[0]


def test_time_regression_gated_with_tol_and_slack():
    base = _index([_row("a", 1000.0)])
    within = _index([_row("a", 1199.0)])
    exactly = _index([_row("a", 1200.0)])
    beyond = _index([_row("a", 1201.0)])
    assert compare_rows(base, within, 0.2, 0)[0] == []
    # exactly at the envelope limit is within tolerance, not a regression
    assert compare_rows(base, exactly, 0.2, 0)[0] == []
    failures, _ = compare_rows(base, beyond, 0.2, 0)
    assert len(failures) == 1 and "us/call regressed" in failures[0]
    # the absolute slack absorbs jitter on tiny rows
    assert compare_rows(base, beyond, 0.2, 5000)[0] == []
    # ... and the slack boundary itself is inclusive too
    at_slack = _index([_row("a", 1200.0 + 5000.0)])
    past_slack = _index([_row("a", 1201.0 + 5000.0)])
    assert compare_rows(base, at_slack, 0.2, 5000)[0] == []
    assert compare_rows(base, past_slack, 0.2, 5000)[0] != []


def test_lost_arena_bytes_fails():
    """A fresh row that drops its byte figure must fail, not silently
    disarm the strict bytes gate for that row."""
    base = _index([_row("a", 100, 4096)])
    lost = _index([_row("a", 100, None)])
    failures, _ = compare_rows(base, lost, 0.2, 0)
    assert len(failures) == 1 and "arena_bytes lost" in failures[0]
    # a row that never had bytes stays ungated
    never = _index([_row("b", 100, None)])
    assert compare_rows(never, dict(never), 0.2, 0)[0] == []


def test_missing_row_fails_and_new_row_notes():
    base = _index([_row("a"), _row("gone")])
    fresh = _index([_row("a"), _row("new")])
    failures, notes = compare_rows(base, fresh, 0.2, 0)
    assert len(failures) == 1 and "gone" in failures[0]
    assert any("new row" in n for n in notes)


def test_requests_per_s_floor_gate():
    """Serving throughput rows are gated as a tolerant floor: fresh rps
    may dip to baseline * (1 - rps_tol); below that fails, and losing the
    figure entirely fails (gate must not be silently disarmed)."""
    base = _index([dict(_row("s"), requests_per_s=100.0)])
    ok = _index([dict(_row("s"), requests_per_s=51.0)])
    at_floor = _index([dict(_row("s"), requests_per_s=50.0)])
    below = _index([dict(_row("s"), requests_per_s=49.0)])
    faster = _index([dict(_row("s"), requests_per_s=400.0)])
    assert compare_rows(base, ok, 0.2, 0, rps_tol=0.5)[0] == []
    assert compare_rows(base, at_floor, 0.2, 0, rps_tol=0.5)[0] == []
    assert compare_rows(base, faster, 0.2, 0, rps_tol=0.5)[0] == []
    failures, _ = compare_rows(base, below, 0.2, 0, rps_tol=0.5)
    assert len(failures) == 1 and "requests/s fell" in failures[0]
    lost = _index([_row("s")])
    failures, _ = compare_rows(base, lost, 0.2, 0, rps_tol=0.5)
    assert len(failures) == 1 and "requests_per_s lost" in failures[0]
    # rows without throughput stay ungated
    plain = _index([_row("p")])
    assert compare_rows(plain, dict(plain), 0.2, 0, rps_tol=0.5)[0] == []


def test_update_baseline_rps_floor_envelope():
    """Merging keeps the weakest observed requests/s (floor envelope) and
    refuses a merge that would drop the figure entirely."""
    from benchmarks.run import merge_baseline

    base = {"rows": [dict(_row("s", us=100.0, arena=64),
                          requests_per_s=100.0)]}
    notes = merge_baseline(base, [dict(_row("s", us=90.0, arena=64),
                                       requests_per_s=80.0)])
    assert _index(base["rows"])["s"]["requests_per_s"] == 80.0
    assert any("requests/s floor" in n for n in notes)
    merge_baseline(base, [dict(_row("s", us=90.0, arena=64),
                               requests_per_s=200.0)])
    assert _index(base["rows"])["s"]["requests_per_s"] == 80.0  # floor kept
    with pytest.raises(SystemExit, match="lost its requests_per_s"):
        merge_baseline(base, [_row("s", us=90.0, arena=64)])


def test_dtype_change_is_noted():
    base = _index([_row("a", dtypes="float32")])
    fresh = _index([_row("a", dtypes="int8")])
    failures, notes = compare_rows(base, fresh, 0.2, 0)
    assert failures == []
    assert any("dtypes changed" in n for n in notes)


# ----------------------------------------------------------- chaos counters
def test_chaos_counters_must_be_zero_in_no_fault_config():
    """Serving rows carry expired/shed counts from the no-fault benchmark
    configuration; any nonzero value is an admission-layer bug and fails
    exactly (no tolerance)."""
    base = _index([dict(_row("s"), expired=0, shed=0)])
    clean = _index([dict(_row("s"), expired=0, shed=0)])
    assert compare_rows(base, clean, 0.2, 0)[0] == []
    for key in ("expired", "shed"):
        row = dict(_row("s"), expired=0, shed=0)
        row[key] = 1
        failures, _ = compare_rows(base, _index([row]), 0.2, 0)
        assert len(failures) == 1
        assert f"{key}=1" in failures[0] and "exactly 0" in failures[0]


def test_chaos_counter_lost_fails():
    """A fresh row that drops its expired/shed count silently disarms the
    chaos gate — that is a failure, like losing a byte figure."""
    base = _index([dict(_row("s"), expired=0, shed=0)])
    lost = _index([_row("s")])
    failures, _ = compare_rows(base, lost, 0.2, 0)
    assert len(failures) == 2
    assert any("expired count lost" in f for f in failures)
    assert any("shed count lost" in f for f in failures)
    # rows that never carried counters stay ungated
    plain = _index([_row("p")])
    assert compare_rows(plain, dict(plain), 0.2, 0)[0] == []


def test_chaos_counter_nonzero_fails_even_without_baseline_counter():
    """The zero requirement is absolute: a NEW nonzero counter on a row
    whose baseline never had one still fails (faults leaking into a
    benchmark must never pass because the baseline predates the gate)."""
    base = _index([_row("s")])
    dirty = _index([dict(_row("s"), shed=3)])
    failures, _ = compare_rows(base, dirty, 0.2, 0)
    assert len(failures) == 1 and "shed=3" in failures[0]


def test_committed_serving_rows_carry_zero_chaos_counters():
    """The committed baseline's serving rows must participate in the
    chaos gate (counters present and zero)."""
    rows, _ = load_rows(str(BASELINE))
    serving = [r for n, r in rows.items()
               if n.startswith("serving.") and "requests_per_s" in r]
    assert serving
    for r in serving:
        assert r["expired"] == 0 and r["shed"] == 0


# ----------------------------------------------------- corrupt input files
def test_load_rows_missing_file_one_line_diagnosis(tmp_path):
    with pytest.raises(SystemExit, match="cannot read file") as ei:
        load_rows(str(tmp_path / "nope.json"))
    assert "nope.json" in str(ei.value)


def test_load_rows_truncated_json_names_file_and_position(tmp_path):
    p = tmp_path / "trunc.json"
    p.write_text('{"rows": [{"name": "a", "us_per_call": 1')
    with pytest.raises(SystemExit, match="corrupt/truncated JSON") as ei:
        load_rows(str(p))
    msg = str(ei.value)
    assert "trunc.json" in msg and "line 1" in msg


def test_load_rows_missing_rows_key(tmp_path):
    p = tmp_path / "norows.json"
    p.write_text('{"results": []}')
    with pytest.raises(SystemExit, match="missing key 'rows'") as ei:
        load_rows(str(p))
    assert "norows.json" in str(ei.value)


def test_load_rows_non_dict_payload(tmp_path):
    p = tmp_path / "list.json"
    p.write_text('[1, 2, 3]')
    with pytest.raises(SystemExit, match="missing key 'rows'"):
        load_rows(str(p))


def test_load_rows_rows_not_a_list(tmp_path):
    p = tmp_path / "badrows.json"
    p.write_text('{"rows": {"a": 1}}')
    with pytest.raises(SystemExit,
                       match="key 'rows' is dict, expected a list"):
        load_rows(str(p))


def test_load_rows_row_missing_name_names_index(tmp_path):
    p = tmp_path / "noname.json"
    p.write_text('{"rows": [{"name": "ok"}, {"us_per_call": 5}]}')
    with pytest.raises(SystemExit, match=r"rows\[1\] missing key 'name'"):
        load_rows(str(p))


# ------------------------------------------------------------- Pareto gate
FRONT = [[0, 32768], [1024, 31744], [4864, 26368]]


def test_front_covers_matched_and_dominated():
    assert front_covers(FRONT, FRONT) == []                    # identical
    better = [[0, 32768], [512, 31744], [4864, 26000]]         # dominates
    assert front_covers(FRONT, better) == []
    worse = [[0, 32768], [1024, 31745], [4864, 26368]]         # peak +1
    assert front_covers(FRONT, worse) == [(1024, 31744)]
    sparser = [[0, 32768], [4864, 26368]]                      # point gone
    assert front_covers(FRONT, sparser) == [(1024, 31744)]


def test_pareto_point_regression_fails():
    base = _index([_row("a", 100, 4096, pareto=FRONT)])
    ok = _index([_row("a", 100, 4096, pareto=[list(p) for p in FRONT])])
    assert compare_rows(base, ok, 0.2, 0)[0] == []
    worse = _index([_row("a", 100, 4096,
                         pareto=[[0, 32768], [1024, 31745], [4864, 26368]])])
    failures, _ = compare_rows(base, worse, 0.2, 0)
    assert len(failures) == 1 and "Pareto point" in failures[0]
    assert "31744" in failures[0]


def test_pareto_front_lost_fails_and_new_front_notes():
    base = _index([_row("a", 100, 4096, pareto=FRONT)])
    lost = _index([_row("a", 100, 4096)])
    failures, _ = compare_rows(base, lost, 0.2, 0)
    assert len(failures) == 1 and "Pareto front lost" in failures[0]
    plain = _index([_row("a", 100, 4096)])
    fresh = _index([_row("a", 100, 4096, pareto=FRONT)])
    failures, notes = compare_rows(plain, fresh, 0.2, 0)
    assert failures == []
    assert any("new Pareto front" in n for n in notes)


def test_committed_baseline_is_well_formed():
    rows, payload = load_rows(str(BASELINE))
    assert payload["smoke"] is True
    assert payload["units"]["arena_bytes"] == "bytes"
    # the gate has real byte rows to hold on to, at both element widths
    arena_rows = {n: r for n, r in rows.items() if r.get("arena_bytes") and r["arena_bytes"] > 0}
    assert len(arena_rows) >= 10
    dtypes = {r.get("dtypes") for r in rows.values()}
    assert "int8" in dtypes and "float32" in dtypes
    # a known anchor: the paper's figure1 arena is 4960 B
    assert rows["executor.figure1.arena_B"]["arena_bytes"] == 4960
    # the joint solver's Pareto row is present and carries a real front
    front = rows["scheduler.pareto.chain"].get("pareto")
    assert front and len(front) >= 3
    extras = [p[0] for p in front]
    peaks = [p[1] for p in front]
    assert extras == sorted(extras) and extras[0] == 0
    assert peaks == sorted(peaks, reverse=True)


def test_baseline_byte_rows_match_current_scheduling():
    """The committed baseline's deterministic byte numbers must be
    reproducible by today's schedulers — cheap rows only (figure1)."""
    from repro.core import schedule
    from repro.graphs import figure1_graph

    rows, _ = load_rows(str(BASELINE))
    assert rows["figure1.optimal_peak_B"]["arena_bytes"] == schedule(figure1_graph()).peak == 4960


# ----------------------------------------------------- baseline regeneration
def test_update_baseline_envelope_merge():
    """run.py --update-baseline semantics: max-us envelope, exact bytes,
    new rows appended, rows not re-run kept."""
    from benchmarks.run import merge_baseline

    base = {"rows": [_row("a", us=100.0, arena=4096),
                     _row("kept", us=5.0, arena=64)]}
    notes = merge_baseline(
        base, [_row("a", us=80.0, arena=4000), _row("new", us=7.0, arena=8)])
    rows = _index(base["rows"])
    assert rows["a"]["us_per_call"] == 100.0      # envelope: max of runs
    assert rows["a"]["arena_bytes"] == 4000       # bytes: exact, may shrink
    assert rows["kept"]["us_per_call"] == 5.0     # not re-run: untouched
    assert rows["new"]["arena_bytes"] == 8
    assert any("new row new" in n for n in notes)


def test_update_baseline_refuses_bytes_growth():
    from benchmarks.run import merge_baseline

    base = {"rows": [_row("a", us=100.0, arena=4096)]}
    with pytest.raises(SystemExit, match="refusing to loosen"):
        merge_baseline(base, [_row("a", us=80.0, arena=5000)])
    # the escape hatch is explicit
    notes = merge_baseline(base, [_row("a", us=80.0, arena=5000)],
                           allow_bytes_growth=True)
    assert _index(base["rows"])["a"]["arena_bytes"] == 5000
    assert any("--allow-bytes-growth" in n for n in notes)


def test_update_baseline_refuses_lost_bytes_even_with_growth_flag():
    """--allow-bytes-growth loosens numbers; it must NOT bypass the
    lost-arena_bytes refusal (a row silently leaving the gate entirely)."""
    from benchmarks.run import merge_baseline

    for flag in (False, True):
        base = {"rows": [_row("a", us=100.0, arena=4096)]}
        with pytest.raises(SystemExit, match="refusing to merge"):
            merge_baseline(base, [_row("a", us=80.0, arena=None)],
                           allow_bytes_growth=flag)
        assert _index(base["rows"])["a"]["arena_bytes"] == 4096  # untouched


def test_update_baseline_pareto_semantics():
    """A merge must not silently regress or drop a committed front:
    uncovered points refuse without --allow-bytes-growth, a lost front
    always refuses (even with the flag), a covering front merges."""
    from benchmarks.run import merge_baseline

    worse = [[0, 32768], [1024, 31745], [4864, 26368]]
    base = {"rows": [_row("a", us=100.0, arena=4096, pareto=FRONT)]}
    with pytest.raises(SystemExit, match="refusing to loosen"):
        merge_baseline(base, [_row("a", us=80.0, arena=4096, pareto=worse)])
    notes = merge_baseline(base,
                           [_row("a", us=80.0, arena=4096, pareto=worse)],
                           allow_bytes_growth=True)
    assert _index(base["rows"])["a"]["pareto"] == worse
    assert any("pareto front" in n for n in notes)

    for flag in (False, True):
        base = {"rows": [_row("a", us=100.0, arena=4096, pareto=FRONT)]}
        with pytest.raises(SystemExit, match="refusing to merge"):
            merge_baseline(base, [_row("a", us=80.0, arena=4096)],
                           allow_bytes_growth=flag)

    base = {"rows": [_row("a", us=100.0, arena=4096, pareto=FRONT)]}
    better = [[0, 32768], [512, 31744], [4864, 26000]]
    notes = merge_baseline(base,
                           [_row("a", us=80.0, arena=4096, pareto=better)])
    assert _index(base["rows"])["a"]["pareto"] == better
    assert any("pareto front" in n for n in notes)
