"""Property suite for ``core/solver.py`` beyond oracle equality: the
structural contracts the solver must keep even on graphs too large to
enumerate.

* **Front invariants** — no point dominates another; after the solver's
  sort, extra MACs strictly increase while peak strictly decreases; every
  point's schedule is valid and re-prices to its claimed peak.
* **Ladder dominance** — the solver's best never loses to any rung of the
  escalation ladder (default order, greedy, exact DP, contracted DP, beam,
  or the public ``schedule()`` itself), so wiring it in as a rung can only
  help.
* **Determinism** — two identical calls return identical fronts and
  schedules (the search orders children by a total key; nothing depends on
  set/dict iteration order or wall clock).
* **Anytime contract** — a truncated node budget must still yield a
  *valid* schedule whose peak is ≥ the true optimum and ≤ the seeds; a
  larger budget is never worse.

Fixed-seed fallbacks always run; hypothesis explores fresh examples when
installed (``hypothesis_compat`` pattern).
"""
from hypothesis_compat import given, settings, st
from oracle import (build_dag, dp_min_peak, random_dag,
                    random_sliceable_chain)

from repro.core import (beam_schedule, greedy_schedule, minimise_peak_memory,
                        minimise_peak_memory_contracted, schedule, solve)
from repro.core.solver import _Budget, branch_and_bound_order


# ------------------------------------------------------------- front shape
def _assert_front_invariants(g, sr):
    front = sr.front
    assert front, "front is never empty"
    for p in front:
        assert p.extra_macs >= 0
        owner = p.result.graph if p.result.graph is not None else g
        assert owner.is_valid_schedule(p.result.schedule)
        assert owner.peak_usage(p.result.schedule) == p.peak
        # all-pairs: no front point is dominated by any other
        for q in front:
            if q is p:
                continue
            assert not (q.peak <= p.peak and q.extra_macs <= p.extra_macs
                        and (q.peak < p.peak or q.extra_macs < p.extra_macs))
    # the solver emits the front sorted: MACs strictly up, peak strictly down
    for a, b in zip(front, front[1:]):
        assert b.extra_macs > a.extra_macs
        assert b.peak < a.peak
    # best is on the front (memory mode: the min-peak endpoint)
    if sr.mode == "memory" and sr.best.extra_macs is not None:
        assert sr.best.peak == min(p.peak for p in front
                                   if p.extra_macs <= sr.best.extra_macs)


def test_front_invariants_fixed_seeds():
    for seed in range(10):
        g = random_sliceable_chain(seed)
        _assert_front_invariants(g, solve(g, max_k=4))


def test_front_invariants_plain_dags():
    # no sliceable runs: the front collapses to the single reorder point
    for seed in range(10):
        g = random_dag(seed)
        sr = solve(g)
        _assert_front_invariants(g, sr)
        assert len(sr.front) == 1
        assert sr.front[0].extra_macs == 0


# --------------------------------------------------------- ladder dominance
def _ladder_peaks(g):
    peaks = [g.peak_usage(g.default_schedule()),
             greedy_schedule(g).peak,
             minimise_peak_memory(g).peak,
             beam_schedule(g, width=8).peak,
             schedule(g).peak]
    contracted = minimise_peak_memory_contracted(g)
    if contracted is not None:
        peaks.append(contracted.peak)
    return peaks


def _assert_ladder_dominance(g):
    sr = solve(g)
    assert sr.best.peak <= min(_ladder_peaks(g))
    # the public API includes the solver rung — but without an arena budget
    # it searches order only (no Pex rewrites: their MACs cost is only paid
    # on request), so the bar is the base-space solve, not the joint one
    base = solve(g, max_rewrites=0)
    if base.complete:
        assert schedule(g).peak <= base.best.peak


def test_ladder_dominance_fixed_seeds():
    for seed in range(15):
        _assert_ladder_dominance(random_dag(seed))
        _assert_ladder_dominance(random_dag(seed, inplace_every=2))
    for seed in range(6):
        _assert_ladder_dominance(random_sliceable_chain(seed))


@st.composite
def dags(draw):
    n_inputs = draw(st.integers(min_value=1, max_value=2))
    n_ops = draw(st.integers(min_value=2, max_value=8))
    sizes = draw(st.lists(st.integers(min_value=1, max_value=64),
                          min_size=3, max_size=6))
    wiring = [draw(st.lists(st.integers(min_value=0, max_value=9),
                            min_size=1, max_size=2))
              for _ in range(n_ops)]
    inplace_every = draw(st.sampled_from([0, 2, 3]))
    return build_dag(n_inputs, sizes, wiring, inplace_every)


@given(dags())
@settings(max_examples=20, deadline=None)
def test_ladder_dominance_hypothesis(g):
    _assert_ladder_dominance(g)


# ------------------------------------------------------------- determinism
def _front_fingerprint(sr):
    return [(p.extra_macs, p.peak, p.method,
             tuple(op.name for op in p.result.schedule)) for p in sr.front]


def test_solver_is_deterministic():
    for seed in range(8):
        g = random_sliceable_chain(seed)
        a, b = solve(g, max_k=4), solve(g, max_k=4)
        assert _front_fingerprint(a) == _front_fingerprint(b)
        assert ([op.name for op in a.best.schedule]
                == [op.name for op in b.best.schedule])
        assert a.nodes == b.nodes
    for seed in range(8):
        g = random_dag(seed, inplace_every=2)
        a, b = solve(g), solve(g)
        assert _front_fingerprint(a) == _front_fingerprint(b)
        assert a.nodes == b.nodes


@given(dags())
@settings(max_examples=15, deadline=None)
def test_solver_is_deterministic_hypothesis(g):
    a, b = solve(g), solve(g)
    assert _front_fingerprint(a) == _front_fingerprint(b)
    assert a.nodes == b.nodes


# ---------------------------------------------------------------- anytime
def _assert_anytime(g):
    # dp_min_peak, not minimise_peak_memory: the paper's DP does not model
    # inplace aliasing, so on inplace graphs the true optimum can be lower
    optimum = dp_min_peak(g)
    seed = greedy_schedule(g)
    last = None
    for budget in (1, 4, 16, 64, 100_000):
        res, complete = branch_and_bound_order(g, _Budget(budget),
                                               seeds=[seed])
        assert g.is_valid_schedule(res.schedule)
        assert g.peak_usage(res.schedule) == res.peak
        assert optimum <= res.peak <= seed.peak   # never invalid, never
        if last is not None:                      # worse than the seed
            assert res.peak <= last               # more budget: never worse
        last = res.peak
        if complete:
            assert res.peak == optimum
    assert last == optimum    # 100k nodes is plenty for <=8 ops


def test_anytime_contract_fixed_seeds():
    for seed in range(12):
        _assert_anytime(random_dag(seed))
        _assert_anytime(random_dag(seed, inplace_every=2))


@given(dags())
@settings(max_examples=15, deadline=None)
def test_anytime_contract_hypothesis(g):
    _assert_anytime(g)


def test_truncated_solve_reports_incomplete():
    g = random_sliceable_chain(0)
    sr = solve(g, max_nodes=1, max_k=3)
    assert not sr.complete
    owner = sr.best.graph if sr.best.graph is not None else g
    assert owner.is_valid_schedule(sr.best.schedule)
    full = solve(g, max_k=3)
    assert full.complete
    assert full.best.peak <= sr.best.peak
