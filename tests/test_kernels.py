"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode,
plus equivalence with the model's chunked-attention path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import decode_attention, flash_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.layers import chunked_attention


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# The first shape is the fast-tier smoke; the full sweep runs in the slow
# tier (pytest -m slow) to keep tier-1 well under a minute.
@pytest.mark.parametrize("B,Sq,Skv,H,K,D", [
    (1, 128, 128, 4, 4, 64),       # MHA square
    pytest.param(2, 128, 128, 8, 2, 64,     # GQA 4:1
                 marks=pytest.mark.slow),
    pytest.param(1, 256, 256, 4, 1, 128,    # MQA, bigger D
                 marks=pytest.mark.slow),
    pytest.param(1, 64, 256, 2, 2, 64,      # cross-ish (Sq < Skv)
                 marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, Sq, Skv, H, K, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, Sq, H, D), dtype)
    k = rand(ks[1], (B, Skv, K, D), dtype)
    v = rand(ks[2], (B, Skv, K, D), dtype)
    got = flash_attention(q, k, v, causal=True, bq=64, bk=64,
                          interpret=True)
    want = attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_non_causal():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (1, 128, 2, 64), jnp.float32)
    k = rand(ks[1], (1, 128, 2, 64), jnp.float32)
    v = rand(ks[2], (1, 128, 2, 64), jnp.float32)
    got = flash_attention(q, k, v, causal=False, bq=64, bk=64,
                          interpret=True)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_model_chunked_path():
    """The model's jnp chunked attention and the kernel agree — the kernel
    can be swapped in on TPU without numerics drift."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = rand(ks[0], (2, 128, 4, 64), jnp.float32)
    k = rand(ks[1], (2, 128, 2, 64), jnp.float32)
    v = rand(ks[2], (2, 128, 2, 64), jnp.float32)
    a = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    b = chunked_attention(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,H,K,D,bs", [
    (2, 256, 4, 4, 64, 64),
    pytest.param(1, 512, 8, 2, 64, 128, marks=pytest.mark.slow),
    pytest.param(3, 256, 4, 1, 128, 256, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(B, S, H, K, D, bs, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(ks[0], (B, H, D), dtype)
    kc = rand(ks[1], (B, S, K, D), dtype)
    vc = rand(ks[2], (B, S, K, D), dtype)
    lengths = jnp.asarray([S // 2 + 7 * i + 1 for i in range(B)], jnp.int32)
    got = decode_attention(q, kc, vc, lengths, bs=bs, interpret=True)
    want = decode_attention_ref(q, kc, vc, lengths)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_decode_attention_single_valid_row():
    """length=1 edge case: attends only to the first cache row."""
    B, S, H, K, D = 1, 128, 2, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = rand(ks[0], (B, H, D), jnp.float32)
    kc = rand(ks[1], (B, S, K, D), jnp.float32)
    vc = rand(ks[2], (B, S, K, D), jnp.float32)
    lengths = jnp.asarray([1], jnp.int32)
    got = decode_attention(q, kc, vc, lengths, bs=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(vc[:, 0]),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------- fused pointwise conv kernel
from repro.kernels import conv1x1_fused
from repro.kernels.conv_pointwise.ref import conv1x1_ref


@pytest.mark.parametrize("H,W,Cin,Cout,block_rows", [
    (12, 12, 64, 128, 64),                  # MCU-shaped, uneven row blocks
    pytest.param(24, 24, 32, 64, 256, marks=pytest.mark.slow),
    pytest.param(7, 9, 3, 8, 16, marks=pytest.mark.slow),   # ragged padding
])
@pytest.mark.parametrize("bias,relu", [(True, True), (False, False)])
def test_conv1x1_fused_matches_ref(H, W, Cin, Cout, block_rows, bias, relu):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    x = rand(ks[0], (H, W, Cin), jnp.float32)
    w = rand(ks[1], (Cin, Cout), jnp.float32) * 0.1
    b = rand(ks[2], (Cout,), jnp.float32) if bias else None
    got = conv1x1_fused(x, w, b, relu=relu, block_rows=block_rows,
                        interpret=True)
    want = conv1x1_ref(x, w, b, relu=relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int32])
def test_conv1x1_fused_rejects_integer_input(dtype):
    """Regression: the float kernel's final ``astype(o_ref.dtype)`` would
    silently TRUNCATE the f32 accumulator on integer inputs instead of
    requantizing — it must refuse and point at the fused int8 kernels."""
    x = jnp.zeros((4, 4, 8), dtype)
    w = jnp.zeros((8, 8), jnp.float32)
    with pytest.raises(TypeError, match="qconv_fused"):
        conv1x1_fused(x, w, interpret=True)
