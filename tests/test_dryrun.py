"""Dry-run harness: shape policy logic (pure) + one end-to-end subprocess
lowering on the production mesh (the full 40×2 sweep runs via
`python -m repro.launch.dryrun`; its artifacts feed EXPERIMENTS.md)."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.shapes import (SHAPES, config_for_shape, shape_applicable)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_shape_policy():
    # whisper skips long_500k; everything else runs everything
    assert not shape_applicable(get_config("whisper-large-v3"), "long_500k")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if arch == "whisper-large-v3" and shape == "long_500k":
                continue
            assert shape_applicable(cfg, shape)


def test_long500k_gets_sliding_window_for_attention_archs():
    for arch in ("llama3.2-3b", "qwen2-7b", "glm4-9b", "phi3-medium-14b",
                 "internvl2-1b", "phi3.5-moe-42b-a6.6b"):
        cfg = config_for_shape(get_config(arch), "long_500k")
        assert cfg.sliding_window == 8192, arch
    for arch in ("zamba2-2.7b", "xlstm-350m"):
        cfg = config_for_shape(get_config(arch), "long_500k")
        assert cfg.sliding_window == 0, arch   # native sub-quadratic


def test_all_shapes_defined():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524_288


@pytest.mark.slow
def test_dryrun_end_to_end_subprocess():
    """Lower+compile one cheap combo on the real 256-device mesh in a fresh
    process (the 512-device XLA flag must be set before jax init)."""
    with tempfile.TemporaryDirectory() as out:
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "xlstm-350m", "--shape", "decode_32k",
             "--mesh", "single", "--no-unroll", "--out", out],
            env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
            capture_output=True, text=True, timeout=560)
        assert r.returncode == 0, r.stdout + r.stderr
        f = os.path.join(out, "xlstm-350m__decode_32k__single.json")
        rec = json.load(open(f))
        assert rec["chips"] == 256
        assert rec["memory_analysis"]["peak_memory_in_bytes"] > 0
        assert rec["roofline"]["dominant"] in (
            "compute_s", "memory_s", "collective_s")
