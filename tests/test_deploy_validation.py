"""Regression tests for ``Deployment.validate_inputs`` — one per
rejection.  The executor's own ``make_arena`` checks silently cast
float64 → float32 (jnp.asarray does it before the dtype check fires) and
silently accept any wrong shape with the right flat element count; on an
MCU deployment both are wrong-answer factories, so the facade rejects
them with a typed ``InputValidationError`` before the arena is touched.
Also covers the strict/non-strict build ladder and rungs validation.
"""
import numpy as np
import pytest

import repro.deploy as deploy
from repro.core import schedule
from repro.core.graph import Graph
from repro.errors import (BudgetUnreachableError, InputValidationError,
                          ReproError)
from repro.graphs import figure1_int8_graph, random_input
from repro.graphs.cnn_ops import CNNBuilder


def _float_cnn() -> Graph:
    g = Graph()
    b = CNNBuilder(g)
    x = b.input("input", 8, 8, 3)
    x = b.conv(x, 4, k=3)
    y = b.fc(x, 4)
    g.set_outputs([y])
    return g


@pytest.fixture(scope="module")
def d_float():
    return deploy.build(_float_cnn())


@pytest.fixture(scope="module")
def d_int8():
    return deploy.build(figure1_int8_graph())


def _good(d, seed=0):
    return random_input(d.exec_graph, seed=seed)


# ------------------------------------------------------------- rejections
def test_non_dict_inputs_rejected(d_float):
    with pytest.raises(InputValidationError, match="must be a dict"):
        d_float.run([1, 2, 3])


def test_missing_input_rejected(d_float):
    with pytest.raises(InputValidationError, match="missing graph inputs"):
        d_float.run({})


def test_unknown_tensor_rejected_with_hint(d_float):
    x = _good(d_float)
    x["not_a_tensor"] = np.zeros(1, np.float32)
    with pytest.raises(InputValidationError,
                       match="unknown input tensor 'not_a_tensor'"):
        d_float.run(x)


def test_produced_tensor_rejected(d_float):
    """Feeding an operator's output as an input must be refused — the
    arena program would just overwrite it, silently ignoring the value."""
    x = _good(d_float)
    produced = d_float.exec_graph.outputs[0]
    x[produced] = np.zeros(1, np.float32)
    with pytest.raises(InputValidationError, match="is produced by"):
        d_float.run(x)


def test_float64_silent_cast_rejected(d_float):
    """THE regression this layer exists for: jnp.asarray silently
    downcasts float64 → float32, so the old path accepted doubles and
    quietly served answers computed on truncated values."""
    x = _good(d_float)
    name = next(iter(x))
    x[name] = np.asarray(x[name], np.float64)
    with pytest.raises(InputValidationError,
                       match="float64.*declares float32"):
        d_float.run(x)


def test_int_input_on_float_graph_rejected(d_float):
    x = _good(d_float)
    name = next(iter(x))
    x[name] = np.zeros(np.asarray(x[name]).shape, np.int32)
    with pytest.raises(InputValidationError, match="int32"):
        d_float.run(x)


def test_float_input_on_int8_graph_names_quantize_hint(d_int8):
    """An un-quantized float fed to an int8 graph gets the actionable
    hint (quantize_inputs), not just a dtype mismatch."""
    x = _good(d_int8)
    name = next(iter(x))
    x[name] = np.asarray(x[name], np.float32)
    with pytest.raises(InputValidationError, match="quantize_inputs"):
        d_int8.run(x)


def test_wrong_shape_same_elements_rejected(d_float):
    """The silent-flatten regression: right element count, wrong shape
    used to be accepted and reshaped (transposing the layout wholesale)."""
    x = _good(d_float)
    name, val = next(iter(x.items()))
    val = np.asarray(val)
    if val.ndim < 2:
        pytest.skip("needs a multi-dim input")
    x[name] = np.ascontiguousarray(val.reshape(-1))
    with pytest.raises(InputValidationError,
                       match="refusing the silent flatten"):
        d_float.run(x)


def test_wrong_element_count_rejected(d_float):
    x = _good(d_float)
    name = next(iter(x))
    x[name] = np.zeros(3, np.float32)
    with pytest.raises(InputValidationError, match="elements"):
        d_float.run(x)


def test_non_finite_floats_rejected(d_float):
    for poison in (np.nan, np.inf, -np.inf):
        x = _good(d_float)
        name, val = next(iter(x.items()))
        val = np.array(val)
        val.flat[0] = poison
        x[name] = val
        with pytest.raises(InputValidationError, match="non-finite"):
            d_float.run(x)


def test_typed_error_is_catchable_as_repro_and_value_error(d_float):
    """InputValidationError subclasses both ReproError (library-wide
    catch) and ValueError (legacy callers)."""
    with pytest.raises(ReproError):
        d_float.run({})
    with pytest.raises(ValueError):
        d_float.run({})


def test_validate_false_escape_hatch(d_float):
    """validate=False restores the raw executor path (trusted inner-loop
    callers); good inputs produce identical outputs either way."""
    x = _good(d_float, seed=5)
    ref = d_float.run(x)
    out = d_float.run(x, validate=False)
    for name in d_float.exec_graph.outputs:
        np.testing.assert_array_equal(ref[name], out[name])


def test_good_inputs_pass_unchanged(d_float, d_int8):
    for d in (d_float, d_int8):
        d.validate_inputs(_good(d, seed=7))     # no raise


# ------------------------------------------------------ build strictness
def test_strict_budget_miss_raises_typed():
    with pytest.raises(BudgetUnreachableError, match="arena budget missed"):
        deploy.build(figure1_int8_graph(), arena_budget=1)


def test_nonstrict_budget_miss_records_degraded():
    d = deploy.build(figure1_int8_graph(), arena_budget=1, strict=False)
    assert any("arena budget missed" in n for n in d.degraded)
    # the deployment still serves correctly (best effort, not broken)
    x = _good(d)
    ref = deploy.build(figure1_int8_graph()).run(x)
    out = d.run(x)
    for name in d.exec_graph.outputs:
        np.testing.assert_array_equal(ref[name], out[name])


def test_strict_build_no_degradation_notes():
    d = deploy.build(figure1_int8_graph())
    assert d.degraded == []


def test_schedule_rejects_unknown_rung():
    with pytest.raises(ValueError, match="unknown scheduler rungs"):
        schedule(figure1_int8_graph(), rungs=("reorder", "warp_drive"))


def test_schedule_requires_reorder_rung():
    with pytest.raises(ValueError, match="reorder"):
        schedule(figure1_int8_graph(), rungs=("pex",))


def test_reorder_only_rungs_matches_full_ladder_on_small_graph():
    """figure1 needs no rewrites, so gating the ladder down to plain
    reordering must reproduce the full ladder's peak exactly."""
    g = figure1_int8_graph()
    assert schedule(g, rungs=("reorder",)).peak == schedule(g).peak
