"""The post-training int8 pass: calibration, rewrite invariants, accuracy.

Bit-identity of the int8 executors is covered by the differential grid in
``test_executor_diff.py``; this module pins the quantization *pass* itself:
qparams arithmetic, graph-rewrite structure (names/topology/byte sizes),
the calibration-free scheduling shadow, and end-to-end accuracy of the
quantized model against its float reference.
"""

import numpy as np
import pytest

from repro.core import schedule
from repro.core.graph import Graph
from repro.graphs import (
    int8_scheduling_graph,
    mobilenet_v1_graph,
    quantize_graph,
    random_input,
)
from repro.graphs.quantize import activation_qparams, weight_qparams
from repro.mcu import MicroInterpreter


def test_activation_qparams_zero_is_exact():
    """The range is widened to include 0 and zp is the image of real 0 —
    the property SAME padding and the relu clamp rely on."""
    for lo, hi in [(-1.3, 2.7), (0.2, 5.0), (-4.0, -1.0), (0.0, 0.0)]:
        qp = activation_qparams(lo, hi)
        assert -128 <= qp.zero_point <= 127
        assert qp.quantize(np.zeros(3)).tolist() == [qp.zero_point] * 3
        # representable range covers the observed one
        lo0, hi0 = min(0.0, lo), max(0.0, hi)
        assert qp.dequantize(np.int8(-128)) <= lo0 + qp.scale
        assert qp.dequantize(np.int8(127)) >= hi0 - qp.scale


def test_quantize_dequantize_roundtrip_error_bounded():
    qp = activation_qparams(-3.0, 3.0)
    x = np.linspace(-3, 3, 1001, dtype=np.float32)
    err = np.abs(qp.dequantize(qp.quantize(x)) - x)
    assert float(err.max()) <= qp.scale / 2 + 1e-7


def test_weight_qparams_symmetric():
    w = np.array([-0.5, 0.25, 0.5], np.float32)
    wq, s = weight_qparams(w)
    assert wq.dtype == np.int8
    assert wq.tolist() == [-127, 64, 127]  # round-half-even: 63.5 -> 64
    assert s == pytest.approx(0.5 / 127)


def _tiny_chain() -> Graph:
    from repro.graphs.cnn_ops import CNNBuilder

    g = Graph()
    b = CNNBuilder(g)
    x = b.input("input", 12, 12, 3)
    x = b.conv(x, 8, k=3, stride=2)
    x = b.dwconv(x, k=3)
    x = b.maxpool(x, k=2, stride=2)
    x = b.avgpool(x)
    x = b.fc(x, 4)
    g.set_outputs([x])
    return g


def test_rewrite_preserves_structure_and_quarters_bytes():
    g = _tiny_chain()
    qm = quantize_graph(g, random_input(g))
    q = qm.graph
    assert set(q.tensors) == set(g.tensors)
    assert [op.name for op in q.operators] == [op.name for op in g.operators]
    assert q.outputs == g.outputs
    for name, t in g.tensors.items():
        qt = q.tensors[name]
        assert qt.dtype == "int8" and 4 * qt.size == t.size
        assert qt.shape == t.shape
    for fop, qop in zip(g.operators, q.operators):
        assert qop.kind == "q" + fop.kind
        if "weight_bytes" in fop.attrs:
            assert 4 * qop.attrs["weight_bytes"] == fop.attrs["weight_bytes"]


def test_int8_scheduling_graph_matches_quantized_sizes():
    g = _tiny_chain()
    shadow = int8_scheduling_graph(g)
    real = quantize_graph(g, random_input(g)).graph
    for name in g.tensors:
        assert shadow.size(name) == real.size(name)
    assert schedule(shadow).peak == schedule(real).peak == schedule(g).peak // 4


def test_quantized_mobilenet_tracks_float_reference():
    """End-to-end accuracy: dequantized int8 outputs stay within a fraction
    of the output range of the f32 model (loose by design — this guards
    against sign/zero-point bugs, not against quantization error)."""
    g = mobilenet_v1_graph()  # 0.25 @ 96
    x = random_input(g)
    qm = quantize_graph(g, x)
    ref = MicroInterpreter(g).run(x)
    got = MicroInterpreter(qm.graph).run(qm.quantize_inputs(x))
    out = qm.dequantize_outputs({o: got.outputs[o] for o in qm.graph.outputs})
    for o in g.outputs:
        full_range = 255 * qm.qparams[o].scale
        err = np.max(np.abs(out[o] - ref.outputs[o]))
        assert err <= 0.2 * full_range, (err, full_range)


def test_quantize_rejects_unknown_kind():
    g = Graph()
    g.add_tensor("a", 16, (4,), dtype="float32")
    g.add_tensor("b", 16, (4,), dtype="float32")
    g.add_operator("op", ["a"], "b", kind="mystery", fn=lambda x: x * 2.0)
    g.set_outputs(["b"])
    with pytest.raises(ValueError, match="unsupported operator kind"):
        quantize_graph(g, {"a": np.ones((4,), np.float32)})


def test_interpreter_rejects_float_input_on_int8_graph():
    g = _tiny_chain()
    qm = quantize_graph(g, random_input(g))
    with pytest.raises(ValueError, match="declares int8"):
        MicroInterpreter(qm.graph).run(random_input(g))
