"""Roofline machinery: HLO collective census + three-term report."""
import pytest

from repro.analysis.roofline import (collective_bytes_from_hlo,
                                     model_flops, roofline_report)
from repro.configs import get_config

HLO = """
HloModule test
%x1 = f32[1024,256]{1,0} all-gather(%a), channel_id=1, replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
%x2 = bf16[512]{0} all-reduce(%b), replica_groups=[4,4]<=[16]
%x3 = (f32[128]{0}, f32[2048]{0}) all-gather-start(%c), replica_groups=[1,16]<=[16]
%x4 = f32[2048]{0} all-gather-done(%x3)
%x5 = f32[256,64]{1,0} reduce-scatter(%d), replica_groups={{0,1,2,3}}, dimensions={0}
%x6 = f32[64,64]{1,0} all-to-all(%e), replica_groups=[2,8]<=[16]
%x7 = bf16[32]{0} collective-permute(%f), source_target_pairs={{0,1}}
"""


def test_collective_census():
    out = collective_bytes_from_hlo(HLO)
    # all-gather: 1024*256*4 * 15/16
    assert out["all-gather"] == (1024 * 256 * 4) * 15 // 16 \
        + (2048 * 4) * 15 // 16
    # all-reduce: 2 * 512*2 * 3/4
    assert out["all-reduce"] == 2 * 512 * 2 * 3 // 4
    # reduce-scatter: result * (g-1), g=4
    assert out["reduce-scatter"] == 256 * 64 * 4 * 3
    assert out["all-to-all"] == 64 * 64 * 4 * 7 // 8
    assert out["collective-permute"] == 32 * 2
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_collective_census_ignores_non_collectives():
    txt = "%y = f32[8]{0} add(f32[8] %a, f32[8] %b)\n"
    assert collective_bytes_from_hlo(txt)["total"] == 0


def test_roofline_dominant_term():
    r = roofline_report(flops=197e12, bytes_accessed=819e9 * 2,
                        collective_bytes=50e9 * 0.5)
    # compute: 1s, memory: 2s, collective: 0.5s
    assert r["dominant"] == "memory_s"
    assert r["step_time_lb_s"] == pytest.approx(2.0)


def test_roofline_mfu_bound():
    r = roofline_report(flops=1e12, bytes_accessed=0.0, collective_bytes=0.0,
                        model_flops_global=256e12, chips=256)
    assert r["useful_flop_fraction"] == pytest.approx(1.0)
    assert r["mfu_bound"] == pytest.approx(1.0)


def test_model_flops_moe_uses_active_params():
    moe = get_config("phi3.5-moe-42b-a6.6b")
    dense_equiv = model_flops(moe, "train", 1000)
    assert dense_equiv == 6.0 * moe.active_param_count() * 1000
    # active ~6.6B << total ~42B
    assert moe.active_param_count() < 0.25 * moe.param_count()
