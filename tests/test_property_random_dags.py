"""Property tests (hypothesis) for the schedulers over random DAGs.

Sound ordering invariants:

* ``schedule()`` never returns a peak above the embedded (default) order —
  the tool must never make a model worse;
* the exact DP lower-bounds every heuristic: ``exact <= contracted`` (the
  contracted DP optimises over the subset of schedules that run each chain
  contiguously) and ``exact <= greedy``.

Note the deliberately *omitted* ``contracted <= greedy``: it is false in
general — greedy may interleave chains to free a held tensor mid-chain,
which the contracted DP cannot express.  Random sampling finds
counterexamples at about a 2% rate (e.g. contracted 120 vs greedy 102 on an
8-op DAG), so the suite pins only the sound direction.

Every schedule any method returns must pass ``graph.is_valid_schedule``.
"""
from hypothesis_compat import given, settings, st

from repro.core import (ArenaPlanner, Graph, beam_schedule, greedy_schedule,
                        minimise_peak_memory,
                        minimise_peak_memory_contracted, schedule)


def _build_dag(n_inputs, sizes, wiring):
    """Deterministic DAG from drawn data.  ``wiring[i]`` picks operator
    i's inputs (indices into the tensors created so far, modulo-folded so
    any drawn integers are valid)."""
    g = Graph()
    tensors = []
    for i in range(n_inputs):
        g.add_tensor(f"c{i}", sizes[i % len(sizes)])
        tensors.append(f"c{i}")
    for i, picks in enumerate(wiring):
        ins = sorted({tensors[p % len(tensors)] for p in picks})
        out = f"t{i}"
        g.add_tensor(out, sizes[(n_inputs + i) % len(sizes)])
        g.add_operator(f"op{i}", ins, out)
        tensors.append(out)
    sinks = [t for t in g.tensors
             if not g.consumers(t) and g.producer(t) is not None]
    g.set_outputs(sinks or [tensors[-1]])
    return g


@st.composite
def dags(draw):
    n_inputs = draw(st.integers(min_value=1, max_value=2))
    n_ops = draw(st.integers(min_value=2, max_value=8))
    sizes = draw(st.lists(st.integers(min_value=1, max_value=64),
                          min_size=3, max_size=6))
    wiring = [draw(st.lists(st.integers(min_value=0, max_value=9),
                            min_size=1, max_size=2))
              for _ in range(n_ops)]
    return _build_dag(n_inputs, sizes, wiring)


@given(dags())
@settings(max_examples=30, deadline=None)
def test_schedule_never_worse_than_default(g):
    default_peak = g.peak_usage(g.default_schedule())
    res = schedule(g)
    assert g.is_valid_schedule(res.schedule)
    assert res.peak <= default_peak
    assert g.peak_usage(res.schedule) == res.peak


@given(dags())
@settings(max_examples=30, deadline=None)
def test_exact_lower_bounds_heuristics(g):
    exact = minimise_peak_memory(g)
    greedy = greedy_schedule(g)
    assert g.is_valid_schedule(exact.schedule)
    assert g.is_valid_schedule(greedy.schedule)
    assert exact.peak <= greedy.peak
    contracted = minimise_peak_memory_contracted(g)
    if contracted is not None:
        assert g.is_valid_schedule(contracted.schedule)
        assert exact.peak <= contracted.peak


@given(dags())
@settings(max_examples=15, deadline=None)
def test_beam_returns_valid_schedule(g):
    res = beam_schedule(g, width=8)
    assert g.is_valid_schedule(res.schedule)
    assert res.peak >= minimise_peak_memory(g).peak


def _as_f32(g):
    """The same DAG with every tensor widened to float32 (4 bytes per
    element) — the byte-granular mirror of an int8 graph."""
    f = Graph()
    for name, t in g.tensors.items():
        f.add_tensor(name, 4 * t.size, t.shape, dtype="float32")
    for op in g.operators:
        f.add_operator(op.name, list(op.inputs), op.output, kind=op.kind)
    f.set_outputs(g.outputs)
    return f


def _contracted_beats_greedy_counterexample():
    """The minimal pinned counterexample to ``contracted <= greedy`` (found
    by random search over the ``dags()`` space, then shrunk to 3 ops and
    sizes {1, 2}).  The chain op0 -> op1 must run contiguously under the
    contracted DP, but the cheapest point to run op2 is *between* them —
    after op2's last input c0 can retire, before the 2-byte t1
    materialises — which only greedy can express."""
    g = Graph()
    g.add_tensor("c0", 2)
    g.add_tensor("c1", 1)
    g.add_tensor("t0", 1)
    g.add_tensor("t1", 2)
    g.add_tensor("t2", 1)
    g.add_operator("op0", ["c0", "c1"], "t0")
    g.add_operator("op1", ["t0"], "t1")
    g.add_operator("op2", ["c0"], "t2")
    g.set_outputs(["t1", "t2"])
    return g


def test_contracted_is_not_upper_bounded_by_greedy():
    """Regression pin for the documented ~2% unsoundness of assuming
    ``contracted <= greedy`` (see the module docstring): on this fixture
    the contracted DP is strictly WORSE than greedy, and ``schedule()``
    must therefore take the min over both rungs rather than trust the
    contracted result."""
    g = _contracted_beats_greedy_counterexample()
    contracted = minimise_peak_memory_contracted(g)
    greedy = greedy_schedule(g)
    assert contracted is not None
    assert contracted.peak == 5
    assert greedy.peak == 4
    assert contracted.peak > greedy.peak      # the pinned counterexample
    res = schedule(g)
    assert res.peak <= min(contracted.peak, greedy.peak)
    assert res.peak == minimise_peak_memory(g).peak == 4


@given(dags())
@settings(max_examples=30, deadline=None)
def test_int8_arena_never_exceeds_f32(g):
    """Byte-granular quantization invariant: for ANY dag, the int8 build's
    peak and planned arena never exceed the f32 build's.  In fact the
    optimum scales exactly by the itemsize (all sizes scale uniformly), so
    the stronger 4x equality is asserted for the peak."""
    f = _as_f32(g)
    rq, rf = schedule(g), schedule(f)
    assert 4 * rq.peak == rf.peak
    pq = ArenaPlanner.plan(g, rq.schedule)
    pf = ArenaPlanner.plan(f, rf.schedule)
    ArenaPlanner.validate(pq, g)
    ArenaPlanner.validate(pf, f)
    assert pq.arena_size <= pf.arena_size
