"""Fused int8 kernels vs the q-op reference semantics: differential
bit-identity grids in interpret mode, plus the compiled executor end-to-end
with ``use_pallas=True`` and zero-copy ring reads.

Unlike the float conv kernel (tolerance-checked: f32 accumulation order
differs), every assertion here is ``assert_array_equal``: int32 accumulation
of int8 products is exact and order-independent, and the kernels replay the
reference requantize sequence literally — so the fused path must cost zero
ULPs, on every shape, stride and padding the MCU graphs produce."""
import numpy as np
import pytest

import jax.numpy as jnp

import jax

from repro.core import ArenaPlanner, schedule
from repro.core.graph import Graph
from repro.core.partition import cascade_graph
from repro.graphs import quantize_graph, random_input
from repro.graphs.cnn_ops import CNNBuilder, qadd, qconv2d, qdwconv2d
from repro.kernels import qconv_add_fused, qconv_fused, qdwconv_fused
from repro.mcu import MicroInterpreter, compile_schedule


def qrand(rng, shape):
    return jnp.asarray(rng.integers(-128, 128, size=shape, dtype=np.int8))


# --------------------------------------------------------- differential grids
# The first two cases per kernel are the fast-tier smoke; the rest run in the
# slow tier.  Deliberately hostile shapes: odd H/W (ragged row blocks),
# 1-lane channels, stride 2, asymmetric ``hpad`` overrides (a Pex slice's
# zp-padded halo), and tiny ``block_rows`` so the grid always has several
# steps with a ragged tail.
_CONV_GRID = [
    # H, W, Cin, Cout, k, stride, hpad, block_rows
    (12, 12, 8, 16, 1, 1, None, 40),          # 1x1 fast path, ragged blocks
    (11, 9, 4, 6, 3, 2, None, 2),             # odd shape, stride 2
    pytest.param(7, 9, 1, 5, 3, 1, None, 4, marks=pytest.mark.slow),
    pytest.param(10, 8, 3, 7, 3, 1, (0, 2), 4,       # Pex mid-slice pads
                 marks=pytest.mark.slow),
    pytest.param(9, 7, 5, 1, 3, 2, (2, 0), 4,        # 1-lane Cout, top halo
                 marks=pytest.mark.slow),
    pytest.param(8, 8, 3, 4, 5, 2, None, 3, marks=pytest.mark.slow),  # k=5
]

_DW_GRID = [
    # H, W, C, k, stride, hpad, block_rows
    (11, 9, 8, 3, 1, None, 4),                # odd shape
    (12, 10, 6, 3, 2, None, 2),               # stride 2
    pytest.param(9, 7, 1, 3, 1, None, 4, marks=pytest.mark.slow),  # 1 lane
    pytest.param(10, 8, 5, 3, 1, (2, 0), 4, marks=pytest.mark.slow),
    pytest.param(9, 9, 4, 3, 2, (0, 2), 3, marks=pytest.mark.slow),
]

# Non-trivial quantization params: fractional multiplier exercising
# round-half-even, off-zero input/output zero-points (so halo padding and
# the fused ReLU clamp are both off the integer origin).
_QP = dict(mult=0.0123, zp_in=3, zp_out=-5)


@pytest.mark.parametrize("H,W,Cin,Cout,k,stride,hpad,block_rows", _CONV_GRID)
def test_qconv_fused_bit_identical(H, W, Cin, Cout, k, stride, hpad,
                                   block_rows):
    rng = np.random.default_rng(11)
    x = qrand(rng, (H, W, Cin))
    w = qrand(rng, (k, k, Cin, Cout))
    got = qconv_fused(x, w, stride=stride, hpad=hpad,
                      block_rows=block_rows, interpret=True, **_QP)
    want = qconv2d(x, w, stride, _QP["mult"], _QP["zp_in"], _QP["zp_out"],
                   hpad=hpad)
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("H,W,C,k,stride,hpad,block_rows", _DW_GRID)
def test_qdwconv_fused_bit_identical(H, W, C, k, stride, hpad, block_rows):
    rng = np.random.default_rng(13)
    x = qrand(rng, (H, W, C))
    w = qrand(rng, (k, k, C, 1))
    got = qdwconv_fused(x, w, stride=stride, hpad=hpad,
                        block_rows=block_rows, interpret=True, **_QP)
    want = qdwconv2d(x, w, stride, _QP["mult"], _QP["zp_in"], _QP["zp_out"],
                     hpad=hpad)
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# Residual-leg quantization params for the fused conv->add chain, in the
# ``qadd`` argument order (mult_a, mult_b, zp_a, zp_b, zp_out); leg *a* is
# the conv's output, so its zero-point is the conv's ``zp_out``.
_ADDP = (0.71, 0.39, _QP["zp_out"], 2, -7)

_CONV_ADD_GRID = [
    # H, W, Cin, Cout, k, stride, hpad, block_rows
    (12, 12, 8, 16, 1, 1, None, 40),          # 1x1 fast path, ragged blocks
    (11, 9, 4, 6, 3, 2, None, 2),             # odd shape, stride 2
    pytest.param(10, 8, 3, 7, 3, 1, (0, 2), 4,       # Pex mid-slice pads
                 marks=pytest.mark.slow),
    pytest.param(9, 7, 5, 1, 3, 2, (2, 0), 4,        # 1-lane Cout, top halo
                 marks=pytest.mark.slow),
]


@pytest.mark.parametrize("H,W,Cin,Cout,k,stride,hpad,block_rows",
                         _CONV_ADD_GRID)
def test_qconv_add_fused_bit_identical(H, W, Cin, Cout, k, stride, hpad,
                                       block_rows):
    """Fused conv->add (residual requant folded into the conv kernel's
    epilogue) vs the two-op reference chain ``qconv2d -> qadd``: the
    intermediate conv output never leaves VMEM, yet every element must
    match bit-for-bit."""
    rng = np.random.default_rng(7)
    x = qrand(rng, (H, W, Cin))
    w = qrand(rng, (k, k, Cin, Cout))
    want_conv = qconv2d(x, w, stride, _QP["mult"], _QP["zp_in"],
                        _QP["zp_out"], hpad=hpad)
    r = qrand(rng, want_conv.shape)
    want = qadd(want_conv, r, *_ADDP)
    got = qconv_add_fused(x, w, r, stride=stride, hpad=hpad,
                          add_params=_ADDP, block_rows=block_rows,
                          interpret=True, **_QP)
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qadd_fixed_point_jit_invariant():
    """``qadd`` must produce the same bits eager and jitted.  The
    fixed-point formulation exists precisely for this: with f32
    multipliers XLA's CPU codegen contracts the mul->add into a
    single-rounded FMA under jit (and ``optimization_barrier`` does not
    survive codegen), silently changing results vs eager — integer
    arithmetic cannot contract."""
    rng = np.random.default_rng(23)
    a = qrand(rng, (9, 11, 6))
    b = qrand(rng, (9, 11, 6))
    args = (0.37, 0.61, 3, -2, 5)
    eager = qadd(a, b, *args)
    jitted = jax.jit(qadd, static_argnums=(2, 3, 4, 5, 6))(a, b, *args)
    assert eager.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))


def test_qconv_fused_saturates_both_rails():
    """Extreme multiplier: outputs must pin to the int8 rails (the ReLU
    clamp floor is ``zp_out``, the ceiling INT8_MAX), never wrap."""
    rng = np.random.default_rng(17)
    x = qrand(rng, (6, 6, 4))
    w = qrand(rng, (3, 3, 4, 8))
    got = np.asarray(qconv_fused(x, w, stride=1, mult=1.0, zp_in=0,
                                 zp_out=-5, interpret=True))
    want = np.asarray(qconv2d(x, w, 1, 1.0, 0, -5))
    np.testing.assert_array_equal(got, want)
    assert got.min() >= -5 and got.max() <= 127
    assert (got == -5).any() and (got == 127).any()


# ------------------------------------------------------------- end-to-end
def _chain_cnn() -> Graph:
    """A small sequential CNN (cascadable chain) mixing every fused-kernel
    shape: k=3 conv, depthwise (stride 1 and 2), 1x1 pointwise."""
    g = Graph()
    b = CNNBuilder(g)
    x = b.input("input", 20, 20, 4)
    x = b.conv(x, 8, k=3)
    x = b.dwconv(x, k=3)
    x = b.conv(x, 12, k=1)
    x = b.dwconv(x, k=3, stride=2)
    x = b.conv(x, 8, k=1)
    g.set_outputs([x])
    return g


def test_compiled_use_pallas_bit_identical_e2e():
    """The compiled executor with ``use_pallas=True`` routes every int8
    conv through the fused kernels and must match the interpreter
    bit-for-bit — the acceptance gate for swapping the kernels in."""
    g = _chain_cnn()
    gq = quantize_graph(g, random_input(g)).graph
    sched = schedule(gq).schedule
    plan = ArenaPlanner.plan(gq, sched)
    x = random_input(gq)
    ref = MicroInterpreter(gq).run(x, schedule=sched)
    ex = compile_schedule(gq, sched, plan, use_pallas=True, interpret=True)
    out = ex.run(x)
    for o in gq.outputs:
        np.testing.assert_array_equal(ref.outputs[o], out[o])
    assert ex.arena_size == plan.arena_size    # kernels change no placement


def test_zero_copy_ring_reads_bit_identical():
    """Cascade ring reads fuse into their consumers (no arena round-trip):
    the zero-copy executor must count fused reads, keep the arena plan
    byte-identical, and agree bit-for-bit with the interpreter and with
    the copying executor — with and without the fused kernels."""
    g = _chain_cnn()
    gq = quantize_graph(g, random_input(g)).graph
    peak = gq.peak_usage(gq.default_schedule())
    cr = cascade_graph(gq, budget=int(peak * 0.6))
    assert cr.cascades, "chain must cascade under a 0.6x budget"
    gp = cr.graph
    sched = gp.default_schedule()
    plan = ArenaPlanner.plan(gp, sched)
    x = random_input(gq)
    ref = MicroInterpreter(gp).run(x, schedule=sched)
    copying = compile_schedule(gp, sched, plan, zero_copy_rings=False)
    assert copying.zero_copy_reads == 0
    for use_pallas in (False, True):
        ex = compile_schedule(gp, sched, plan, use_pallas=use_pallas,
                              interpret=True)
        assert ex.zero_copy_reads > 0
        assert ex.arena_size == plan.arena_size
        out = ex.run(x)
        for o in gp.outputs:
            np.testing.assert_array_equal(ref.outputs[o], out[o])
            np.testing.assert_array_equal(copying.run(x)[o], out[o])
