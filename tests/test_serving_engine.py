"""Serving-tier tests: the ``repro.deploy`` facade, ragged-tail padding
accounting, typed ``EngineStats``, and admission-order invariance of the
sharded continuous-batching engine.

The invariance contract is the serving-layer analogue of the executor's
bit-identity contract: whatever the arrival interleaving (one-shot serve,
submit/step interleavings, ragged tails) and whatever replica/lane a
request lands on, its outputs are **bit-identical** to a one-shot
``Deployment.run`` of that request alone.  A subprocess leg re-runs the
grid on a forced 3-device host mesh so real multi-replica pmap assignment
is covered, not just the degenerate 1-device mesh of the test process.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import repro.deploy as deploy
from repro.graphs import figure1_int8_graph, quantize_graph, random_input
from repro.graphs.cnn_ops import CNNBuilder
from repro.core.graph import Graph
from repro.serving import (EngineStats, GraphServingEngine,
                           ShardedServingEngine, percentile_ms)


def _tiny_cnn() -> Graph:
    g = Graph()
    b = CNNBuilder(g)
    x = b.input("input", 12, 12, 3)
    x = b.conv(x, 6, k=3)
    y = b.maxpool(x, k=2, stride=2)
    y = b.conv(y, 6, k=1)
    y = b.avgpool(y)
    y = b.fc(y, 4)
    g.set_outputs([y])
    return g


def _tiny_cnn_int8() -> Graph:
    g = _tiny_cnn()
    return quantize_graph(g, random_input(g)).graph


# fixed-seed grid: the int8 golden graph plus a quantized CNN and its
# float build (three dtype/shape regimes through the same engines)
_GRID = {
    "figure1_int8": figure1_int8_graph,
    "tiny_cnn_int8": _tiny_cnn_int8,
    "tiny_cnn_f32": _tiny_cnn,
}


def _requests(g, n, seed0=0):
    return [random_input(g, seed=seed0 + i) for i in range(n)]


# ------------------------------------------------------------------ facade
def test_deploy_build_matches_manual_chain():
    """build() is exactly the schedule→plan→validate→compile chain."""
    from repro.core import ArenaPlanner, schedule
    from repro.mcu import compile_schedule

    g = figure1_int8_graph()
    d = deploy.build(g)
    res = schedule(g)
    assert d.schedule_result.peak == res.peak
    assert [op.name for op in d.schedule] == [op.name for op in res.schedule]
    plan = ArenaPlanner.plan(g, res.schedule)
    assert d.arena_bytes == plan.arena_size
    x = random_input(g)
    ref = compile_schedule(g, res.schedule, plan).run(x)
    out = d.run(x)
    for o in g.outputs:
        np.testing.assert_array_equal(ref[o], out[o])


def test_deploy_quantize_builds_int8():
    g = _tiny_cnn()
    d = deploy.build(g, quantize=True)
    assert d.qmodel is not None
    assert all(t.dtype == "int8" for t in d.exec_graph.tensors.values())
    xq = d.quantize_inputs(random_input(g))
    out = d.run(xq)
    deq = d.dequantize_outputs(out)
    for o in g.outputs:
        assert out[o].dtype == np.int8
        assert deq[o].dtype != np.int8


def test_deploy_stats_typed():
    d = deploy.build(figure1_int8_graph())
    s = d.stats
    assert isinstance(s, EngineStats)
    j = s.as_json()
    assert j["arena_bytes"] == d.arena_bytes > 0
    assert j["schedule_method"]
    # never-measured serve fields stay out of the payload
    assert "requests_per_s" not in j and "p99_ms" not in j


def test_engine_stats_legacy_keys():
    s = EngineStats(arena_bytes=7, dispatches=3)
    assert s["arena_bytes"] == 7
    assert s["micro_batches"] == 3          # legacy spelling of dispatches
    assert "micro_batches" in s
    with pytest.raises(KeyError):
        s["no_such_stat"]


def test_percentile_ms():
    lat = [0.001 * (i + 1) for i in range(100)]
    assert percentile_ms(lat, 50) == pytest.approx(50.0, abs=1.5)
    assert percentile_ms(lat, 99) == pytest.approx(99.0, abs=1.5)
    assert percentile_ms([], 99) == 0.0


# -------------------------------------------------------- ragged-tail fix
def test_ragged_tail_accounting_and_outputs():
    """Regression: a ragged final micro-batch must (a) return correct
    outputs for every true request, (b) report true request count vs pad
    lanes explicitly, (c) keep pad lanes out of per-request stats."""
    g = _tiny_cnn()
    d = deploy.build(g)
    eng = GraphServingEngine(deployment=d, micro_batch=4)
    reqs = _requests(g, 6)                  # 4 + ragged tail of 2 (2 pads)
    outs = eng.serve(reqs)
    assert len(outs) == 6
    for r, o in zip(reqs, outs):
        ref = d.run(r)
        for name in g.outputs:
            np.testing.assert_array_equal(ref[name], o[name])
    st = eng.stats
    assert st.requests == 6
    assert st.padded_lanes == 2
    assert st.dispatches == 2
    assert len(outs) == st.requests         # pads never extracted
    assert st.requests_per_s > 0 and st.p99_ms >= st.p50_ms > 0
    j = st.as_json()
    assert j["requests"] == 6 and j["padded_lanes"] == 2


def test_no_padding_on_exact_batches():
    g = _tiny_cnn()
    eng = GraphServingEngine(g, micro_batch=2)
    eng.serve(_requests(g, 4))
    assert eng.stats.padded_lanes == 0
    assert eng.stats.dispatches == 2


# ---------------------------------------------- admission-order invariance
@pytest.mark.parametrize("name", sorted(_GRID))
def test_sharded_outputs_invariant_under_interleaving(name):
    """Per-request outputs are bit-identical to one-shot Deployment.run,
    regardless of how submits interleave with dispatch boundaries."""
    g = _GRID[name]()
    d = deploy.build(g)
    reqs = _requests(g, 7, seed0=11)
    refs = [d.run(r) for r in reqs]

    eng = ShardedServingEngine(d, lanes=2)

    # interleaving A: everything up front (one-shot serve, ragged tail)
    outs = eng.serve(reqs)
    for ref, o in zip(refs, outs):
        for t in g.outputs:
            np.testing.assert_array_equal(ref[t], o[t])

    # interleaving B: late arrivals join later dispatch boundaries
    rids = [eng.submit(reqs[0]), eng.submit(reqs[1])]
    eng.step()                               # boundary: 0,1 complete
    rids += [eng.submit(r) for r in reqs[2:5]]
    eng.step()                               # boundary: 2,3 (lanes=2) ...
    rids += [eng.submit(r) for r in reqs[5:]]
    done = eng.drain()
    assert sorted(done) == sorted(rids)
    for ref, rid in zip(refs, rids):
        for t in g.outputs:
            np.testing.assert_array_equal(ref[t], done[rid][t])
    st = eng.stats
    assert st.requests == 7 and st.dispatches >= 3


def test_sharded_admission_is_fifo_at_boundaries():
    g = figure1_int8_graph()
    eng = ShardedServingEngine(deploy.build(g), lanes=2)
    a = eng.submit(random_input(g, seed=1))
    b = eng.submit(random_input(g, seed=2))
    c = eng.submit(random_input(g, seed=3))
    done_now = eng.step()                    # capacity 2: admits a, b only
    assert done_now == 2
    assert eng.pending == 1
    out_a = eng.take(a)
    out_b = eng.take(b)
    out_c = eng.drain()[c]                  # drain returns what's left
    for out, seed in ((out_a, 1), (out_b, 2), (out_c, 3)):
        ref = deploy.build(g).run(random_input(g, seed=seed))
        for t in g.outputs:
            np.testing.assert_array_equal(ref[t], out[t])


def test_sharded_rejects_build_opts_on_deployment():
    d = deploy.build(figure1_int8_graph())
    with pytest.raises(ValueError, match="already a Deployment"):
        ShardedServingEngine(d, arena_budget=1024)


_MULTI_DEVICE_SCRIPT = """
from repro.serving import force_host_devices
force_host_devices(3)
import jax
assert jax.local_device_count() == 3, jax.devices()
import numpy as np
import repro.deploy as deploy
from repro.graphs import figure1_int8_graph, random_input
from repro.serving import ShardedServingEngine

g = figure1_int8_graph()
d = deploy.build(g)
reqs = [random_input(g, seed=20 + i) for i in range(8)]
refs = [d.run(r) for r in reqs]
eng = ShardedServingEngine(d, replicas=3, lanes=2)
assert eng.replicas == 3 and eng.capacity == 6
outs = eng.serve(reqs)                  # 8 over capacity 6: ragged 2nd step
for ref, o in zip(refs, outs):
    for t in g.outputs:
        np.testing.assert_array_equal(ref[t], o[t])
st = eng.stats
assert st.dispatches == 2 and st.padded_lanes == 4 and st.requests == 8
print("MULTI_OK")
"""


def test_sharded_multi_replica_bit_identical_subprocess():
    """Real replica assignment: a forced 3-device host mesh (must be set
    before jax init, hence the subprocess) with requests landing on every
    replica — outputs stay bit-identical to single-request execution."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _MULTI_DEVICE_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=300)
    assert proc.returncode == 0, f"\n{proc.stdout}\n{proc.stderr}"
    assert "MULTI_OK" in proc.stdout
