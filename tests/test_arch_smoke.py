"""Per-architecture smoke tests: instantiate the reduced same-family config,
run one forward/train step and one prefill→decode step on CPU, assert output
shapes and no NaNs.  The FULL configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import Model, init_params

pytestmark = pytest.mark.slow   # integration tier; see pytest.ini

S = 32  # smoke sequence length


def make_batch(cfg, B=2, S=S, seed=0):
    rng = np.random.default_rng(seed)
    n_text = S - (cfg.num_patch_tokens or 0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, n_text)), jnp.int32)}
    if cfg.num_patch_tokens:
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_patch_tokens, cfg.frontend_dim)),
            jnp.float32)
    if cfg.arch_type == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.frontend_dim)),
            jnp.float32)
    return batch


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(f"{arch}@smoke")
            params = init_params(cfg, jax.random.PRNGKey(0))
            cache[arch] = (cfg, Model(cfg), params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch, built):
    cfg, model, params = built(arch)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: model.loss_fn(p, b, remat=False))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert np.isfinite(float(metrics["ce"]))
    # gradients exist and are finite for a couple of leaves
    g = jax.grad(lambda p: model.loss_fn(p, batch, remat=False)[0])(params)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(np.isfinite(np.asarray(x)).all() for x in leaves[:4])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(arch, built):
    cfg, model, params = built(arch)
    B = 2
    batch = make_batch(cfg, B=B)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = jax.jit(model.decode_step)(params, cache, tok)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all(), arch
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["llama3.2-3b", "zamba2-2.7b", "xlstm-350m"])
def test_decode_matches_prefill_continuation(arch, built):
    """Decoding token t must equal prefilling t+1 tokens (cache coherence)."""
    cfg, model, params = built(arch)
    B = 2
    full = make_batch(cfg, B=B, S=S)
    short = {k: (v[:, :-1] if k == "tokens" else v) for k, v in full.items()}
    logits_full, _ = jax.jit(model.prefill)(params, full)
    # cache must have room for the extra decoded token
    logits_short, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=S + 1))(params, short)
    last_tok = full["tokens"][:, -1]
    logits_dec, _ = jax.jit(model.decode_step)(params, cache, last_tok)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full),
                               rtol=2e-2, atol=2e-2)


def test_sliding_window_variant_decodes():
    cfg = get_config("llama3.2-3b@smoke").with_sliding_window(16)
    model = Model(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, B=1, S=S)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert cache["k"].shape[2] == 16          # rolling window capacity
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = jax.jit(model.decode_step)(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert np.isfinite(np.asarray(logits)).all()


def test_param_count_sanity_full_configs():
    """Full-config analytic parameter counts are in the advertised range."""
    expected = {
        "phi3.5-moe-42b-a6.6b": (35e9, 50e9),
        "llama3.2-3b": (2.5e9, 4.5e9),
        "qwen2-7b": (6e9, 9e9),
        "phi3-medium-14b": (12e9, 16e9),
        "glm4-9b": (8e9, 11e9),
        "granite-moe-1b-a400m": (0.8e9, 1.8e9),
        "zamba2-2.7b": (2e9, 4e9),
        "xlstm-350m": (0.2e9, 0.6e9),
        "whisper-large-v3": (1e9, 2.2e9),
        "internvl2-1b": (0.5e9, 1.2e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
