from .roofline import (HardwareSpec, TPU_V5E, collective_bytes_from_hlo,
                       roofline_report)

__all__ = ["HardwareSpec", "TPU_V5E", "collective_bytes_from_hlo",
           "roofline_report"]
