"""Three-term roofline model from a compiled dry-run artifact.

    compute    = HLO_FLOPs_per_device   / peak_FLOP/s
    memory     = HLO_bytes_per_device   / HBM_bw
    collective = collective_bytes_per_device / link_bw

``compiled.cost_analysis()`` on a GSPMD-partitioned module reports the
*per-device* program, so no further division by chip count is needed (the
formula's /chips is the partitioning itself; verified in tests against an
analytic FLOP count).  Collective bytes are not in cost_analysis — they are
summed from the operand shapes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute in the optimized HLO text,
which is likewise the per-device program.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    link_bw: float             # bytes/s per ICI link
    hbm_bytes: float


TPU_V5E = HardwareSpec("tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                       link_bw=50e9, hbm_bytes=16e9)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(.*?)\s?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?(?:\.\d+)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _tensor_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:                        # iota format [G,S]<=...: S devices/group
        return max(int(m.group(2)), 1)
    m = _GROUPS_V1_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-device link traffic per collective kind, from the optimized HLO
    (a per-device program after SPMD partitioning).

    Post-scheduling HLO prints operands as bare names, so sizes come from
    each collective's RESULT shape(s), converted to traffic with the
    standard ring-algorithm cost models (g = replica-group size):

      all-gather          result*(g-1)/g      (bytes received per device)
      all-reduce          2*result*(g-1)/g    (reduce-scatter + all-gather)
      reduce-scatter      result*(g-1)        (operand = result*g)
      all-to-all          result*(g-1)/g
      collective-permute  result
    """
    out: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind, suffix = m.group(2), m.group(3)
        if suffix == "-done":
            continue        # async pair: counted at the -start
        sizes = [_tensor_bytes(dm.group(1), dm.group(2))
                 for dm in _SHAPE_RE.finditer(m.group(1))]
        if not sizes:
            continue
        # -start ops return (operand, result[, scratch]) tuples: the logical
        # result is the largest element; plain ops may return tuples too
        # (combined collectives) -> sum, but for starts take the max.
        result_bytes = max(sizes) if suffix == "-start" else sum(sizes)
        g = _group_size(line)
        if kind == "all-gather":
            traffic = result_bytes * (g - 1) // max(g, 1)
        elif kind == "all-reduce":
            traffic = 2 * result_bytes * (g - 1) // max(g, 1)
        elif kind == "reduce-scatter":
            traffic = result_bytes * (g - 1)
        elif kind == "all-to-all":
            traffic = result_bytes * (g - 1) // max(g, 1)
        else:                                  # collective-permute
            traffic = result_bytes
        out[kind] += traffic
    out["total"] = sum(out[k] for k in COLLECTIVES)
    return out


def roofline_report(*, flops: float, bytes_accessed: float,
                    collective_bytes: float,
                    hw: HardwareSpec = TPU_V5E,
                    model_flops_global: Optional[float] = None,
                    chips: int = 1) -> Dict[str, float]:
    """All inputs are per-device quantities except model_flops_global."""
    t_compute = flops / hw.peak_flops
    t_memory = bytes_accessed / hw.hbm_bw
    t_collective = collective_bytes / hw.link_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dom = max(terms, key=terms.get)
    bound = max(t_compute, t_memory, t_collective)
    report = dict(terms)
    report["dominant"] = dom
    report["step_time_lb_s"] = bound
    if model_flops_global is not None:
        useful = model_flops_global / max(chips, 1)
        report["model_flops_per_device"] = useful
        report["useful_flop_fraction"] = useful / flops if flops else 0.0
        # MFU lower bound implied by the dominant term
        report["mfu_bound"] = (useful / hw.peak_flops) / bound \
            if bound > 0 else 0.0
    return report


def model_flops(cfg, shape_kind: str, tokens: int) -> float:
    """6·N·D (training) / 2·N·D (inference) with N = active params."""
    n = cfg.active_param_count()
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n * tokens
