"""A SwiftNet-Cell-like CNN (Cheng et al., VWW 2019 winning submission used in
the paper's Table 1).  The exact cell graph is not published; we reconstruct a
faithful *shape*: a branchy cell — 1x1 bottleneck feeding two asymmetric
paths (1x1→3x3dw→1x1 and 3x3dw→1x1) joined by concat — repeated over four
resolution stages on a 96×96×3 person-detection input, ≈250 KB of int8
parameters, with the same property the paper exploits: the embedded
(insertion) operator order is memory-suboptimal and reordering recovers tens
of KB of SRAM.
"""
from __future__ import annotations

from repro.core.graph import Graph
from .cnn_ops import CNNBuilder


def _cell(b: CNNBuilder, x: str, mid: int, expand: int, out_a: int,
          out_b: int, stride: int = 1) -> str:
    if stride > 1:
        x = b.dwconv(x, k=3, stride=stride)
    t1 = b.conv(x, mid, k=1)
    # branch A (long, fat): 1x1 expand -> 3x3 dw -> 1x1 project
    a1 = b.conv(t1, expand, k=1)
    a2 = b.dwconv(a1, k=3)
    a3 = b.conv(a2, out_a, k=1)
    # branch B (short, thin): 1x1 project -> 3x3 dw
    b1 = b.conv(t1, out_b, k=1)
    b2 = b.dwconv(b1, k=3)
    return b.concat([a3, b2])


def swiftnet_cell_graph() -> Graph:
    g = Graph()
    b = CNNBuilder(g)
    x = b.input("input", 96, 96, 3)
    x = b.conv(x, 12, k=3, stride=1)          # stem, 96x96x12 (108 KB)
    x = _cell(b, x, mid=22, expand=9, out_a=8, out_b=2)       # 96x96x10
    x = _cell(b, x, mid=40, expand=20, out_a=24, out_b=8, stride=2)   # 48² x32
    x = _cell(b, x, mid=80, expand=40, out_a=48, out_b=16, stride=2)  # 24² x64
    x = _cell(b, x, mid=160, expand=80, out_a=96, out_b=32, stride=2) # 12² x128
    x = b.dwconv(x, k=3, stride=2)                            # 6x6x128
    x = b.conv(x, 384, k=1)                                   # 6x6x384
    x = b.avgpool(x)
    x = b.fc(x, 2)
    g.set_outputs([x])
    return g
