"""MobileNet-v1 0.25x @ 96×96 (the TFLite-Micro person-detection model used
in the paper's Table 1 static-vs-dynamic allocation comparison).

This graph is a pure chain, so operator reordering cannot help — exactly the
paper's point: the 241 KB → 55 KB saving there comes from *dynamic allocation*
(freeing dead tensors) instead of static all-tensors-resident planning.
"""
from __future__ import annotations

from repro.core.graph import Graph
from .cnn_ops import CNNBuilder

# (stride of dw, full-width output channels of pw) for the 13 blocks;
# alpha is applied at build time.
_BLOCKS = [(1, 64), (2, 128), (1, 128), (2, 256), (1, 256),
           (2, 512), (1, 512), (1, 512), (1, 512), (1, 512), (1, 512),
           (2, 1024), (1, 1024)]


def mobilenet_v1_graph(alpha: float = 0.25, resolution: int = 96) -> Graph:
    g = Graph()
    b = CNNBuilder(g)
    x = b.input("input", resolution, resolution, 3)
    x = b.conv(x, int(32 * alpha), k=3, stride=2)
    for stride, cout in _BLOCKS:
        x = b.dwconv(x, k=3, stride=stride)
        x = b.conv(x, int(cout * alpha), k=1)
    x = b.avgpool(x)
    x = b.fc(x, 2)
    g.set_outputs([x])
    return g
