"""The paper's Figure-1 example graph, reconstructed exactly from Appendix A.

Solving the working-set tables of Figures 2/3 gives the tensor sizes:
  t0=1568 (input), t1=3136, t2=1568, t3=512, t4=512, t5=256, t6=256, t7=512
and the structure: two branches off t1 — (op2→op3→op5) and (op4→op6) —
joined by a concat (op7):

    t0 ──op1──► t1 ──op2──► t2 ──op3──► t3 ──op5──► t5 ─┐
                 └──op4──► t4 ──op6──► t6 ───────────────┴─op7──► t7

Default order 1..7 peaks at 5,216 B (at op3); optimal order
1,4,6,2,3,5,7 peaks at 4,960 B (at op2) — Table rows reproduced in tests.
"""
from __future__ import annotations

from repro.core.graph import Graph

SIZES = {"t0": 1568, "t1": 3136, "t2": 1568, "t3": 512,
         "t4": 512, "t5": 256, "t6": 256, "t7": 512}

DEFAULT_PEAK = 5216
OPTIMAL_PEAK = 4960


def _wire_ops(g: Graph) -> None:
    g.add_operator("op1", ["t0"], "t1", kind="conv2d")
    g.add_operator("op2", ["t1"], "t2", kind="conv2d")
    g.add_operator("op3", ["t2"], "t3", kind="conv2d")
    g.add_operator("op4", ["t1"], "t4", kind="conv2d")
    g.add_operator("op5", ["t3"], "t5", kind="conv2d")
    g.add_operator("op6", ["t4"], "t6", kind="conv2d")
    g.add_operator("op7", ["t5", "t6"], "t7", kind="concat")
    g.set_outputs(["t7"])


def figure1_graph() -> Graph:
    g = Graph()
    for name, size in SIZES.items():
        g.add_tensor(name, size)
    _wire_ops(g)
    return g


def figure1_executable_graph() -> Graph:
    """figure1 with deterministic f32 semantics attached, so the executors
    (micro-interpreter and compiled) can run it — the paper's figure is a
    scheduling exemplar and ships without numerics.  The byte sizes are the
    paper's, so as a float32 graph each tensor holds ``size // 4`` elements
    (the memory model is byte-granular; dtype honesty is what the executors
    verify).  Shared by the differential tests and the executor benchmark
    so both exercise the same program."""
    import jax.numpy as jnp

    g = Graph()
    for name, size in SIZES.items():
        g.add_tensor(name, size, shape=(size // 4,), dtype="float32")
    _wire_ops(g)
    for op in g.operators:
        if op.kind == "concat":
            op.fn = lambda *xs: jnp.concatenate([jnp.ravel(x) for x in xs])
        else:
            n = g.elements(op.output)
            op.fn = (lambda n: lambda x: jnp.resize(x, (n,)) * 0.5 + 0.25)(n)
    return g


def figure1_int8_graph() -> Graph:
    """figure1 as a *directly-constructed* int8 graph (1 byte per element,
    deterministic integer semantics) — the non-calibrated member of the
    int8 differential grid, exercising the byte arena with itemsize 1."""
    import jax.numpy as jnp

    g = Graph()
    for name, size in SIZES.items():
        g.add_tensor(name, size, shape=(size,), dtype="int8")
    _wire_ops(g)
    for op in g.operators:
        if op.kind == "concat":
            op.fn = lambda *xs: jnp.concatenate([jnp.ravel(x) for x in xs])
        else:
            n = g.elements(op.output)

            def fn(x, n=n):
                y = jnp.resize(x, (n,)).astype(jnp.int32) * 3 // 2 + 1
                return jnp.clip(y, -128, 127).astype(jnp.int8)
            op.fn = fn
    return g
