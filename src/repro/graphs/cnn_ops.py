"""Executable semantics for CNN graph operators (used by the
micro-interpreter simulator).  Weights are deterministic per-op constants
kept in ``Operator.attrs`` — they model NOR-Flash residency (paper §2.2:
parameters are immutable static data, only activations occupy SRAM), so they
are *not* tensors of the scheduling graph.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

try:  # jnp when available (tests run it through jax), numpy otherwise
    import jax.numpy as jnp
    from jax import lax
    _HAVE_JAX = True
except Exception:  # pragma: no cover
    _HAVE_JAX = False

from repro.core.graph import Graph


def _weight(name: str, shape: Tuple[int, ...], scale: float = 0.1):
    rng = np.random.default_rng(abs(hash(name)) % (2 ** 32))
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def conv_out_hw(h: int, w: int, stride: int) -> Tuple[int, int]:
    return math.ceil(h / stride), math.ceil(w / stride)


# Each builder registers a tensor + operator on the graph and returns the
# output tensor name.  Sizes are int8 bytes = H*W*C (paper models are int8).
class CNNBuilder:
    def __init__(self, graph: Graph):
        self.g = graph
        self.shapes: Dict[str, Tuple[int, int, int]] = {}
        self._n = 0

    def _next(self, prefix: str) -> str:
        self._n += 1
        return f"{prefix}{self._n}"

    def input(self, name: str, h: int, w: int, c: int) -> str:
        self.g.add_tensor(name, h * w * c, (h, w, c))
        self.shapes[name] = (h, w, c)
        return name

    def _emit(self, kind: str, inputs: Sequence[str], out_shape, fn, **attrs):
        name = self._next(kind)
        out = f"{name}_out"
        h, w, c = out_shape
        self.g.add_tensor(out, h * w * c, out_shape)
        self.shapes[out] = out_shape
        self.g.add_operator(name, list(inputs), out, kind=kind, fn=fn, **attrs)
        return out

    def conv(self, x: str, cout: int, k: int = 1, stride: int = 1) -> str:
        h, w, cin = self.shapes[x]
        oh, ow = conv_out_hw(h, w, stride)
        wname = f"conv{self._n + 1}_w"
        wgt = _weight(wname, (k, k, cin, cout))

        def fn(a, w=wgt, stride=stride):
            return conv2d(a, w, stride)

        return self._emit("conv", [x], (oh, ow, cout), fn,
                          weight_bytes=wgt.size, k=k, stride=stride)

    def dwconv(self, x: str, k: int = 3, stride: int = 1) -> str:
        h, w, cin = self.shapes[x]
        oh, ow = conv_out_hw(h, w, stride)
        wname = f"dw{self._n + 1}_w"
        wgt = _weight(wname, (k, k, cin, 1))

        def fn(a, w=wgt, stride=stride):
            return dwconv2d(a, w, stride)

        return self._emit("dwconv", [x], (oh, ow, cin), fn,
                          weight_bytes=wgt.size, k=k, stride=stride)

    def concat(self, xs: Sequence[str]) -> str:
        shapes = [self.shapes[x] for x in xs]
        h, w = shapes[0][0], shapes[0][1]
        c = sum(s[2] for s in shapes)

        def fn(*arrays):
            return jnp.concatenate(arrays, axis=-1)

        return self._emit("concat", xs, (h, w, c), fn)

    def add(self, a: str, b: str) -> str:
        def fn(x, y):
            return x + y

        return self._emit("add", [a, b], self.shapes[a], fn)

    def avgpool(self, x: str) -> str:
        h, w, c = self.shapes[x]

        def fn(a):
            return jnp.mean(a, axis=(0, 1), keepdims=True)

        return self._emit("avgpool", [x], (1, 1, c), fn)

    def fc(self, x: str, nout: int) -> str:
        h, w, c = self.shapes[x]
        wgt = _weight(f"fc{self._n + 1}_w", (h * w * c, nout))

        def fn(a, w=wgt):
            return jnp.reshape(a, (1, 1, -1)) @ w

        return self._emit("fc", [x], (1, 1, nout), fn, weight_bytes=wgt.size)


def conv2d(x, w, stride: int):
    """x: (H,W,Cin) f32; w: (k,k,Cin,Cout); SAME padding; relu."""
    y = lax.conv_general_dilated(
        x[None], w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    return jnp.maximum(y, 0.0)


def dwconv2d(x, w, stride: int):
    cin = x.shape[-1]
    y = lax.conv_general_dilated(
        x[None], jnp.reshape(jnp.transpose(w, (0, 1, 3, 2)), (w.shape[0], w.shape[1], 1, cin)),
        window_strides=(stride, stride), padding="SAME",
        feature_group_count=cin,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    return jnp.maximum(y, 0.0)


def model_weight_bytes(graph: Graph) -> int:
    return sum(op.attrs.get("weight_bytes", 0) for op in graph.operators)
