"""Executable semantics for CNN graph operators (used by the
micro-interpreter simulator).  Weights are deterministic per-op constants
kept in ``Operator.attrs`` — they model NOR-Flash residency (paper §2.2:
parameters are immutable static data, only activations occupy SRAM), so they
are *not* tensors of the scheduling graph.

This module is also where operators are classified for **partial execution**
(Pex-style spatial slicing, ``core/partition.py``):

* sliceable — elementwise (``add``), depthwise/regular convolution and
  spatial max-pooling: their output rows map to a bounded window of input
  rows under SAME padding, so a slice can be computed from a halo'd input
  window with explicit edge padding, bit-identically to the full op;
* not sliceable — global ``avgpool`` (its 1×1 output needs every input
  row), ``fc`` (ditto), and ``concat`` (channel-wise join of whole maps).

Each builder attaches a ``SliceSpec`` for the sliceable kinds; the spec's
``make_fn`` rebuilds the op with explicit height padding for a slice.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

try:  # jnp when available (tests run it through jax), numpy otherwise
    import jax.numpy as jnp
    from jax import lax
    _HAVE_JAX = True
except Exception:  # pragma: no cover
    _HAVE_JAX = False

from repro.core.graph import Graph, Operator
from repro.core.partition import PEX_ATTR, SliceSpec, same_pads


def _weight(name: str, shape: Tuple[int, ...], scale: float = 0.1):
    rng = np.random.default_rng(abs(hash(name)) % (2 ** 32))
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def conv_out_hw(h: int, w: int, stride: int) -> Tuple[int, int]:
    return math.ceil(h / stride), math.ceil(w / stride)


def _pads(n: int, k: int, stride: int) -> Tuple[int, int]:
    _, beg, end = same_pads(n, k, stride)
    return beg, end


# ----------------------------------------------------- slice-spec factories
def _windowed_slice_fn(kernel_name: str, attr_names: Tuple[str, ...]):
    """make_fn factory for windowed kernels: reads the kernel's extra args
    from op.attrs and rebuilds it with explicit height padding."""
    def make(op: Operator, pad_top: int, pad_bottom: int):
        kernel = globals()[kernel_name]
        args = tuple(op.attrs[a] for a in attr_names)

        def fn(x, kernel=kernel, args=args, hpad=(pad_top, pad_bottom)):
            return kernel(x, *args, hpad=hpad)
        return fn
    return make


def _elementwise_slice_fn(op: Operator, pad_top: int, pad_bottom: int):
    assert pad_top == 0 and pad_bottom == 0
    return op.fn


def pex_spec(kind: str, out_shape: Tuple[int, int, int], cin: int,
             k: int = 1, stride: int = 1) -> Optional[SliceSpec]:
    """The partial-execution classification of a CNN operator kind."""
    oh, ow, cout = out_shape
    if kind == "conv":
        return SliceSpec(k, stride, (0,),
                         _windowed_slice_fn("conv2d", ("weight", "stride")),
                         macs_per_row=ow * cout * k * k * cin)
    if kind == "dwconv":
        return SliceSpec(k, stride, (0,),
                         _windowed_slice_fn("dwconv2d", ("weight", "stride")),
                         macs_per_row=ow * cout * k * k)
    if kind == "maxpool":
        return SliceSpec(k, stride, (0,),
                         _windowed_slice_fn("maxpool2d", ("k", "stride")),
                         macs_per_row=ow * cout * k * k)
    if kind == "add":
        return SliceSpec(1, 1, None, _elementwise_slice_fn,
                         macs_per_row=ow * cout)
    return None    # concat / avgpool / fc: not spatially sliceable


# Each builder registers a tensor + operator on the graph and returns the
# output tensor name.  Sizes are int8 bytes = H*W*C (paper models are int8).
class CNNBuilder:
    def __init__(self, graph: Graph):
        self.g = graph
        self.shapes: Dict[str, Tuple[int, int, int]] = {}
        self._n = 0

    def _next(self, prefix: str) -> str:
        self._n += 1
        return f"{prefix}{self._n}"

    def input(self, name: str, h: int, w: int, c: int) -> str:
        self.g.add_tensor(name, h * w * c, (h, w, c))
        self.shapes[name] = (h, w, c)
        return name

    def _emit(self, kind: str, inputs: Sequence[str], out_shape, fn,
              cin: int = 0, **attrs):
        name = self._next(kind)
        out = f"{name}_out"
        h, w, c = out_shape
        self.g.add_tensor(out, h * w * c, out_shape)
        self.shapes[out] = out_shape
        spec = pex_spec(kind, out_shape, cin, attrs.get("k", 1),
                        attrs.get("stride", 1))
        if spec is not None:
            attrs[PEX_ATTR] = spec
        self.g.add_operator(name, list(inputs), out, kind=kind, fn=fn, **attrs)
        return out

    def conv(self, x: str, cout: int, k: int = 1, stride: int = 1) -> str:
        h, w, cin = self.shapes[x]
        oh, ow = conv_out_hw(h, w, stride)
        wname = f"conv{self._n + 1}_w"
        wgt = _weight(wname, (k, k, cin, cout))

        def fn(a, w=wgt, stride=stride):
            return conv2d(a, w, stride)

        return self._emit("conv", [x], (oh, ow, cout), fn, cin=cin,
                          weight_bytes=wgt.size, weight=wgt, k=k,
                          stride=stride)

    def dwconv(self, x: str, k: int = 3, stride: int = 1) -> str:
        h, w, cin = self.shapes[x]
        oh, ow = conv_out_hw(h, w, stride)
        wname = f"dw{self._n + 1}_w"
        wgt = _weight(wname, (k, k, cin, 1))

        def fn(a, w=wgt, stride=stride):
            return dwconv2d(a, w, stride)

        return self._emit("dwconv", [x], (oh, ow, cin), fn, cin=cin,
                          weight_bytes=wgt.size, weight=wgt, k=k,
                          stride=stride)

    def maxpool(self, x: str, k: int = 2, stride: int = 2) -> str:
        h, w, c = self.shapes[x]
        oh, ow = conv_out_hw(h, w, stride)

        def fn(a, k=k, stride=stride):
            return maxpool2d(a, k, stride)

        return self._emit("maxpool", [x], (oh, ow, c), fn, cin=c,
                          k=k, stride=stride)

    def concat(self, xs: Sequence[str]) -> str:
        shapes = [self.shapes[x] for x in xs]
        h, w = shapes[0][0], shapes[0][1]
        c = sum(s[2] for s in shapes)

        def fn(*arrays):
            return jnp.concatenate(arrays, axis=-1)

        return self._emit("concat", xs, (h, w, c), fn)

    def add(self, a: str, b: str) -> str:
        def fn(x, y):
            return x + y

        cin = self.shapes[a][2]
        return self._emit("add", [a, b], self.shapes[a], fn, cin=cin)

    def avgpool(self, x: str) -> str:
        h, w, c = self.shapes[x]

        def fn(a):
            return jnp.mean(a, axis=(0, 1), keepdims=True)

        return self._emit("avgpool", [x], (1, 1, c), fn)

    def fc(self, x: str, nout: int) -> str:
        h, w, c = self.shapes[x]
        wgt = _weight(f"fc{self._n + 1}_w", (h * w * c, nout))

        def fn(a, w=wgt):
            # explicit mul+reduce instead of a dot: XLA CPU emits tiny dots
            # context-sensitively (surrounding fusion changes the
            # accumulation path), which would break the compiled executor's
            # bit-identity contract with this eager reference.
            return jnp.sum(jnp.reshape(a, (-1, 1)) * w, axis=0)[None, None, :]

        return self._emit("fc", [x], (1, 1, nout), fn, weight_bytes=wgt.size)


def conv2d(x, w, stride: int, hpad: Optional[Tuple[int, int]] = None):
    """x: (H,W,Cin) f32; w: (k,k,Cin,Cout); SAME padding; relu.

    ``hpad`` overrides the height padding with an explicit (top, bottom)
    pair — partial execution uses this to run a slice whose interior edges
    get their halo rows from the input window instead of zero padding.
    SAME is reproduced exactly when ``hpad`` is None.
    """
    k = w.shape[0]
    hp = _pads(x.shape[0], k, stride) if hpad is None else tuple(hpad)
    wp = _pads(x.shape[1], w.shape[1], stride)
    y = lax.conv_general_dilated(
        x[None], w, window_strides=(stride, stride), padding=[hp, wp],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    return jnp.maximum(y, 0.0)


def dwconv2d(x, w, stride: int, hpad: Optional[Tuple[int, int]] = None):
    cin = x.shape[-1]
    k = w.shape[0]
    hp = _pads(x.shape[0], k, stride) if hpad is None else tuple(hpad)
    wp = _pads(x.shape[1], w.shape[1], stride)
    y = lax.conv_general_dilated(
        x[None], jnp.reshape(jnp.transpose(w, (0, 1, 3, 2)), (w.shape[0], w.shape[1], 1, cin)),
        window_strides=(stride, stride), padding=[hp, wp],
        feature_group_count=cin,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    return jnp.maximum(y, 0.0)


def maxpool2d(x, k: int, stride: int,
              hpad: Optional[Tuple[int, int]] = None):
    """SAME max-pooling over (H, W); padding rows take the -inf identity, so
    explicit-pad slices are bit-identical to the full op."""
    hp = _pads(x.shape[0], k, stride) if hpad is None else tuple(hpad)
    wp = _pads(x.shape[1], k, stride)
    return lax.reduce_window(x, -jnp.inf, lax.max, (k, k, 1),
                             (stride, stride, 1), (hp, wp, (0, 0)))


def model_weight_bytes(graph: Graph) -> int:
    return sum(op.attrs.get("weight_bytes", 0) for op in graph.operators)


# ------------------------------------------------- compiled-executor lowering
# Rules for the compiled arena executor (mcu/compile.py) live next to the
# semantics they mirror.  Each rule rebuilds the op's computation from attrs
# (weight/k/stride, plus the explicit pads a partial-execution clone carries
# in ``pex_pads``), tracing the SAME jnp/lax calls the simulator fns run —
# so compiled outputs stay bit-identical to the interpreter.  The pointwise
# conv optionally routes through the Pallas fused conv+bias+relu kernel
# (different accumulation order: fast, not bit-stable — opt-in).
from repro.mcu.compile import register_lowering


@register_lowering("conv")
def _lower_conv(ctx, op: Operator, x):
    w, stride = op.attrs["weight"], op.attrs["stride"]
    if (ctx.use_pallas and op.attrs.get("k", 1) == 1 and stride == 1
            and x.ndim == 3):
        from repro.kernels import conv1x1_fused
        return conv1x1_fused(x, jnp.asarray(w)[0, 0], relu=True,
                             interpret=ctx.interpret)
    return conv2d(x, w, stride, hpad=op.attrs.get("pex_pads"))


@register_lowering("dwconv")
def _lower_dwconv(ctx, op: Operator, x):
    return dwconv2d(x, op.attrs["weight"], op.attrs["stride"],
                    hpad=op.attrs.get("pex_pads"))


@register_lowering("maxpool")
def _lower_maxpool(ctx, op: Operator, x):
    return maxpool2d(x, op.attrs["k"], op.attrs["stride"],
                     hpad=op.attrs.get("pex_pads"))


@register_lowering("add")
def _lower_add(ctx, op: Operator, x, y):
    return x + y
