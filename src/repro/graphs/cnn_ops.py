"""Executable semantics for CNN graph operators (used by the
micro-interpreter simulator).  Weights are deterministic per-op constants
kept in ``Operator.attrs`` — they model NOR-Flash residency (paper §2.2:
parameters are immutable static data, only activations occupy SRAM), so they
are *not* tensors of the scheduling graph.

This module is also where operators are classified for **partial execution**
(Pex-style spatial slicing, ``core/partition.py``):

* sliceable — elementwise (``add``), depthwise/regular convolution and
  spatial max-pooling: their output rows map to a bounded window of input
  rows under SAME padding, so a slice can be computed from a halo'd input
  window with explicit edge padding, bit-identically to the full op;
* not sliceable — global ``avgpool`` (its 1×1 output needs every input
  row), ``fc`` (ditto), and ``concat`` (channel-wise join of whole maps).

Each builder attaches a ``SliceSpec`` for the sliceable kinds; the spec's
``make_fn`` rebuilds the op with explicit height padding for a slice.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

try:  # jnp when available (tests run it through jax), numpy otherwise
    import jax.numpy as jnp
    from jax import lax
    _HAVE_JAX = True
except Exception:  # pragma: no cover
    _HAVE_JAX = False

from repro.core.graph import Graph, Operator
from repro.core.partition import PEX_ATTR, SliceSpec, same_pads


def _weight(name: str, shape: Tuple[int, ...], scale: float = 0.1):
    rng = np.random.default_rng(abs(hash(name)) % (2 ** 32))
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def conv_out_hw(h: int, w: int, stride: int) -> Tuple[int, int]:
    return math.ceil(h / stride), math.ceil(w / stride)


def _pads(n: int, k: int, stride: int) -> Tuple[int, int]:
    _, beg, end = same_pads(n, k, stride)
    return beg, end


# ----------------------------------------------------- slice-spec factories
def _windowed_slice_fn(kernel_name: str, attr_names: Tuple[str, ...]):
    """make_fn factory for windowed kernels: reads the kernel's extra args
    from op.attrs and rebuilds it with explicit height padding — and, for
    2-D tile clones, explicit width padding.  1-D callers pass two pads and
    get the legacy closure (no ``wpad`` argument at all), so the row-ring
    path traces byte-identical jaxprs."""
    def make(op: Operator, pad_top: int, pad_bottom: int,
             pad_left: Optional[int] = None, pad_right: Optional[int] = None):
        kernel = globals()[kernel_name]
        args = tuple(op.attrs[a] for a in attr_names)

        if pad_left is None:
            def fn(x, kernel=kernel, args=args, hpad=(pad_top, pad_bottom)):
                return kernel(x, *args, hpad=hpad)
        else:
            def fn(x, kernel=kernel, args=args, hpad=(pad_top, pad_bottom),
                   wpad=(pad_left, pad_right)):
                return kernel(x, *args, hpad=hpad, wpad=wpad)
        return fn
    return make


def _elementwise_slice_fn(op: Operator, pad_top: int, pad_bottom: int,
                          pad_left: int = 0, pad_right: int = 0):
    assert pad_top == 0 and pad_bottom == 0
    assert pad_left in (0, None) and pad_right in (0, None)
    return op.fn


_QCONV_ATTRS = ("weight_q", "stride", "mult", "zp_in", "zp_out")


def pex_spec(kind: str, out_shape: Tuple[int, int, int], cin: int,
             k: int = 1, stride: int = 1) -> Optional[SliceSpec]:
    """The partial-execution classification of a CNN operator kind.  The
    int8 kinds (``q*``) slice exactly like their float counterparts: the
    row map only depends on kernel/stride, and requantization is per-tensor
    so every slice applies the same (scale, zero-point)."""
    oh, ow, cout = out_shape
    if kind == "conv":
        return SliceSpec(k, stride, (0,),
                         _windowed_slice_fn("conv2d", ("weight", "stride")),
                         macs_per_row=ow * cout * k * k * cin)
    if kind == "dwconv":
        return SliceSpec(k, stride, (0,),
                         _windowed_slice_fn("dwconv2d", ("weight", "stride")),
                         macs_per_row=ow * cout * k * k)
    if kind == "maxpool":
        return SliceSpec(k, stride, (0,),
                         _windowed_slice_fn("maxpool2d", ("k", "stride")),
                         macs_per_row=ow * cout * k * k)
    if kind == "add":
        return SliceSpec(1, 1, None, _elementwise_slice_fn,
                         macs_per_row=ow * cout)
    if kind == "qconv":
        return SliceSpec(k, stride, (0,),
                         _windowed_slice_fn("qconv2d", _QCONV_ATTRS),
                         macs_per_row=ow * cout * k * k * cin)
    if kind == "qdwconv":
        return SliceSpec(k, stride, (0,),
                         _windowed_slice_fn("qdwconv2d", _QCONV_ATTRS),
                         macs_per_row=ow * cout * k * k)
    if kind == "qmaxpool":
        return SliceSpec(k, stride, (0,),
                         _windowed_slice_fn("qmaxpool2d", ("k", "stride")),
                         macs_per_row=ow * cout * k * k)
    if kind == "qadd":
        return SliceSpec(1, 1, None, _elementwise_slice_fn,
                         macs_per_row=ow * cout)
    return None    # concat / avgpool / fc: not spatially sliceable


# Each builder registers a tensor + operator on the graph and returns the
# output tensor name.  The builder models the *float* network, so tensors
# are float32 and sizes are honest bytes (4 * H * W * C); the post-training
# int8 path (``graphs/quantize.py``) rewrites the graph with int8 tensors
# at 1 byte per element — the byte-for-byte composition of quantization
# with reordering/Pex the paper calls "orthogonal".
F32 = 4   # bytes per float32 element


class CNNBuilder:
    def __init__(self, graph: Graph):
        self.g = graph
        self.shapes: Dict[str, Tuple[int, int, int]] = {}
        self._n = 0

    def _next(self, prefix: str) -> str:
        self._n += 1
        return f"{prefix}{self._n}"

    def input(self, name: str, h: int, w: int, c: int) -> str:
        self.g.add_tensor(name, F32 * h * w * c, (h, w, c), dtype="float32")
        self.shapes[name] = (h, w, c)
        return name

    def _emit(self, kind: str, inputs: Sequence[str], out_shape, fn,
              cin: int = 0, **attrs):
        name = self._next(kind)
        out = f"{name}_out"
        h, w, c = out_shape
        self.g.add_tensor(out, F32 * h * w * c, out_shape, dtype="float32")
        self.shapes[out] = out_shape
        spec = pex_spec(kind, out_shape, cin, attrs.get("k", 1),
                        attrs.get("stride", 1))
        if spec is not None:
            attrs[PEX_ATTR] = spec
        self.g.add_operator(name, list(inputs), out, kind=kind, fn=fn, **attrs)
        return out

    def conv(self, x: str, cout: int, k: int = 1, stride: int = 1) -> str:
        h, w, cin = self.shapes[x]
        oh, ow = conv_out_hw(h, w, stride)
        wname = f"conv{self._n + 1}_w"
        wgt = _weight(wname, (k, k, cin, cout))

        def fn(a, w=wgt, stride=stride):
            return conv2d(a, w, stride)

        return self._emit("conv", [x], (oh, ow, cout), fn, cin=cin,
                          weight_bytes=wgt.nbytes, weight=wgt, k=k,
                          stride=stride)

    def dwconv(self, x: str, k: int = 3, stride: int = 1) -> str:
        h, w, cin = self.shapes[x]
        oh, ow = conv_out_hw(h, w, stride)
        wname = f"dw{self._n + 1}_w"
        wgt = _weight(wname, (k, k, cin, 1))

        def fn(a, w=wgt, stride=stride):
            return dwconv2d(a, w, stride)

        return self._emit("dwconv", [x], (oh, ow, cin), fn, cin=cin,
                          weight_bytes=wgt.nbytes, weight=wgt, k=k,
                          stride=stride)

    def maxpool(self, x: str, k: int = 2, stride: int = 2) -> str:
        h, w, c = self.shapes[x]
        oh, ow = conv_out_hw(h, w, stride)

        def fn(a, k=k, stride=stride):
            return maxpool2d(a, k, stride)

        return self._emit("maxpool", [x], (oh, ow, c), fn, cin=c,
                          k=k, stride=stride)

    def concat(self, xs: Sequence[str]) -> str:
        shapes = [self.shapes[x] for x in xs]
        h, w = shapes[0][0], shapes[0][1]
        c = sum(s[2] for s in shapes)

        def fn(*arrays):
            return jnp.concatenate(arrays, axis=-1)

        return self._emit("concat", xs, (h, w, c), fn)

    def add(self, a: str, b: str) -> str:
        def fn(x, y):
            return x + y

        cin = self.shapes[a][2]
        return self._emit("add", [a, b], self.shapes[a], fn, cin=cin)

    def avgpool(self, x: str) -> str:
        h, w, c = self.shapes[x]

        def fn(a):
            return jnp.mean(a, axis=(0, 1), keepdims=True)

        return self._emit("avgpool", [x], (1, 1, c), fn)

    def fc(self, x: str, nout: int) -> str:
        h, w, c = self.shapes[x]
        wgt = _weight(f"fc{self._n + 1}_w", (h * w * c, nout))

        def fn(a, w=wgt):
            # explicit mul+reduce instead of a dot: XLA CPU emits tiny dots
            # context-sensitively (surrounding fusion changes the
            # accumulation path), which would break the compiled executor's
            # bit-identity contract with this eager reference.
            return jnp.sum(jnp.reshape(a, (-1, 1)) * w, axis=0)[None, None, :]

        return self._emit("fc", [x], (1, 1, nout), fn, weight=wgt,
                          weight_bytes=wgt.nbytes)


def conv2d(x, w, stride: int, hpad: Optional[Tuple[int, int]] = None,
           wpad: Optional[Tuple[int, int]] = None):
    """x: (H,W,Cin) f32; w: (k,k,Cin,Cout); SAME padding; relu.

    ``hpad`` overrides the height padding with an explicit (top, bottom)
    pair — partial execution uses this to run a slice whose interior edges
    get their halo rows from the input window instead of zero padding.
    ``wpad`` is the width-axis twin, used by 2-D tile clones whose column
    windows carry their own halos.  SAME is reproduced exactly when either
    is None.
    """
    k = w.shape[0]
    hp = _pads(x.shape[0], k, stride) if hpad is None else tuple(hpad)
    wp = _pads(x.shape[1], w.shape[1], stride) if wpad is None else tuple(wpad)
    y = lax.conv_general_dilated(
        x[None], w, window_strides=(stride, stride), padding=[hp, wp],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    return jnp.maximum(y, 0.0)


def dwconv2d(x, w, stride: int, hpad: Optional[Tuple[int, int]] = None,
             wpad: Optional[Tuple[int, int]] = None):
    cin = x.shape[-1]
    k = w.shape[0]
    hp = _pads(x.shape[0], k, stride) if hpad is None else tuple(hpad)
    wp = _pads(x.shape[1], w.shape[1], stride) if wpad is None else tuple(wpad)
    y = lax.conv_general_dilated(
        x[None], jnp.reshape(jnp.transpose(w, (0, 1, 3, 2)), (w.shape[0], w.shape[1], 1, cin)),
        window_strides=(stride, stride), padding=[hp, wp],
        feature_group_count=cin,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    return jnp.maximum(y, 0.0)


def maxpool2d(x, k: int, stride: int,
              hpad: Optional[Tuple[int, int]] = None,
              wpad: Optional[Tuple[int, int]] = None):
    """SAME max-pooling over (H, W); padding rows take the -inf identity, so
    explicit-pad slices are bit-identical to the full op."""
    hp = _pads(x.shape[0], k, stride) if hpad is None else tuple(hpad)
    wp = _pads(x.shape[1], k, stride) if wpad is None else tuple(wpad)
    return lax.reduce_window(x, -jnp.inf, lax.max, (k, k, 1),
                             (stride, stride, 1), (hp, wp, (0, 0)))


def model_weight_bytes(graph: Graph) -> int:
    return sum(op.attrs.get("weight_bytes", 0) for op in graph.operators)


# ------------------------------------------------------ int8 (quantized) ops
# Per-tensor affine quantization (TFLite-Micro convention): real = scale *
# (q - zero_point), q int8 in [-128, 127].  Convolutions subtract the input
# zero-point, accumulate in int32 (exact), then requantize through a single
# float32 multiplier ``mult = s_in * s_w / s_out`` with round-half-even —
# every step is deterministic, so the compiled executor's int8 outputs are
# bit-identical to the interpreter's, slice-by-slice (the same contract the
# f32 path keeps).  SAME padding in the quantized domain pads with the input
# zero-point, which the (x - zp) -> pad-with-0 formulation gives for free,
# so Pex slices of int8 ops stay bit-identical too.
INT8_MIN, INT8_MAX = -128, 127


def requantize(acc, mult: float, zp_out: int, lo: int = INT8_MIN):
    """int32 accumulator -> int8 at the output (scale, zero_point).  ``lo``
    is the lower clamp: ``zp_out`` for fused relu (real 0), -128 otherwise."""
    y = jnp.round(acc.astype(jnp.float32) * jnp.float32(mult)) + zp_out
    return jnp.clip(y, lo, INT8_MAX).astype(jnp.int8)


def quantize_array(x, scale: float, zp: int):
    """f32 -> int8 at (scale, zp).  Also the semantics of ``quant`` ops in
    mixed-precision graphs."""
    q = jnp.round(x.astype(jnp.float32) / jnp.float32(scale)) + zp
    return jnp.clip(q, INT8_MIN, INT8_MAX).astype(jnp.int8)


def dequantize_array(q, scale: float, zp: int):
    """int8 -> f32; the semantics of ``dequant`` ops."""
    return (q.astype(jnp.float32) - zp) * jnp.float32(scale)


def qconv2d(x, w, stride: int, mult: float, zp_in: int, zp_out: int,
            hpad: Optional[Tuple[int, int]] = None,
            wpad: Optional[Tuple[int, int]] = None):
    """x: (H,W,Cin) int8; w: (k,k,Cin,Cout) int8; SAME padding; fused relu
    (lower clamp at ``zp_out``).  ``hpad``/``wpad`` as in ``conv2d``."""
    k = w.shape[0]
    hp = _pads(x.shape[0], k, stride) if hpad is None else tuple(hpad)
    wp = _pads(x.shape[1], w.shape[1], stride) if wpad is None else tuple(wpad)
    xi = x.astype(jnp.int32) - zp_in       # pad rows become 0 == zp_in
    acc = lax.conv_general_dilated(
        xi[None], jnp.asarray(w, jnp.int32), window_strides=(stride, stride),
        padding=[hp, wp], dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    return requantize(acc, mult, zp_out, lo=zp_out)


def qdwconv2d(x, w, stride: int, mult: float, zp_in: int, zp_out: int,
              hpad: Optional[Tuple[int, int]] = None,
              wpad: Optional[Tuple[int, int]] = None):
    cin = x.shape[-1]
    k = w.shape[0]
    hp = _pads(x.shape[0], k, stride) if hpad is None else tuple(hpad)
    wp = _pads(x.shape[1], w.shape[1], stride) if wpad is None else tuple(wpad)
    xi = x.astype(jnp.int32) - zp_in
    wi = jnp.reshape(jnp.transpose(jnp.asarray(w, jnp.int32), (0, 1, 3, 2)),
                     (w.shape[0], w.shape[1], 1, cin))
    acc = lax.conv_general_dilated(
        xi[None], wi, window_strides=(stride, stride), padding=[hp, wp],
        feature_group_count=cin,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    return requantize(acc, mult, zp_out, lo=zp_out)


def qmaxpool2d(x, k: int, stride: int,
               hpad: Optional[Tuple[int, int]] = None,
               wpad: Optional[Tuple[int, int]] = None):
    """Max-pooling is order-preserving, so scale/zero-point pass through;
    padding takes the int8 identity -128 (mirrors the f32 -inf)."""
    hp = _pads(x.shape[0], k, stride) if hpad is None else tuple(hpad)
    wp = _pads(x.shape[1], k, stride) if wpad is None else tuple(wpad)
    return lax.reduce_window(x, np.int8(INT8_MIN), lax.max, (k, k, 1),
                             (stride, stride, 1), (hp, wp, (0, 0)))


# qadd runs in fixed point: the two rescale multipliers are quantized to
# QADD_SHIFT fractional bits at trace time and the whole op is int32
# arithmetic + an integer round-half-even.  A float formulation
# (``round((a-zp_a)*mult_a + (b-zp_b)*mult_b)``) is NOT bit-stable across
# execution contexts: XLA CPU codegen contracts the mul->add into an FMA
# under jit (optimization_barrier/bitcast do not survive codegen), so the
# eager interpreter and the jitted compiled executor disagreed by +-1 on
# exact-half ties.  Integer ops cannot be contracted, so this sequence is
# bit-identical everywhere — eager, jit, and inside Pallas kernels (the
# fused conv->add kernel replays it literally).
QADD_SHIFT = 16


def _round_half_even_rshift(acc, shift: int):
    """Round-half-even of ``acc / 2**shift`` in pure integer arithmetic
    (``acc`` any signed int array; arithmetic right shift floors)."""
    base = acc >> shift
    rem = acc - (base << shift)          # in [0, 2**shift)
    half = 1 << (shift - 1)
    return jnp.where(rem > half, base + 1,
                     jnp.where(rem < half, base, base + (base & 1)))


def qadd(a, b, mult_a: float, mult_b: float, zp_a: int, zp_b: int,
         zp_out: int):
    ma = int(round(float(mult_a) * (1 << QADD_SHIFT)))
    mb = int(round(float(mult_b) * (1 << QADD_SHIFT)))
    assert abs(ma) + abs(mb) <= (1 << 23), "qadd multipliers too large"
    acc = ((a.astype(jnp.int32) - zp_a) * ma
           + (b.astype(jnp.int32) - zp_b) * mb)
    y = _round_half_even_rshift(acc, QADD_SHIFT) + zp_out
    return jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8)


def qavgpool(x):
    """Global average in the quantized domain (scale/zp pass through: the
    mean of q-values represents the mean of reals at the same params)."""
    m = jnp.mean(x.astype(jnp.float32), axis=(0, 1), keepdims=True)
    return jnp.clip(jnp.round(m), INT8_MIN, INT8_MAX).astype(jnp.int8)


def qfc(x, w, mult: float, zp_in: int, zp_out: int):
    """int8 fully-connected; mul+reduce in int32 for the same
    context-insensitivity reason as the f32 ``fc`` (and exactness)."""
    xi = jnp.reshape(x.astype(jnp.int32) - zp_in, (-1, 1))
    acc = jnp.sum(xi * jnp.asarray(w, jnp.int32), axis=0)[None, None, :]
    return requantize(acc, mult, zp_out)


def qconcat(*xs, mults: Sequence[float], zps: Sequence[int], zp_out: int):
    """Channel concat with per-input requantization to the output params."""
    parts = []
    for x, m, zp in zip(xs, mults, zps):
        y = jnp.round((x.astype(jnp.float32) - zp) * jnp.float32(m)) + zp_out
        parts.append(jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8))
    return jnp.concatenate(parts, axis=-1)


# ----------------------------------------- receptive-field redistribution
# 2-D tiled cascades pay halo recompute along BOTH spatial axes, and the
# bill scales with the receptive field of the early (high-resolution) ops.
# MCUNetV2's "receptive field redistribution" shifts kernel reach from the
# expensive early stage to the cheap late stage: shrink an early kernel to
# its center tap (a flagged MODEL EDIT — accuracy must be re-validated by
# retraining, which is out of scope here) and grow a late kernel by
# zero-embedding (function-preserving: a zero tap contributes exactly 0 to
# the int32/f32 accumulation, so outputs stay bit-identical while the
# planner sees — and prices — the larger reach).  ``cascade_graph(...,
# rf_redistribute=(shrink_op, grow_op))`` applies the pair before planning.
_RF_KINDS = ("conv", "dwconv", "qconv", "qdwconv")


def _rf_op(graph: Graph, op_name: str) -> Operator:
    for op in graph.operators:
        if op.name == op_name:
            if op.kind not in _RF_KINDS:
                raise ValueError(
                    f"receptive-field edit needs a conv kind, {op_name!r} "
                    f"is {op.kind!r}")
            return op
    raise KeyError(op_name)


def _rf_rebuild(graph: Graph, op: Operator, new_w: Optional[np.ndarray],
                new_k: int, rf_edit: str) -> Graph:
    """Copy of ``graph`` with ``op`` rebuilt at kernel size ``new_k``:
    weights/attrs/fn/SliceSpec all refreshed so the planner's halo maps and
    the executable semantics agree on the new reach."""
    wkey = "weight_q" if op.kind.startswith("q") else "weight"
    attrs = {a: v for a, v in op.attrs.items() if a != PEX_ATTR}
    old_k = attrs["k"]
    stride = attrs["stride"]
    attrs["k"] = new_k
    attrs["rf_edit"] = rf_edit
    if new_w is not None:
        attrs[wkey] = new_w
        attrs["weight_bytes"] = new_w.nbytes
    elif "weight_bytes" in attrs:
        # scheduling-only graphs carry no weights: scale flash accounting
        attrs["weight_bytes"] = (attrs["weight_bytes"] * new_k * new_k
                                 // (old_k * old_k))
    out_shape = tuple(graph.tensors[op.output].shape)
    in_shape = graph.tensors[op.inputs[0]].shape
    cin = in_shape[-1] if in_shape else 1
    spec = pex_spec(op.kind, out_shape, cin, new_k, stride)
    if spec is not None:
        attrs[PEX_ATTR] = spec
    fn = None
    if new_w is not None and op.fn is not None:
        if op.kind == "conv":
            def fn(a, w=new_w, s=stride):
                return conv2d(a, w, s)
        elif op.kind == "dwconv":
            def fn(a, w=new_w, s=stride):
                return dwconv2d(a, w, s)
        else:
            kern = qconv2d if op.kind == "qconv" else qdwconv2d
            def fn(a, kern=kern, w=new_w, at=dict(attrs)):
                return kern(a, w, at["stride"], at["mult"], at["zp_in"],
                            at["zp_out"])
    new = Graph()
    for tname, t in graph.tensors.items():
        new.add_tensor(tname, t.size, t.shape, t.dtype)
    for o in graph.operators:
        if o.name == op.name:
            new.add_operator(o.name, list(o.inputs), o.output, kind=o.kind,
                             fn=fn, **attrs)
        else:
            new.add_operator(o.name, list(o.inputs), o.output, kind=o.kind,
                             fn=o.fn, **o.attrs)
    new.set_outputs(graph.outputs)
    return new


def grow_kernel(graph: Graph, op_name: str,
                new_k: Optional[int] = None) -> Graph:
    """Zero-embed ``op_name``'s kernel into a ``new_k``×``new_k`` one
    (default k+2).  Function-preserving — bit-identical outputs: the
    embedded taps read exactly the rows/cols the original taps read (the
    embed offset equals the SAME pad growth), and the new zero taps
    contribute exactly 0 to the accumulation."""
    op = _rf_op(graph, op_name)
    k, stride = op.attrs["k"], op.attrs["stride"]
    new_k = k + 2 if new_k is None else new_k
    if new_k < k:
        raise ValueError(f"grow_kernel: new_k {new_k} < k {k}")
    h_in, w_in = graph.tensors[op.inputs[0]].shape[:2]
    eh = same_pads(h_in, new_k, stride)[1] - same_pads(h_in, k, stride)[1]
    ew = same_pads(w_in, new_k, stride)[1] - same_pads(w_in, k, stride)[1]
    assert 0 <= eh <= new_k - k and 0 <= ew <= new_k - k, (eh, ew, k, new_k)
    wkey = "weight_q" if op.kind.startswith("q") else "weight"
    old_w = op.attrs.get(wkey)
    new_w = None
    if old_w is not None:
        new_w = np.zeros((new_k, new_k) + old_w.shape[2:], old_w.dtype)
        new_w[eh:eh + k, ew:ew + k] = old_w
    return _rf_rebuild(graph, op, new_w, new_k, "grow")


def shrink_kernel(graph: Graph, op_name: str) -> Graph:
    """Shrink ``op_name``'s kernel to its center tap (k -> 1).  A flagged
    MODEL EDIT (``attrs['rf_edit'] == 'shrink'``): outputs change, reach
    drops to 1, and the planner's halo/extra-MACs bill shrinks with it.
    Pairs with ``grow_kernel`` on a later op to conserve network reach."""
    op = _rf_op(graph, op_name)
    k, stride = op.attrs["k"], op.attrs["stride"]
    if k == 1:
        return graph
    h_in, w_in = graph.tensors[op.inputs[0]].shape[:2]
    # the tap that reads input row i*stride — what a 1x1 SAME kernel reads
    pb_h = same_pads(h_in, k, stride)[1]
    pb_w = same_pads(w_in, k, stride)[1]
    assert 0 <= pb_h < k and 0 <= pb_w < k, (pb_h, pb_w, k)
    wkey = "weight_q" if op.kind.startswith("q") else "weight"
    old_w = op.attrs.get(wkey)
    new_w = None
    if old_w is not None:
        new_w = np.ascontiguousarray(old_w[pb_h:pb_h + 1, pb_w:pb_w + 1])
    return _rf_rebuild(graph, op, new_w, 1, "shrink")


def redistribute_receptive_field(graph: Graph, shrink: str, grow: str,
                                 grow_k: Optional[int] = None) -> Graph:
    """The MCUNetV2-style planner option: move kernel reach from an early
    op (``shrink`` -> center tap) to a later one (``grow`` zero-embedded to
    ``grow_k``, default its k plus the reach the shrink dropped).  The
    result carries ``rf_edit`` flags on both ops; the grow leg alone is
    bit-identical, the pair is a model edit gated behind explicit opt-in."""
    s_op = _rf_op(graph, shrink)
    g_op = _rf_op(graph, grow)
    if grow_k is None:
        grow_k = g_op.attrs["k"] + max(0, s_op.attrs["k"] - 1)
    out = shrink_kernel(graph, shrink)
    return grow_kernel(out, grow, grow_k)


# ------------------------------------------------- compiled-executor lowering
# Rules for the compiled arena executor (mcu/compile.py) live next to the
# semantics they mirror.  Each rule rebuilds the op's computation from attrs
# (weight/k/stride, plus the explicit pads a partial-execution clone carries
# in ``pex_pads``), tracing the SAME jnp/lax calls the simulator fns run —
# so compiled outputs stay bit-identical to the interpreter.  The pointwise
# conv optionally routes through the Pallas fused conv+bias+relu kernel
# (different accumulation order: fast, not bit-stable — opt-in).  The
# quantized convs route through the fused int8 kernels under
# ``kernels/conv_quant/`` when ``use_pallas=True`` — those ARE bit-identical
# (int32 accumulation is exact and order-independent; see the kernel module
# docstring), so ``use_pallas`` costs no precision on int8 graphs.
from repro.mcu.compile import register_lowering


@register_lowering("conv")
def _lower_conv(ctx, op: Operator, x):
    w, stride = op.attrs["weight"], op.attrs["stride"]
    if (ctx.use_pallas and op.attrs.get("k", 1) == 1 and stride == 1
            and x.ndim == 3):
        from repro.kernels import conv1x1_fused
        return conv1x1_fused(x, jnp.asarray(w)[0, 0], relu=True,
                             interpret=ctx.interpret)
    return conv2d(x, w, stride, hpad=op.attrs.get("pex_pads"),
                  wpad=op.attrs.get("pex_wpads"))


@register_lowering("dwconv")
def _lower_dwconv(ctx, op: Operator, x):
    return dwconv2d(x, op.attrs["weight"], op.attrs["stride"],
                    hpad=op.attrs.get("pex_pads"),
                    wpad=op.attrs.get("pex_wpads"))


@register_lowering("maxpool")
def _lower_maxpool(ctx, op: Operator, x):
    return maxpool2d(x, op.attrs["k"], op.attrs["stride"],
                     hpad=op.attrs.get("pex_pads"),
                     wpad=op.attrs.get("pex_wpads"))


@register_lowering("add")
def _lower_add(ctx, op: Operator, x, y):
    return x + y


@register_lowering("qconv")
def _lower_qconv(ctx, op: Operator, x):
    a = op.attrs
    hpad, wpad = a.get("pex_pads"), a.get("pex_wpads")
    if ctx.use_pallas and x.ndim == 3:
        from repro.kernels import qconv_fused
        return qconv_fused(x, jnp.asarray(a["weight_q"]), stride=a["stride"],
                           mult=a["mult"], zp_in=a["zp_in"],
                           zp_out=a["zp_out"],
                           hpad=None if hpad is None else tuple(hpad),
                           wpad=None if wpad is None else tuple(wpad),
                           interpret=ctx.interpret)
    return qconv2d(x, a["weight_q"], a["stride"], a["mult"], a["zp_in"],
                   a["zp_out"], hpad=hpad, wpad=wpad)


@register_lowering("qdwconv")
def _lower_qdwconv(ctx, op: Operator, x):
    a = op.attrs
    hpad, wpad = a.get("pex_pads"), a.get("pex_wpads")
    if ctx.use_pallas and x.ndim == 3:
        from repro.kernels import qdwconv_fused
        return qdwconv_fused(x, jnp.asarray(a["weight_q"]),
                             stride=a["stride"], mult=a["mult"],
                             zp_in=a["zp_in"], zp_out=a["zp_out"],
                             hpad=None if hpad is None else tuple(hpad),
                             wpad=None if wpad is None else tuple(wpad),
                             interpret=ctx.interpret)
    return qdwconv2d(x, a["weight_q"], a["stride"], a["mult"], a["zp_in"],
                     a["zp_out"], hpad=hpad, wpad=wpad)


@register_lowering("qmaxpool")
def _lower_qmaxpool(ctx, op: Operator, x):
    return qmaxpool2d(x, op.attrs["k"], op.attrs["stride"],
                      hpad=op.attrs.get("pex_pads"),
                      wpad=op.attrs.get("pex_wpads"))


@register_lowering("qadd")
def _lower_qadd(ctx, op: Operator, x, y):
    a = op.attrs
    return qadd(x, y, a["mult_a"], a["mult_b"], a["zp_a"], a["zp_b"],
                a["zp_out"])


@register_lowering("qavgpool")
def _lower_qavgpool(ctx, op: Operator, x):
    return qavgpool(x)


@register_lowering("qfc")
def _lower_qfc(ctx, op: Operator, x):
    a = op.attrs
    return qfc(x, a["weight_q"], a["mult"], a["zp_in"], a["zp_out"])


@register_lowering("qconcat")
def _lower_qconcat(ctx, op: Operator, *xs):
    a = op.attrs
    return qconcat(*xs, mults=a["mults"], zps=a["zps"], zp_out=a["zp_out"])


@register_lowering("quant")
def _lower_quant(ctx, op: Operator, x):
    return quantize_array(x, op.attrs["scale"], op.attrs["zp"])


@register_lowering("dequant")
def _lower_dequant(ctx, op: Operator, x):
    return dequantize_array(x, op.attrs["scale"], op.attrs["zp"])
