from .figure1 import figure1_graph
from .swiftnet import swiftnet_cell_graph
from .mobilenet import mobilenet_v1_graph

__all__ = ["figure1_graph", "swiftnet_cell_graph", "mobilenet_v1_graph"]
