from .figure1 import (figure1_executable_graph, figure1_graph,
                      figure1_int8_graph)
from .swiftnet import swiftnet_cell_graph
from .mobilenet import mobilenet_v1_graph
from .quantize import (QParams, QuantizedModel, int8_scheduling_graph,
                       quantize_graph)


def graph_dtypes(graph) -> str:
    """Element-width tag for a graph: a single dtype name when uniform
    ("float32", "int8", ...), "mixed" otherwise.  The benchmark trajectory
    records this per row so byte figures stay comparable across
    quantization changes."""
    kinds = {t.dtype for t in graph.tensors.values()}
    return kinds.pop() if len(kinds) == 1 else "mixed"


def random_input(graph, seed: int = 0):
    """{name: array} for the graph's (single consumed) input tensor, in the
    tensor's declared dtype — f32 normals for float graphs, uniform int8
    for quantized/int8 graphs.  The input-synthesis convention the tests
    and benchmarks share."""
    import numpy as np

    name = next((c for c in graph.constants() if graph.consumers(c)), None)
    if name is None:
        raise ValueError(f"{graph!r} has no consumed input tensor")
    t = graph.tensors[name]
    shape = t.shape if t.shape else (t.elements,)
    rng = np.random.default_rng(seed)
    if t.dtype == "int8":
        return {name: rng.integers(-128, 128, shape).astype(np.int8)}
    return {name: rng.standard_normal(shape).astype(np.float32)}


__all__ = ["figure1_executable_graph", "figure1_graph", "figure1_int8_graph",
           "swiftnet_cell_graph", "mobilenet_v1_graph", "graph_dtypes",
           "random_input", "QParams", "QuantizedModel",
           "int8_scheduling_graph", "quantize_graph"]
