from .figure1 import figure1_executable_graph, figure1_graph
from .swiftnet import swiftnet_cell_graph
from .mobilenet import mobilenet_v1_graph


def random_input(graph, seed: int = 0):
    """{name: f32 array} for the graph's (single consumed) input tensor —
    the input-synthesis convention the tests and benchmarks share."""
    import numpy as np

    name = next((c for c in graph.constants() if graph.consumers(c)), None)
    if name is None:
        raise ValueError(f"{graph!r} has no consumed input tensor")
    t = graph.tensors[name]
    shape = t.shape if t.shape else (t.size,)
    rng = np.random.default_rng(seed)
    return {name: rng.standard_normal(shape).astype(np.float32)}


__all__ = ["figure1_executable_graph", "figure1_graph",
           "swiftnet_cell_graph", "mobilenet_v1_graph", "random_input"]
