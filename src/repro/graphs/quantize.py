"""Post-training int8 quantization of CNN graphs.

The paper frames operator reordering as "orthogonal to other compression
methods"; on real MCUs the dominant such method is int8 quantization
(TFLite-Micro, MCUNet).  This pass makes the composition measurable
byte-for-byte: ``quantize_graph`` takes the float graph the builders
produce, runs a calibration batch through its f32 semantics to observe
per-tensor activation ranges, and rewrites the graph with

* int8 tensors (1 byte per element — a 4x cut of every activation the
  planner, Pex cost model and arena executor account for), and
* quantized operator semantics (``graphs/cnn_ops.py``: ``qconv`` /
  ``qdwconv`` / ``qmaxpool`` / ``qadd`` / ``qavgpool`` / ``qfc`` /
  ``qconcat``) with per-tensor (scale, zero-point) requantization, int32
  accumulation and deterministic round-half-even — so the compiled arena
  executor stays bit-identical to the int8 interpreter, including across
  Pex slices (the q-kinds carry ``SliceSpec``s like their float
  counterparts).

Topology, tensor names and operator names are preserved, so any schedule
found for the float graph maps 1:1, and the scheduling/partition machinery
runs unchanged on the quantized graph — just over 4x smaller byte sizes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple, Union

import numpy as np

from repro.core.graph import Graph
from repro.core.partition import PEX_ATTR

from . import cnn_ops
from .cnn_ops import INT8_MAX, INT8_MIN, pex_spec


@dataclasses.dataclass(frozen=True)
class QParams:
    """Per-tensor affine quantization: real = scale * (q - zero_point)."""

    scale: float
    zero_point: int

    def quantize(self, x: np.ndarray) -> np.ndarray:
        q = np.round(np.asarray(x, np.float32) / np.float32(self.scale))
        return np.clip(q + self.zero_point, INT8_MIN, INT8_MAX).astype(np.int8)

    def dequantize(self, q: np.ndarray) -> np.ndarray:
        return ((np.asarray(q, np.float32) - self.zero_point)
                * np.float32(self.scale))


def activation_qparams(lo: float, hi: float) -> QParams:
    """Asymmetric int8 params for an observed [lo, hi] range.  The range is
    widened to include 0 (standard practice: zero padding / relu zero must
    be exactly representable, which is what lets SAME padding and the relu
    clamp use the zero-point directly)."""
    lo, hi = min(0.0, float(lo)), max(0.0, float(hi))
    scale = (hi - lo) / (INT8_MAX - INT8_MIN)
    if scale == 0.0:
        scale = 1.0    # degenerate all-zero tensor
    zp = int(round(INT8_MIN - lo / scale))
    return QParams(scale, max(INT8_MIN, min(INT8_MAX, zp)))


def weight_qparams(w: np.ndarray) -> Tuple[np.ndarray, float]:
    """Symmetric per-tensor weight quantization: (w_q int8, scale)."""
    scale = float(max(np.abs(w).max(), 1e-8)) / INT8_MAX
    wq = np.clip(np.round(w / scale), -INT8_MAX, INT8_MAX).astype(np.int8)
    return wq, scale


def calibrate(graph: Graph,
              batches: Sequence[Dict[str, np.ndarray]]
              ) -> Dict[str, Tuple[float, float]]:
    """Observed [min, max] per tensor over an eager run of the float graph
    on each calibration batch."""
    ranges: Dict[str, Tuple[float, float]] = {}

    def track(name: str, value: np.ndarray) -> None:
        lo, hi = float(np.min(value)), float(np.max(value))
        if name in ranges:
            plo, phi = ranges[name]
            lo, hi = min(lo, plo), max(hi, phi)
        ranges[name] = (lo, hi)

    for inputs in batches:
        bufs: Dict[str, Any] = {}
        for name, value in inputs.items():
            bufs[name] = np.asarray(value, np.float32)
            track(name, bufs[name])
        for op in graph.default_schedule():
            if op.fn is None:
                raise ValueError(
                    f"cannot calibrate: operator {op.name!r} has no "
                    f"semantics")
            out = np.asarray(op.fn(*[bufs[i] for i in op.inputs]))
            bufs[op.output] = out
            track(op.output, out)
    return ranges


@dataclasses.dataclass
class QuantizedModel:
    """The int8 rewrite of a float graph plus everything needed to use it:
    per-tensor ``QParams`` (quantize inputs / dequantize outputs) and the
    original float graph for reference comparisons."""

    graph: Graph
    qparams: Dict[str, QParams]
    float_graph: Graph

    def quantize_inputs(self, inputs: Dict[str, np.ndarray]
                        ) -> Dict[str, np.ndarray]:
        return {n: self.qparams[n].quantize(v) for n, v in inputs.items()}

    def dequantize_outputs(self, outputs: Dict[str, np.ndarray]
                           ) -> Dict[str, np.ndarray]:
        return {n: self.qparams[n].dequantize(v) for n, v in outputs.items()}


def _int8_tensors(old: Graph, new: Graph) -> None:
    for name, t in old.tensors.items():
        new.add_tensor(name, t.elements, t.shape, dtype="int8")


def quantize_graph(graph: Graph,
                   calibration: Union[None, Dict[str, np.ndarray],
                                      Sequence[Dict[str, np.ndarray]]] = None,
                   ) -> QuantizedModel:
    """Post-training quantization: calibrate activation ranges on the float
    graph, then rebuild it with int8 tensors and quantized semantics.

    ``calibration``: one input dict, a sequence of them, or None for a
    deterministic synthetic batch (``graphs.random_input``).
    """
    if calibration is None:
        from . import random_input
        batches: List[Dict[str, np.ndarray]] = [random_input(graph)]
    elif isinstance(calibration, dict):
        batches = [calibration]
    else:
        batches = list(calibration)
    ranges = calibrate(graph, batches)

    qp: Dict[str, QParams] = {n: activation_qparams(*ranges[n])
                              for n in ranges}
    # pass-through kinds reuse the input's params (max/avg pooling are
    # order/mean-preserving in the quantized domain)
    for op in graph.default_schedule():
        if op.kind in ("maxpool", "avgpool"):
            qp[op.output] = qp[op.inputs[0]]

    new = Graph()
    _int8_tensors(graph, new)
    for op in graph.operators:
        _quantize_op(graph, new, op, qp)
    new.set_outputs(graph.outputs)
    return QuantizedModel(new, qp, graph)


def int8_scheduling_graph(graph: Graph) -> Graph:
    """The int8 rewrite's *memory model* only: tensors shrink to 1 byte per
    element, operators keep their kinds/attrs (weights dropped,
    ``weight_bytes`` divided by the source element width) but carry no
    semantics.
    For scheduling/golden accounting of graphs too large to execute in a
    fast test — the full ``quantize_graph`` produces identical sizes, so
    peaks/plans computed here are exactly the quantized model's.  The
    original ``SliceSpec``s are preserved: a row map depends only on
    kernel/stride, never on dtype."""
    new = Graph()
    _int8_tensors(graph, new)
    for op in graph.operators:
        attrs = {k: v for k, v in op.attrs.items() if k != "weight"}
        if "weight_bytes" in attrs:
            # weights share the activations' element width in these
            # builders; deriving the divisor keeps an already-int8 graph
            # a no-op instead of silently quartering flash accounting
            attrs["weight_bytes"] //= graph.itemsize(op.output)
        new.add_operator(op.name, list(op.inputs), op.output, kind=op.kind,
                         fn=None, **attrs)
    new.set_outputs(graph.outputs)
    return new


def _quantize_op(old: Graph, new: Graph, op, qp: Dict[str, QParams]) -> None:
    """Emit the int8 counterpart of ``op`` onto ``new``."""
    kind = "q" + op.kind
    ins, out = list(op.inputs), op.output
    out_shape = old.tensors[out].shape
    attrs: Dict[str, Any] = {}
    fn = None

    if op.kind in ("conv", "dwconv"):
        wq, sw = weight_qparams(op.attrs["weight"])
        s_in, zp_in = qp[ins[0]].scale, qp[ins[0]].zero_point
        s_out, zp_out = qp[out].scale, qp[out].zero_point
        mult = s_in * sw / s_out
        attrs = dict(weight_q=wq, weight_bytes=wq.nbytes, k=op.attrs["k"],
                     stride=op.attrs["stride"], mult=mult, zp_in=zp_in,
                     zp_out=zp_out)
        kernel = cnn_ops.qconv2d if op.kind == "conv" else cnn_ops.qdwconv2d

        def fn(x, kernel=kernel, wq=wq, a=attrs):
            return kernel(x, wq, a["stride"], a["mult"], a["zp_in"],
                          a["zp_out"])
    elif op.kind == "maxpool":
        attrs = dict(k=op.attrs["k"], stride=op.attrs["stride"])

        def fn(x, a=attrs):
            return cnn_ops.qmaxpool2d(x, a["k"], a["stride"])
    elif op.kind == "avgpool":
        fn = cnn_ops.qavgpool
    elif op.kind == "add":
        s_out, zp_out = qp[out].scale, qp[out].zero_point
        attrs = dict(mult_a=qp[ins[0]].scale / s_out,
                     mult_b=qp[ins[1]].scale / s_out,
                     zp_a=qp[ins[0]].zero_point, zp_b=qp[ins[1]].zero_point,
                     zp_out=zp_out)

        def fn(x, y, a=attrs):
            return cnn_ops.qadd(x, y, a["mult_a"], a["mult_b"], a["zp_a"],
                                a["zp_b"], a["zp_out"])
    elif op.kind == "concat":
        s_out, zp_out = qp[out].scale, qp[out].zero_point
        attrs = dict(mults=tuple(qp[i].scale / s_out for i in ins),
                     zps=tuple(qp[i].zero_point for i in ins), zp_out=zp_out)

        def fn(*xs, a=attrs):
            return cnn_ops.qconcat(*xs, mults=a["mults"], zps=a["zps"],
                                   zp_out=a["zp_out"])
    elif op.kind == "fc":
        wq, sw = weight_qparams(op.attrs["weight"])
        s_in, zp_in = qp[ins[0]].scale, qp[ins[0]].zero_point
        s_out, zp_out = qp[out].scale, qp[out].zero_point
        attrs = dict(weight_q=wq, weight_bytes=wq.nbytes,
                     mult=s_in * sw / s_out, zp_in=zp_in, zp_out=zp_out)

        def fn(x, wq=wq, a=attrs):
            return cnn_ops.qfc(x, wq, a["mult"], a["zp_in"], a["zp_out"])
    else:
        raise ValueError(
            f"quantize_graph: unsupported operator kind {op.kind!r} "
            f"({op.name!r})")

    h, w = (out_shape[0], out_shape[1]) if len(out_shape) == 3 else (1, 1)
    cin = (old.tensors[ins[0]].shape[-1]
           if old.tensors[ins[0]].shape else 1)
    spec = pex_spec(kind, tuple(out_shape) if len(out_shape) == 3
                    else (h, w, out_shape[-1] if out_shape else 1),
                    cin, attrs.get("k", 1), attrs.get("stride", 1))
    if spec is not None:
        attrs[PEX_ATTR] = spec
    new.add_operator(op.name, ins, out, kind=kind, fn=fn, **attrs)
