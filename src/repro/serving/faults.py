"""Deterministic fault injection for the serving/deploy failure layer.

Chaos testing an MCU inference stack only works if the chaos replays: a
fault that appears on one CI run and not the next is a flake, not a test.
Everything here is therefore driven by one seeded ``numpy`` generator
inside ``FaultInjector`` — the same ``FaultPlan`` (seed + rates) produces
the same fault sequence on every run, so ``tests/test_chaos.py`` can
assert exact outcomes (which lanes were poisoned, how many retries fired)
rather than statistical ones.

Fault taxonomy (DESIGN.md §12):

* **device error** — the dispatch call raises ``TransientDeviceError``
  before executing; models a flaky bus/DMA transfer.  Retryable.
* **slow dispatch** — the dispatch stalls ``slow_s`` seconds; models a
  contended device.  The post-hoc watchdog in ``dispatch_with_retry``
  detects the overrun, discards the (complete but late) result and
  re-dispatches — see the honesty note on that function.
* **corrupted arena bytes** — lane arena bytes are XOR-flipped after
  execution; models bit-flips/out-of-bounds writes.  Detected either by
  genuine guard-canary verification (when the plan carries guard bytes)
  or by the injector's own lane report standing in for the ECC/bus-fault
  signal real hardware would raise.
* **NaN activations** — a float output lane is overwritten with NaN;
  detected by a genuine ``np.isnan`` scan of decoded outputs.
* **engine-init failure** — replica-mesh bring-up raises
  ``DeviceInitError``; the sharded engine degrades to single-device.

The injector mutates **numpy copies** of lane arenas only — jax buffers
are never written in place, so with faults disabled the execution path is
byte-identical to the un-instrumented engine.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.errors import (DeviceInitError, DispatchFailedError,
                          TransientDeviceError)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded fault schedule.  All rates are per-dispatch (or per-lane for
    corruption/NaN) probabilities in [0, 1]; the default plan injects
    nothing and costs nothing."""

    seed: int = 0
    device_error_rate: float = 0.0   # dispatch raises TransientDeviceError
    slow_rate: float = 0.0           # dispatch sleeps slow_s first
    slow_s: float = 0.02
    corrupt_rate: float = 0.0        # per-lane arena byte corruption
    corrupt_bytes: int = 4
    nan_rate: float = 0.0            # per-lane NaN output poisoning
    fail_engine_init: bool = False   # replica mesh bring-up fails

    def any_lane_faults(self) -> bool:
        return self.corrupt_rate > 0.0 or self.nan_rate > 0.0


class FaultInjector:
    """Executes a ``FaultPlan`` with one private seeded RNG.

    ``injected`` counts every fault actually fired, keyed by kind — the
    chaos suite's ledger: every count here must be matched by a
    retry-success, a typed error result, or a recorded degradation.
    """

    def __init__(self, plan: FaultPlan) -> None:
        self.plan = plan
        self._rng = np.random.default_rng(plan.seed)
        self.injected: Dict[str, int] = {
            "device_error": 0, "slow": 0, "corrupt": 0, "nan": 0,
            "engine_init": 0,
        }

    # ----------------------------------------------------------- init
    def engine_init(self) -> None:
        """Hook at replica-mesh bring-up; raises when the plan says the
        mesh fails (models a missing/odd device topology)."""
        if self.plan.fail_engine_init:
            self.injected["engine_init"] += 1
            raise DeviceInitError("injected replica-mesh init failure")

    # ------------------------------------------------------- dispatch
    def before_dispatch(self, sleep: Callable[[float], None] = time.sleep
                        ) -> None:
        """Hook before each dispatch: one RNG draw decides device error
        (raises) vs slow dispatch (sleeps) vs nothing.  One draw, not two,
        keeps the fault sequence a pure function of the draw count."""
        p = self.plan
        if p.device_error_rate <= 0.0 and p.slow_rate <= 0.0:
            return
        u = float(self._rng.random())
        if u < p.device_error_rate:
            self.injected["device_error"] += 1
            raise TransientDeviceError("injected transient device error")
        if u < p.device_error_rate + p.slow_rate:
            self.injected["slow"] += 1
            sleep(p.slow_s)

    # ---------------------------------------------------- lane faults
    def corrupt_lanes(self, n_lanes: int) -> List[int]:
        """Which of ``n_lanes`` get arena-byte corruption this dispatch."""
        if self.plan.corrupt_rate <= 0.0 or n_lanes == 0:
            return []
        draws = self._rng.random(n_lanes)
        return [i for i in range(n_lanes)
                if draws[i] < self.plan.corrupt_rate]

    def nan_lanes(self, n_lanes: int) -> List[int]:
        """Which of ``n_lanes`` get NaN output poisoning this dispatch."""
        if self.plan.nan_rate <= 0.0 or n_lanes == 0:
            return []
        draws = self._rng.random(n_lanes)
        return [i for i in range(n_lanes) if draws[i] < self.plan.nan_rate]

    def corrupt_arena(self, lane_arena: np.ndarray,
                      guard_regions: Sequence[Tuple[int, int]] = ()) -> None:
        """XOR-flip ``corrupt_bytes`` bytes of one lane arena in place
        (numpy copy, never a jax buffer).  When the plan has guard
        regions, corruption lands inside one — modelling the adjacent
        out-of-bounds write guards exist to catch — so detection is the
        *genuine* canary check, not injector bookkeeping."""
        self.injected["corrupt"] += 1
        n = min(self.plan.corrupt_bytes, lane_arena.size)
        if n <= 0:
            return
        if guard_regions:
            regions = list(guard_regions)
            off, size = regions[int(self._rng.integers(len(regions)))]
            start = off + int(self._rng.integers(max(1, size - n + 1)))
            n = min(n, off + size - start)
        else:
            start = int(self._rng.integers(max(1, lane_arena.size - n + 1)))
        lane_arena[start:start + n] ^= 0xFF

    def inject_nan(self, lane_arena: np.ndarray, executor) -> bool:
        """Overwrite the first float32 output's leading element with NaN
        in one lane arena (numpy copy).  Returns False when the graph has
        no float output to poison (int8 outputs can't encode NaN)."""
        for name in executor.graph.outputs:
            if executor.graph.tensors[name].dtype == "float32":
                off, _size = executor.offsets[name]
                nan = np.frombuffer(
                    np.float32(np.nan).tobytes(), dtype=np.uint8)
                lane_arena[off:off + 4] = nan
                self.injected["nan"] += 1
                return True
        return False


def dispatch_with_retry(dispatch: Callable[[], object], *,
                        faults: Optional[FaultInjector] = None,
                        max_retries: int = 2,
                        dispatch_timeout: Optional[float] = None,
                        clock: Callable[[], float] = time.perf_counter
                        ) -> Tuple[object, int, int]:
    """Run ``dispatch`` with bounded retry-on-transient-failure and a
    post-hoc watchdog.  Returns ``(result, retried, watchdog_trips)``;
    raises ``DispatchFailedError`` once the retry budget is spent.

    Watchdog honesty: a synchronous jax call cannot be pre-empted from
    Python, so the watchdog is *post-hoc* — it measures elapsed wall time
    and, past ``dispatch_timeout``, discards the (late but complete)
    result and re-dispatches.  That bounds how stale a served result can
    be and converts a persistently-slow device into a typed
    ``DispatchFailedError`` instead of unbounded tail latency; it does
    not abort an in-flight kernel.  Double execution is safe because the
    compiled arena program is pure (callers rebuild donated inputs per
    attempt).
    """
    retried = 0
    watchdog_trips = 0
    last_err: Optional[BaseException] = None
    for attempt in range(max_retries + 1):
        t0 = clock()
        try:
            if faults is not None:
                faults.before_dispatch()
            result = dispatch()
        except TransientDeviceError as e:
            last_err = e
            retried += 1
            continue
        if dispatch_timeout is not None and clock() - t0 > dispatch_timeout:
            watchdog_trips += 1
            last_err = TransientDeviceError(
                f"dispatch exceeded watchdog timeout {dispatch_timeout}s")
            retried += 1
            continue
        return result, retried, watchdog_trips
    err = DispatchFailedError(
        f"dispatch failed after {max_retries + 1} attempts "
        f"(last: {last_err})")
    err.retried = retried                  # the spent budget rides on the
    err.watchdog_trips = watchdog_trips    # exception so stats stay exact
    raise err from last_err


__all__ = ["FaultPlan", "FaultInjector", "dispatch_with_retry"]
