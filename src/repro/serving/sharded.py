"""Sharded continuous-batching serving engine (the production tier).

``GraphServingEngine`` amortises XLA dispatch across vmap lanes but still
runs every batch on one device and makes late requests wait for the whole
serve loop.  ``ShardedServingEngine`` scales that out and opens the batch
boundary:

* **Replica sharding** — the deployed arena program is ``pmap(vmap(...))``
  over ``replicas`` devices: each dispatch executes an ``[R, L, arena]``
  stack, R replicas × L vmap lanes, with no collectives (requests are
  embarrassingly parallel), so per-lane results are bit-identical to a
  single ``Deployment.run``.  On CPU hosts the replica mesh comes from
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — call
  ``force_host_devices(N)`` (importable without touching jax) before the
  first jax import.
* **Continuous batching at dispatch granularity** — requests enter an
  admission queue (``submit``); every ``step`` admits up to R×L queued
  requests *at that batch boundary*.  A late arrival joins the next
  dispatch instead of waiting for the current serve loop to finish.
* **Deadline/priority admission** (``serving/admission.py``) — requests
  carry optional ``priority`` (larger admits first; ties FIFO, so the
  default queue is exactly the old FIFO) and an absolute ``deadline``:
  past-deadline requests are *never executed*, they complete as typed
  ``RequestError("expired")`` results.  ``max_pending`` bounds the queue —
  excess submissions shed immediately as ``RequestError("shed")``
  (backpressure as a typed outcome, not a latency cliff).
* **Bounded retry + watchdog** — each dispatch runs through
  ``faults.dispatch_with_retry``: transient device errors retry up to
  ``max_retries``; a ``dispatch_timeout`` turns persistent slowness into a
  typed failure (post-hoc watchdog — see that function's honesty note).
  Exhausted budgets become ``RequestError("dispatch_failed")`` for the
  admitted requests, never an exception out of the serve loop.
* **Fault detection + degradation** (DESIGN.md §12) — with a seeded
  ``FaultPlan``, injected arena corruption is caught by genuine guard-
  canary verification (``guard_bytes`` deployments) or the injector's
  ECC-style lane report, and NaN poison by a genuine output scan; poisoned
  requests re-queue (bounded by ``max_retries``) or fail typed.  Replica-
  mesh init failure degrades to the single-device batched program with a
  note in ``stats.degraded`` instead of refusing to serve.
* **Honest ragged tails** — pad lanes are explicit all-zero arenas:
  executed (one compiled shape), counted in ``stats.padded_lanes``, never
  extracted, never in per-request latency.
* **Typed stats** — latency p50/p99 and throughput plus the failure-layer
  counters (admitted/expired/shed/retried/failed/watchdog_trips) in
  ``EngineStats``; ``benchmarks/bench_serving.py`` gates requests/s as a
  floor and expired/shed as exact zeros in the no-fault configuration.

With no faults, no guards, and default admission (no deadlines, no bound)
the dispatch path is unchanged from PR 8: same jax calls, same extraction,
bit-identical outputs under any arrival interleaving.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.errors import (DeviceInitError, DispatchFailedError,
                          GuardViolation)
from repro.serving.admission import (AdmissionQueue, QueuedRequest,
                                     RequestError)
from repro.serving.faults import (FaultInjector, FaultPlan,
                                  dispatch_with_retry)
from repro.serving.stats import EngineStats


class ShardedServingEngine:
    """Continuous-batching engine over an ``[R, L]`` replica × lane grid.

    ``deployment`` is a ``repro.deploy.Deployment`` (or a graph, which is
    built through the facade).  ``replicas=None`` takes every visible
    device; ``lanes`` is the vmap width per replica, so one dispatch
    serves up to ``replicas * lanes`` requests.

    Failure-layer knobs (all default-off; see the module docstring):
    ``max_pending`` bounds the queue, ``max_retries``/``dispatch_timeout``
    bound the retry/watchdog loop, ``faults`` injects a seeded
    ``FaultPlan``, ``fallback_single_device`` controls mesh-init
    degradation, and ``clock`` is injectable so deadline/latency logic is
    testable against a fake clock.
    """

    def __init__(self, deployment, *, replicas: Optional[int] = None,
                 lanes: int = 4, max_pending: Optional[int] = None,
                 max_retries: int = 2,
                 dispatch_timeout: Optional[float] = None,
                 faults: Union[FaultPlan, FaultInjector, None] = None,
                 fallback_single_device: bool = True,
                 clock: Callable[[], float] = time.perf_counter,
                 **build_opts):
        from repro.deploy import Deployment, build
        if not isinstance(deployment, Deployment):
            deployment = build(deployment, **build_opts)
        elif build_opts:
            raise ValueError(f"build options {sorted(build_opts)} are for "
                             f"graph arguments; this is already a Deployment")
        self.deployment = deployment
        self.executor = deployment.executor
        self._clock = clock
        self.max_retries = int(max_retries)
        self.dispatch_timeout = dispatch_timeout
        self._faults = (FaultInjector(faults)
                        if isinstance(faults, FaultPlan) else faults)
        self._degraded: List[str] = list(deployment.degraded)
        n_dev = len(jax.devices())
        self.replicas = n_dev if replicas is None else min(replicas, n_dev)
        if self.replicas < 1:
            raise ValueError("need at least one replica")
        self.lanes = int(lanes)
        try:
            if self._faults is not None:
                self._faults.engine_init()
            self._fn = self.executor.replicated_fn(self.replicas)
        except (DeviceInitError, RuntimeError) as e:
            if not fallback_single_device:
                raise
            # graceful degradation: the replica mesh is unavailable — serve
            # everything through the single-device batched program (shaped
            # back to [1, L, arena] so the step loop is unchanged)
            self._degraded.append(
                f"replica mesh init failed ({type(e).__name__}: {e}); "
                f"falling back to single-device serving")
            self.replicas = 1
            size = self.executor.arena_size
            batched = self.executor.batched_fn()
            self._fn = (lambda batch:
                        batched(batch.reshape(self.lanes, size))
                        .reshape(1, self.lanes, size))
        self._queue = AdmissionQueue(max_pending=max_pending)
        self._results: Dict[int, Any] = {}
        self._latencies: List[float] = []
        self._next_rid = 0
        self._dispatches = 0
        self._padded = 0
        self._completed = 0
        self._admitted = 0
        self._retried = 0
        self._failed = 0
        self._trips = 0
        self._t_first_submit: Optional[float] = None
        self.stats = EngineStats(
            arena_bytes=deployment.arena_bytes,
            schedule_peak_bytes=int(deployment.schedule_result.peak),
            schedule_method=deployment.schedule_result.method,
            replicas=self.replicas, lanes=self.lanes)

    # ------------------------------------------------------ admission queue
    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def capacity(self) -> int:
        """Requests per dispatch: replicas × lanes."""
        return self.replicas * self.lanes

    def submit(self, inputs: Dict[str, Any], *, priority: int = 0,
               deadline: Optional[float] = None) -> int:
        """Enqueue one request; returns its rid.  ``priority`` (larger
        first, ties FIFO) and ``deadline`` (absolute, on this engine's
        clock; None = never expires) drive admission.  A submission over
        ``max_pending`` is shed: its result is immediately a typed
        ``RequestError("shed")`` — the rid contract is unchanged."""
        rid = self._next_rid
        self._next_rid += 1
        now = self._clock()
        if self._t_first_submit is None:
            self._t_first_submit = now
        req = QueuedRequest(rid, inputs, now, priority=priority,
                            deadline=deadline)
        if not self._queue.push(req):
            self._results[rid] = RequestError(
                rid, "shed",
                f"queue at max_pending={self._queue.max_pending}")
        return rid

    # --------------------------------------------------------- fault layer
    def _detect_lane(self, lane: np.ndarray, injected_corrupt: bool
                     ) -> Optional[str]:
        """Post-dispatch poison detection for one lane's host arena copy.
        Returns the typed error code, or None for a clean lane."""
        ex = self.executor
        if ex.guard_regions:
            try:
                ex.verify_guards(lane)       # genuine canary verification
            except GuardViolation:
                if self._faults is None:
                    raise        # no injection active: a real OOB write
                return "corrupted"
        out = ex.outputs_from(lane)
        for val in out.values():
            arr = np.asarray(val)
            if arr.dtype.kind == "f" and np.isnan(arr).any():
                return "nan_output"          # genuine NaN scan
        if injected_corrupt and not ex.guard_regions:
            # guard-less runs: the injector's lane report stands in for the
            # ECC/bus-fault signal real hardware raises on a flipped byte
            return "corrupted"
        return None

    def _resolve_poisoned(self, req: QueuedRequest, code: str) -> None:
        """A poisoned lane either re-queues (bounded) or fails typed."""
        if req.retries < self.max_retries:
            req.retries += 1
            self._retried += 1
            self._queue.requeue(req)
        else:
            self._results[req.rid] = RequestError(
                req.rid, code,
                f"retry budget ({self.max_retries}) exhausted")
            self._failed += 1

    # -------------------------------------------------------------- serving
    def step(self) -> int:
        """One dispatch: admit up to ``capacity`` queued requests by
        (priority, arrival) — expiring past-deadline ones — pad the ragged
        remainder with zero arenas, execute the replicated program under
        retry/watchdog, detect injected poison, complete the survivors.
        Returns how many completed successfully."""
        if not self._queue:
            return 0
        ex = self.executor
        now = self._clock()
        admitted, expired = self._queue.pop_ready(self.capacity, now)
        for req in expired:
            self._results[req.rid] = RequestError(
                req.rid, "expired",
                f"deadline {req.deadline:.6f} passed at {now:.6f}")
        if not admitted:
            return 0
        self._admitted += len(admitted)
        stack = [ex.make_arena(req.inputs) for req in admitted]
        n_pad = self.capacity - len(stack)
        if n_pad:
            pad = ex.pad_arena()
            stack.extend([pad] * n_pad)
            self._padded += n_pad

        # the pmap path does not donate, but the single-device fallback's
        # batched_fn does — re-stacking per attempt keeps retry safe in
        # both (the per-lane arenas in ``stack`` are never donated)
        def dispatch():
            batch = jnp.stack(stack).reshape(
                (self.replicas, self.lanes, ex.arena_size))
            arenas = self._fn(batch)
            jax.block_until_ready(arenas)
            return arenas

        try:
            arenas, r, w = dispatch_with_retry(
                dispatch, faults=self._faults,
                max_retries=self.max_retries,
                dispatch_timeout=self.dispatch_timeout, clock=self._clock)
        except DispatchFailedError as e:
            for req in admitted:
                self._results[req.rid] = RequestError(
                    req.rid, "dispatch_failed", str(e))
            self._failed += len(admitted)
            self._retried += getattr(e, "retried", self.max_retries)
            self._trips += getattr(e, "watchdog_trips", 0)
            return 0
        self._retried += r
        self._trips += w
        self._dispatches += 1
        t_done = self._clock()

        lane_faults = (self._faults is not None
                       and self._faults.plan.any_lane_faults())
        if not lane_faults and not ex.guard_regions:
            # production path: identical to the pre-failure-layer engine —
            # outputs extracted straight from the device arenas, no host
            # copy, bit-identity preserved
            for i, req in enumerate(admitted):   # lanes i >= len(admitted)
                r_, b_ = divmod(i, self.lanes)   # are pads: never extracted
                self._results[req.rid] = ex.outputs_from(arenas[r_, b_])
                self._latencies.append(t_done - req.t_submit)
            self._completed += len(admitted)
            return len(admitted)

        # fault/guard path: work on a writable host copy (np.asarray of a
        # jax buffer is a read-only view — the device buffer is never
        # mutated), inject per-lane poison, then detect and resolve
        host = np.array(arenas)
        corrupt = set()
        if lane_faults:
            corrupt = set(self._faults.corrupt_lanes(len(admitted)))
            for i in corrupt:
                r_, b_ = divmod(i, self.lanes)
                self._faults.corrupt_arena(host[r_, b_], ex.guard_regions)
            for i in self._faults.nan_lanes(len(admitted)):
                if i in corrupt:
                    continue
                r_, b_ = divmod(i, self.lanes)
                self._faults.inject_nan(host[r_, b_], ex)
        done = 0
        for i, req in enumerate(admitted):
            r_, b_ = divmod(i, self.lanes)
            lane = host[r_, b_]
            code = self._detect_lane(lane, i in corrupt)
            if code is not None:
                self._resolve_poisoned(req, code)
                continue
            self._results[req.rid] = ex.outputs_from(lane)
            self._latencies.append(t_done - req.t_submit)
            done += 1
        self._completed += done
        return done

    def take(self, rid: int):
        """The completed result for ``rid`` (pops it): an outputs dict, or
        a typed ``RequestError`` for expired/shed/failed requests."""
        return self._results.pop(rid)

    def drain(self) -> Dict[int, Any]:
        """Step until the queue is empty; returns {rid: result} for every
        result completed and not yet taken (outputs dicts and typed
        ``RequestError`` entries), and records serve stats — including the
        failure-layer counters — over the window since the first
        un-drained submit."""
        while self._queue:
            self.step()
        wall = (self._clock() - self._t_first_submit
                if self._t_first_submit is not None else 0.0)
        self.stats.record_serve(
            requests=self._completed, padded_lanes=self._padded,
            dispatches=self._dispatches, wall_s=wall,
            latencies_s=self._latencies)
        self.stats.admitted = self._admitted
        self.stats.expired = self._queue.expired
        self.stats.shed = self._queue.shed
        self.stats.retried = self._retried
        self.stats.failed = self._failed
        self.stats.watchdog_trips = self._trips
        self.stats.degraded = list(self._degraded) or None
        self._completed = 0
        self._admitted = 0
        self._retried = 0
        self._failed = 0
        self._trips = 0
        self._dispatches = 0
        self._padded = 0
        self._latencies = []
        self._queue.expired = 0
        self._queue.shed = 0
        self._t_first_submit = None
        out, self._results = self._results, {}
        return out

    # -------------------------------------------------------- one-shot API
    def serve(self, requests: Sequence[Dict[str, Any]]
              ) -> List[Dict[str, Any]]:
        """Submit every request, drain, return outputs in request order
        (same contract as ``GraphServingEngine.serve``)."""
        rids = [self.submit(r) for r in requests]
        done = self.drain()
        return [done[rid] for rid in rids]
