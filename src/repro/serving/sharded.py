"""Sharded continuous-batching serving engine (the production tier).

``GraphServingEngine`` amortises XLA dispatch across vmap lanes but still
runs every batch on one device and makes late requests wait for the whole
serve loop.  ``ShardedServingEngine`` scales that out and opens the batch
boundary:

* **Replica sharding** — the deployed arena program is ``pmap(vmap(...))``
  over ``replicas`` devices: each dispatch executes an ``[R, L, arena]``
  stack, R replicas × L vmap lanes, with no collectives (requests are
  embarrassingly parallel), so per-lane results are bit-identical to a
  single ``Deployment.run``.  On CPU hosts the replica mesh comes from
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — call
  ``force_host_devices(N)`` (importable without touching jax) before the
  first jax import.
* **Continuous batching at dispatch granularity** — requests enter an
  admission queue (``submit``); every ``step`` admits up to R×L queued
  requests *at that batch boundary*.  A late arrival joins the next
  dispatch instead of waiting for the current serve loop to finish —
  "continuous" here means per super-step, the same granularity at which
  Pex's partial execution trades memory for recompute inside each lane.
* **Honest ragged tails** — when fewer than R×L requests are admitted the
  remaining lanes are padded with explicit all-zero arenas: executed (one
  compiled shape, no per-remainder XLA recompiles), counted in
  ``stats.padded_lanes``, never extracted and never part of per-request
  latency.
* **Typed stats** — per-request latency (admission → completion of the
  request's dispatch) p50/p99 and engine throughput (true requests / wall
  second) in ``EngineStats``; the ``requests/s`` figure is what
  ``benchmarks/bench_serving.py`` gates in CI.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.serving.stats import EngineStats


@dataclasses.dataclass
class _Pending:
    rid: int
    inputs: Dict[str, Any]
    t_submit: float


class ShardedServingEngine:
    """Continuous-batching engine over an ``[R, L]`` replica × lane grid.

    ``deployment`` is a ``repro.deploy.Deployment`` (or a graph, which is
    built through the facade).  ``replicas=None`` takes every visible
    device; ``lanes`` is the vmap width per replica, so one dispatch
    serves up to ``replicas * lanes`` requests.
    """

    def __init__(self, deployment, *, replicas: Optional[int] = None,
                 lanes: int = 4, **build_opts):
        from repro.deploy import Deployment, build
        if not isinstance(deployment, Deployment):
            deployment = build(deployment, **build_opts)
        elif build_opts:
            raise ValueError(f"build options {sorted(build_opts)} are for "
                             f"graph arguments; this is already a Deployment")
        self.deployment = deployment
        self.executor = deployment.executor
        n_dev = len(jax.devices())
        self.replicas = n_dev if replicas is None else min(replicas, n_dev)
        if self.replicas < 1:
            raise ValueError("need at least one replica")
        self.lanes = int(lanes)
        self._fn = self.executor.replicated_fn(self.replicas)
        self._queue: collections.deque[_Pending] = collections.deque()
        self._results: Dict[int, Dict[str, Any]] = {}
        self._latencies: List[float] = []
        self._next_rid = 0
        self._dispatches = 0
        self._padded = 0
        self._completed = 0
        self._t_first_submit: Optional[float] = None
        self.stats = EngineStats(
            arena_bytes=deployment.arena_bytes,
            schedule_peak_bytes=int(deployment.schedule_result.peak),
            schedule_method=deployment.schedule_result.method,
            replicas=self.replicas, lanes=self.lanes)

    # ------------------------------------------------------ admission queue
    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def capacity(self) -> int:
        """Requests per dispatch: replicas × lanes."""
        return self.replicas * self.lanes

    def submit(self, inputs: Dict[str, Any]) -> int:
        """Enqueue one request; returns its rid.  The request joins the
        next dispatch boundary (continuous batching): admission order is
        submission order, whatever the interleaving with ``step`` calls."""
        rid = self._next_rid
        self._next_rid += 1
        now = time.perf_counter()
        if self._t_first_submit is None:
            self._t_first_submit = now
        self._queue.append(_Pending(rid, inputs, now))
        return rid

    def step(self) -> int:
        """One dispatch: admit up to ``capacity`` queued requests, pad the
        ragged remainder with zero arenas, execute the replicated program,
        complete the admitted requests.  Returns how many completed."""
        if not self._queue:
            return 0
        ex = self.executor
        admitted = [self._queue.popleft()
                    for _ in range(min(len(self._queue), self.capacity))]
        stack = [ex.make_arena(p.inputs) for p in admitted]
        n_pad = self.capacity - len(stack)
        if n_pad:
            pad = ex.pad_arena()
            stack.extend([pad] * n_pad)
            self._padded += n_pad
        batch = jnp.stack(stack).reshape(
            (self.replicas, self.lanes, ex.arena_size))
        arenas = self._fn(batch)
        jax.block_until_ready(arenas)
        t_done = time.perf_counter()
        for i, p in enumerate(admitted):      # lanes i >= len(admitted)
            r, b = divmod(i, self.lanes)      # are pads: never extracted
            self._results[p.rid] = ex.outputs_from(arenas[r, b])
            self._latencies.append(t_done - p.t_submit)
        self._dispatches += 1
        self._completed += len(admitted)
        return len(admitted)

    def take(self, rid: int) -> Dict[str, Any]:
        """The completed outputs for ``rid`` (pops them)."""
        return self._results.pop(rid)

    def drain(self) -> Dict[int, Dict[str, Any]]:
        """Step until the queue is empty; returns {rid: outputs} for every
        result completed and not yet taken, and records serve stats over
        the window since the first un-drained submit."""
        while self._queue:
            self.step()
        wall = (time.perf_counter() - self._t_first_submit
                if self._t_first_submit is not None else 0.0)
        self.stats.record_serve(
            requests=self._completed, padded_lanes=self._padded,
            dispatches=self._dispatches, wall_s=wall,
            latencies_s=self._latencies)
        self._completed = 0
        self._dispatches = 0
        self._padded = 0
        self._latencies = []
        self._t_first_submit = None
        out, self._results = self._results, {}
        return out

    # -------------------------------------------------------- one-shot API
    def serve(self, requests: Sequence[Dict[str, Any]]
              ) -> List[Dict[str, Any]]:
        """Submit every request, drain, return outputs in request order
        (same contract as ``GraphServingEngine.serve``)."""
        rids = [self.submit(r) for r in requests]
        done = self.drain()
        return [done[rid] for rid in rids]
