"""Deadline- and priority-aware admission for the serving engines.

The FIFO deque the sharded engine shipped with (PR 8) admitted strictly by
arrival and ran every queued request, however stale.  On a deadline-driven
deployment that is the wrong contract twice over: a request whose deadline
has already passed burns a dispatch producing an answer nobody will read,
and an unbounded queue turns overload into unbounded latency for everyone
instead of fast, explicit rejection for the excess.  ``AdmissionQueue``
fixes both:

* **priority admission** — requests are admitted by ``(priority, arrival)``:
  numerically larger ``priority`` first, ties in submission order (so the
  default ``priority=0`` queue is exactly the old FIFO — admission order is
  bit-for-bit unchanged for existing callers);
* **deadline expiry** — a request whose absolute ``deadline`` has passed by
  the time it would be admitted is *never executed*: it is returned on the
  ``expired`` side of ``pop_ready`` and the engine records a typed
  ``RequestError("expired")`` result for it;
* **bounded depth / load shedding** — with ``max_pending`` set, ``push``
  refuses requests beyond the bound (returns ``False``); the engine records
  a typed ``RequestError("shed")`` so backpressure is an explicit, typed
  outcome, not a hidden latency cliff.

Counts (``shed``, ``expired``) are exact and maintained here, property
tested in tests/test_admission.py against a reference model under random
arrival/deadline interleavings.  The queue is clock-agnostic: callers pass
``now`` explicitly, so tests and the chaos suite drive a fake clock.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, List, Optional, Tuple


@dataclasses.dataclass
class QueuedRequest:
    """One pending request.  ``deadline`` is an *absolute* clock value
    (same clock as the engine's), ``None`` = never expires; larger
    ``priority`` admits first; ``retries`` counts fault-layer re-admissions
    already consumed (bounded by the engine's ``max_retries``)."""

    rid: int
    inputs: Any
    t_submit: float
    priority: int = 0
    deadline: Optional[float] = None
    retries: int = 0


@dataclasses.dataclass
class RequestError:
    """Typed per-request failure result.  Engines store these in place of
    an output dict so one bad request never tears down the serve loop;
    ``code`` is machine-checkable:

    * ``"expired"``          — deadline passed before admission
    * ``"shed"``             — queue at ``max_pending``, request refused
    * ``"dispatch_failed"``  — dispatch retries exhausted
    * ``"corrupted"``        — arena corruption detected, retries exhausted
    * ``"nan_output"``       — NaN activations detected, retries exhausted
    """

    rid: int
    code: str
    detail: str = ""


class AdmissionQueue:
    """Priority + arrival admission with deadline expiry and a bounded
    depth.  ``push`` → ``pop_ready`` is the whole lifecycle; the caller
    owns what happens to shed/expired requests (typed results)."""

    def __init__(self, max_pending: Optional[int] = None) -> None:
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.max_pending = max_pending
        self._heap: List[Tuple[int, int, QueuedRequest]] = []
        self._seq = 0
        self.shed = 0       # exact count of refused pushes
        self.expired = 0    # exact count of deadline-expired pops

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, req: QueuedRequest) -> bool:
        """Enqueue ``req``; ``False`` = shed (queue at ``max_pending``)."""
        if self.max_pending is not None and len(self._heap) >= self.max_pending:
            self.shed += 1
            return False
        heapq.heappush(self._heap, (-req.priority, self._seq, req))
        self._seq += 1
        return True

    def requeue(self, req: QueuedRequest) -> None:
        """Re-admit a request the fault layer wants retried.  Bypasses the
        ``max_pending`` bound — the request was already admitted once and
        shedding it now would double-charge the overload policy.  It keeps
        its priority but takes a fresh arrival position (behind same-
        priority peers: a retry must not starve fresh requests)."""
        heapq.heappush(self._heap, (-req.priority, self._seq, req))
        self._seq += 1

    def pop_ready(self, k: int, now: float
                  ) -> Tuple[List[QueuedRequest], List[QueuedRequest]]:
        """Admit up to ``k`` requests by (priority desc, arrival asc) at
        clock ``now``.  Returns ``(admitted, expired)``: requests whose
        deadline has passed are diverted to ``expired`` — they never count
        against ``k`` and are never executed."""
        admitted: List[QueuedRequest] = []
        expired: List[QueuedRequest] = []
        while self._heap and len(admitted) < k:
            _, _, req = heapq.heappop(self._heap)
            if req.deadline is not None and now >= req.deadline:
                expired.append(req)
                self.expired += 1
            else:
                admitted.append(req)
        return admitted, expired


__all__ = ["AdmissionQueue", "QueuedRequest", "RequestError"]
