"""Serving engines over deployed graphs (single-device tier).

``GraphServingEngine`` serves CNN computation graphs through a
``repro.deploy.Deployment`` (schedule → plan → validate → compile in one
facade call): requests run in **micro-batches** — each batch vmaps the
compiled arena program over a [B, arena_size] stack of arenas, so B
inferences share one XLA dispatch.  A ragged final batch is padded with
explicit all-zero arenas: pad lanes are executed (one compiled shape for
the whole serve loop instead of an XLA recompile per remainder size) but
are **accounted separately** (``stats.padded_lanes``) and never extracted
— they are not requests, and per-request stats never count them.

For replica-sharded continuous batching see ``serving/sharded.py``; both
engines report the same typed ``EngineStats`` (``serving/stats.py``).

``ServingEngine`` runs prefill + greedy decode over batches of LLM
requests.  The paper's contribution shows up at two levels (DESIGN.md §2,
L1/L2):

* **L1 — operator reordering of the decode step**: the jitted step function
  is traced and its jaxpr equations re-scheduled with the paper's algorithm;
  the engine reports the peak-liveness delta (on TPU, XLA re-schedules after
  us, so the simulated liveness is the contract — same accounting the paper
  uses for TFLite).

* **L2 — KV-block arena planning**: each admitted request owns a KV block
  whose lifetime is [admission, completion).  Blocks live in one HBM arena
  managed either by the paper's §4 dynamic allocator (first-fit + defrag,
  online) or by the §6 offline ``ArenaPlanner`` when the request schedule is
  known (batch mode).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.allocator import DynamicAllocator
from repro.core.graph import Graph
from repro.core.jaxpr_reorder import reorder_closed_jaxpr
from repro.models.model import Model, init_cache
from repro.serving.stats import EngineStats


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: List[int]
    prefill_ms: float
    decode_ms: float


def kv_block_bytes(cfg: ModelConfig, cache_len: int) -> int:
    """Per-request KV/state bytes at full cache length (batch=1)."""
    c = jax.eval_shape(lambda: init_cache(cfg, 1, cache_len))
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(c))


class GraphServingEngine:
    """Micro-batched single-device serving of a deployed CNN graph.

    Construct from a graph (the facade runs schedule→plan→compile) or pass
    an existing ``deployment=`` to share one compiled program between
    engines.  ``serve`` runs micro-batches of ``micro_batch`` vmap lanes;
    ``stats`` is a typed ``EngineStats``.
    """

    def __init__(self, graph: Optional[Graph] = None, *,
                 deployment=None, arena_budget: Optional[int] = None,
                 partition: bool = False, micro_batch: int = 8,
                 use_pallas: bool = False, faults=None,
                 max_retries: int = 2,
                 dispatch_timeout: Optional[float] = None):
        if deployment is None:
            if graph is None:
                raise ValueError("need a graph or a deployment")
            from repro.deploy import build
            deployment = build(graph, arena_budget=arena_budget,
                               partition=partition, use_pallas=use_pallas)
        self.deployment = deployment
        # failure layer (DESIGN.md §12): seeded fault injection + bounded
        # retry/watchdog around each micro-batch dispatch.  All off by
        # default — the no-fault path adds zero work per dispatch.
        from repro.serving.faults import FaultInjector, FaultPlan
        self.faults = (FaultInjector(faults)
                       if isinstance(faults, FaultPlan) else faults)
        self.max_retries = int(max_retries)
        self.dispatch_timeout = dispatch_timeout
        # aliases kept from the pre-facade engine API
        self.result = deployment.schedule_result
        self.exec_graph = deployment.exec_graph
        self.plan = deployment.plan
        self.executor = deployment.executor
        self.micro_batch = micro_batch
        self._batched = self.executor.batched_fn()
        self.stats = EngineStats(
            arena_bytes=int(self.plan.arena_size),
            schedule_peak_bytes=int(self.result.peak),
            schedule_method=self.result.method,
            replicas=1, lanes=micro_batch)

    def serve(self, requests: Sequence[Dict[str, Any]]
              ) -> List[Dict[str, Any]]:
        """Run every request's input dict through the compiled graph;
        returns one output dict per request, in order."""
        from repro.serving.faults import dispatch_with_retry
        ex = self.executor
        results: List[Dict[str, Any]] = []
        latencies: List[float] = []
        padded = 0
        n_batches = 0
        retried = 0
        trips = 0
        t_start = time.perf_counter()
        for i in range(0, len(requests), self.micro_batch):
            chunk = requests[i:i + self.micro_batch]
            stack = [ex.make_arena(r) for r in chunk]
            # pad a ragged tail up to micro_batch with explicit zero
            # arenas: one compiled shape for the whole serve loop instead
            # of one XLA compile (seconds on MobileNet-scale graphs) per
            # distinct remainder size.  Pad lanes are executed but are
            # not requests: counted in stats.padded_lanes, never
            # extracted, never in per-request latency.
            n_pad = self.micro_batch - len(chunk)
            if n_pad:
                pad = ex.pad_arena()
                stack.extend([pad] * n_pad)
                padded += n_pad
            # the jitted batch fn donates its input, so each retry attempt
            # must re-stack from the (undonated) per-lane arenas
            arenas, r, w = dispatch_with_retry(
                lambda s=stack: self._batched(jnp.stack(s)),
                faults=self.faults, max_retries=self.max_retries,
                dispatch_timeout=self.dispatch_timeout)
            retried += r
            trips += w
            n_batches += 1
            if ex.guard_regions:          # guard-byte debug mode only
                for b in range(len(chunk)):
                    ex.verify_guards(arenas[b])
            for b in range(len(chunk)):       # pad lanes b >= len(chunk)
                results.append(ex.outputs_from(arenas[b]))   # skipped here
            t_done = time.perf_counter()
            # one-shot serve admits everything at t_start, so a request's
            # latency is its batch's completion time
            latencies.extend([t_done - t_start] * len(chunk))
        wall = time.perf_counter() - t_start
        self.stats.record_serve(requests=len(requests), padded_lanes=padded,
                                dispatches=n_batches, wall_s=wall,
                                latencies_s=latencies)
        self.stats.admitted = len(requests)
        self.stats.retried = retried
        self.stats.watchdog_trips = trips
        return results


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 cache_len: int = 128, mesh=None,
                 execute_reordered: bool = False,
                 hbm_budget: Optional[int] = None):
        self.cfg = cfg
        self.model = Model(cfg, mesh)
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.execute_reordered = execute_reordered
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, cache_len=cache_len))
        self._decode = jax.jit(self.model.decode_step)
        # ---- L2: KV arena (virtual HBM bookkeeping)
        self.block_bytes = kv_block_bytes(cfg, cache_len)
        self.arena = DynamicAllocator(capacity=hbm_budget)
        self.reorder_report = None
        self.stats = EngineStats(lanes=max_batch)

    # --------------------------------------------------------- L1 reorder
    def analyse_decode_schedule(self, batch_size: int):
        """Trace the decode step, apply the paper's scheduler to its jaxpr,
        record the liveness report.  Returns the report."""
        cache = jax.eval_shape(
            lambda: init_cache(self.cfg, batch_size, self.cache_len))
        toks = jax.ShapeDtypeStruct((batch_size,), jnp.int32)
        closed = jax.make_jaxpr(
            lambda p, c, t: self.model.decode_step(p, c, t))(
            self.params, cache, toks)
        _, rep = reorder_closed_jaxpr(closed)
        self.reorder_report = rep
        return rep

    # ------------------------------------------------------------ serving
    def serve(self, requests: Sequence[Request]) -> List[RequestResult]:
        """Batch-mode serving: admit up to max_batch requests at a time.
        All prompts in a batch are right-aligned to the longest one."""
        results: List[RequestResult] = []
        pending = list(requests)
        peak_concurrent = 0
        t_start = time.perf_counter()
        latencies: List[float] = []
        n_batches = 0
        while pending:
            batch = pending[:self.max_batch]
            pending = pending[self.max_batch:]
            # L2: allocate a KV block per admitted request
            for r in batch:
                self.arena.alloc(f"req{r.rid}", self.block_bytes)
            peak_concurrent = max(peak_concurrent, len(batch))
            results.extend(self._run_batch(batch))
            n_batches += 1
            t_done = time.perf_counter()
            latencies.extend([t_done - t_start] * len(batch))
            for r in batch:
                self.arena.free(f"req{r.rid}")
            self.arena.defragment()
        wall = time.perf_counter() - t_start
        self.stats.record_serve(requests=len(requests), padded_lanes=0,
                                dispatches=n_batches, wall_s=wall,
                                latencies_s=latencies)
        self.stats.kv_arena_peak_bytes = self.arena.stats.peak_bytes
        self.stats.kv_static_bytes = self.block_bytes * len(requests)
        self.stats.peak_concurrent = peak_concurrent
        return results

    def _run_batch(self, batch: Sequence[Request]) -> List[RequestResult]:
        cfg = self.cfg
        B = len(batch)
        S = max(len(r.prompt) for r in batch)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(batch):       # left-pad with token 0
            toks[i, S - len(r.prompt):] = r.prompt
        feed = {"tokens": jnp.asarray(toks)}
        if cfg.num_patch_tokens:
            feed["patches"] = jnp.zeros(
                (B, cfg.num_patch_tokens, cfg.frontend_dim), jnp.float32)
        if cfg.arch_type == "audio":
            feed["frames"] = jnp.zeros(
                (B, cfg.encoder_seq, cfg.frontend_dim), jnp.float32)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, feed)
        logits.block_until_ready()
        t_pre = (time.perf_counter() - t0) * 1e3

        max_new = max(r.max_new_tokens for r in batch)
        out = [[] for _ in batch]
        t0 = time.perf_counter()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for step in range(max_new):
            for i, r in enumerate(batch):
                if step < r.max_new_tokens:
                    out[i].append(int(tok[i]))
            if step == max_new - 1:
                break
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        t_dec = (time.perf_counter() - t0) * 1e3
        return [RequestResult(r.rid, out[i], t_pre, t_dec)
                for i, r in enumerate(batch)]
