"""Batched serving engines with paper-integrated memory management.

``GraphServingEngine`` serves CNN computation graphs through the compiled
arena executor (``mcu/compile.py``): the graph is scheduled once
(reordering + optional partial execution against an arena budget), planned
into one arena, lowered to a single jitted program, and requests are served
in **micro-batches** — each micro-batch vmaps the arena program over a
[B, arena_size] stack of arenas, so B inferences share one XLA dispatch.

``ServingEngine`` runs prefill + greedy decode over batches of LLM
requests.  The paper's contribution shows up at two levels (DESIGN.md §2,
L1/L2):

* **L1 — operator reordering of the decode step**: the jitted step function
  is traced and its jaxpr equations re-scheduled with the paper's algorithm;
  the engine reports the peak-liveness delta (on TPU, XLA re-schedules after
  us, so the simulated liveness is the contract — same accounting the paper
  uses for TFLite).  With ``execute_reordered=True`` the engine actually
  evaluates the reordered jaxpr (bit-identical results; used by tests).

* **L2 — KV-block arena planning**: each admitted request owns a KV block
  whose lifetime is [admission, completion).  Blocks live in one HBM arena
  managed either by the paper's §4 dynamic allocator (first-fit + defrag,
  online) or by the §6 offline ``ArenaPlanner`` when the request schedule is
  known (batch mode).  The engine reports peak arena bytes vs the static
  all-requests-resident footprint.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.allocator import ArenaPlanner, DynamicAllocator
from repro.core.graph import Graph
from repro.core.heuristics import schedule as schedule_graph
from repro.core.jaxpr_reorder import reorder_closed_jaxpr
from repro.mcu.compile import compile_schedule
from repro.models.model import Model, init_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: List[int]
    prefill_ms: float
    decode_ms: float


def kv_block_bytes(cfg: ModelConfig, cache_len: int) -> int:
    """Per-request KV/state bytes at full cache length (batch=1)."""
    c = jax.eval_shape(lambda: init_cache(cfg, 1, cache_len))
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(c))


class GraphServingEngine:
    """Serve a CNN computation graph through the compiled arena executor.

    One-time setup: schedule (reorder + optional partial execution against
    ``arena_budget``), plan the arena, lower to a single jitted program.
    ``serve`` then runs micro-batches: each batch stacks B arenas and vmaps
    the arena program once, amortising dispatch across requests — the
    multi-model/multi-tenant story all future backend work plugs into.
    """

    def __init__(self, graph: Graph, *, arena_budget: Optional[int] = None,
                 partition: bool = False, micro_batch: int = 8,
                 use_pallas: bool = False):
        res = schedule_graph(graph, arena_budget=arena_budget,
                             partition=partition)
        self.result = res
        self.exec_graph = res.graph if res.graph is not None else graph
        self.plan = ArenaPlanner.plan(self.exec_graph, res.schedule)
        ArenaPlanner.validate(self.plan, self.exec_graph)
        self.executor = compile_schedule(self.exec_graph, res.schedule,
                                         self.plan, use_pallas=use_pallas)
        self.micro_batch = micro_batch
        self._batched = jax.jit(jax.vmap(self.executor.raw_fn),
                                donate_argnums=0)
        self.stats: Dict[str, float] = {
            "schedule_peak_bytes": res.peak,
            "arena_bytes": self.plan.arena_size,
            "schedule_method": res.method,
        }

    def serve(self, requests: Sequence[Dict[str, np.ndarray]]
              ) -> List[Dict[str, np.ndarray]]:
        """Run every request's input dict through the compiled graph;
        returns one output dict per request, in order."""
        results: List[Dict[str, np.ndarray]] = []
        t0 = time.perf_counter()
        n_batches = 0
        for i in range(0, len(requests), self.micro_batch):
            chunk = requests[i:i + self.micro_batch]
            stack = [self.executor.make_arena(r) for r in chunk]
            # pad a ragged tail up to micro_batch: one compiled shape for
            # the whole serve loop instead of one XLA compile (seconds on
            # MobileNet-scale graphs) per distinct remainder size
            stack.extend([stack[0]] * (self.micro_batch - len(chunk)))
            arenas = self._batched(jnp.stack(stack))
            n_batches += 1
            for b in range(len(chunk)):
                results.append(self.executor.outputs_from(arenas[b]))
        wall = time.perf_counter() - t0
        if requests:
            self.stats["us_per_request"] = wall * 1e6 / len(requests)
        self.stats["micro_batches"] = n_batches
        self.stats["requests"] = len(requests)
        return results


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 cache_len: int = 128, mesh=None,
                 execute_reordered: bool = False,
                 hbm_budget: Optional[int] = None):
        self.cfg = cfg
        self.model = Model(cfg, mesh)
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.execute_reordered = execute_reordered
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, cache_len=cache_len))
        self._decode = jax.jit(self.model.decode_step)
        # ---- L2: KV arena (virtual HBM bookkeeping)
        self.block_bytes = kv_block_bytes(cfg, cache_len)
        self.arena = DynamicAllocator(capacity=hbm_budget)
        self.reorder_report = None
        self.stats: Dict[str, float] = {}

    # --------------------------------------------------------- L1 reorder
    def analyse_decode_schedule(self, batch_size: int):
        """Trace the decode step, apply the paper's scheduler to its jaxpr,
        record the liveness report.  Returns the report."""
        cache = jax.eval_shape(
            lambda: init_cache(self.cfg, batch_size, self.cache_len))
        toks = jax.ShapeDtypeStruct((batch_size,), jnp.int32)
        closed = jax.make_jaxpr(
            lambda p, c, t: self.model.decode_step(p, c, t))(
            self.params, cache, toks)
        _, rep = reorder_closed_jaxpr(closed)
        self.reorder_report = rep
        return rep

    # ------------------------------------------------------------ serving
    def serve(self, requests: Sequence[Request]) -> List[RequestResult]:
        """Batch-mode serving: admit up to max_batch requests at a time.
        All prompts in a batch are right-aligned to the longest one."""
        results: List[RequestResult] = []
        pending = list(requests)
        peak_concurrent = 0
        while pending:
            batch = pending[:self.max_batch]
            pending = pending[self.max_batch:]
            # L2: allocate a KV block per admitted request
            for r in batch:
                self.arena.alloc(f"req{r.rid}", self.block_bytes)
            peak_concurrent = max(peak_concurrent, len(batch))
            results.extend(self._run_batch(batch))
            for r in batch:
                self.arena.free(f"req{r.rid}")
            self.arena.defragment()
        self.stats["arena_peak_bytes"] = self.arena.stats.peak_bytes
        self.stats["static_bytes"] = self.block_bytes * len(requests)
        self.stats["peak_concurrent"] = peak_concurrent
        return results

    def _run_batch(self, batch: Sequence[Request]) -> List[RequestResult]:
        cfg = self.cfg
        B = len(batch)
        S = max(len(r.prompt) for r in batch)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(batch):       # left-pad with token 0
            toks[i, S - len(r.prompt):] = r.prompt
        feed = {"tokens": jnp.asarray(toks)}
        if cfg.num_patch_tokens:
            feed["patches"] = jnp.zeros(
                (B, cfg.num_patch_tokens, cfg.frontend_dim), jnp.float32)
        if cfg.arch_type == "audio":
            feed["frames"] = jnp.zeros(
                (B, cfg.encoder_seq, cfg.frontend_dim), jnp.float32)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, feed)
        logits.block_until_ready()
        t_pre = (time.perf_counter() - t0) * 1e3

        max_new = max(r.max_new_tokens for r in batch)
        out = [[] for _ in batch]
        t0 = time.perf_counter()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for step in range(max_new):
            for i, r in enumerate(batch):
                if step < r.max_new_tokens:
                    out[i].append(int(tok[i]))
            if step == max_new - 1:
                break
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        t_dec = (time.perf_counter() - t0) * 1e3
        return [RequestResult(r.rid, out[i], t_pre, t_dec)
                for i, r in enumerate(batch)]
