"""Typed serving statistics.

``EngineStats`` replaces the stringly-keyed ``Dict[str, float]`` both
engines used to mutate ad hoc: every field the engines report — and that
``benchmarks/run.py --json`` rows or the ``benchmarks/compare.py`` gate
consume — is a declared attribute, so a renamed or dropped stat is an
AttributeError at the producer instead of a silently-disarmed gate at the
consumer.

Two kinds of fields coexist:

* **deployment-level** (known at construction): arena bytes, schedule peak
  and method, replica/lane geometry — deterministic artefacts of the
  schedule→plan→compile chain;
* **serve-level** (filled per ``serve()``/``drain()`` call): true request
  count vs padded lanes, dispatch count, wall clock, per-request latency
  percentiles and engine throughput.

``as_json()`` emits only the fields that were actually measured (None
fields are dropped), which is what the benchmark trajectory embeds.  The
legacy ``stats["key"]`` spelling keeps working through ``__getitem__`` so
out-of-tree callers of the old dict API migrate on their own schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence


def percentile_ms(latencies_s: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of a list of second-latencies, in ms."""
    if not latencies_s:
        return 0.0
    xs = sorted(latencies_s)
    k = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[k] * 1e3


# old dict key -> EngineStats attribute (the pre-redesign engines used
# these spellings; __getitem__ honours them so `stats["requests"]` and
# friends stay valid during migration)
_LEGACY_KEYS = {
    "micro_batches": "dispatches",
    "arena_peak_bytes": "kv_arena_peak_bytes",
    "static_bytes": "kv_static_bytes",
}


@dataclasses.dataclass
class EngineStats:
    """One serving engine's deployment + last-serve statistics."""

    # ---- deployment-level (schedule→plan→compile artefacts)
    arena_bytes: int = 0                 # compiled arena size, bytes
    schedule_peak_bytes: int = 0         # scheduler's simulated peak
    schedule_method: str = ""            # winning scheduler rung
    replicas: int = 1                    # device replicas (1 = unsharded)
    lanes: int = 1                       # vmap lanes per replica dispatch

    # ---- serve-level (reset by each serve()/drain())
    requests: int = 0                    # true requests served
    padded_lanes: int = 0                # pad lanes executed, NOT requests
    dispatches: int = 0                  # XLA dispatches issued
    wall_s: float = 0.0                  # serve() wall clock
    us_per_request: float = 0.0          # wall / true requests
    requests_per_s: float = 0.0          # true requests / wall
    p50_ms: float = 0.0                  # per-request latency percentiles
    p99_ms: float = 0.0                  # (admission -> completion)

    # ---- robustness (failure layer, DESIGN.md §12).  These are NOT in
    # the as_json falsy-drop list on purpose: a zero here is a *measured*
    # zero — the CI bench gate requires expired/shed to be present and
    # zero on no-fault serving rows, so "0" and "absent" must differ.
    admitted: int = 0                    # requests admitted to dispatch
    expired: int = 0                     # deadline-expired, never executed
    shed: int = 0                        # refused at max_pending bound
    retried: int = 0                     # dispatch/lane retries consumed
    failed: int = 0                      # typed RequestError results
    watchdog_trips: int = 0              # post-hoc watchdog overruns
    degraded: Optional[List[str]] = None  # degradation notes, None = none

    # ---- LLM engine (KV-block arena accounting); None on graph engines
    kv_arena_peak_bytes: Optional[int] = None
    kv_static_bytes: Optional[int] = None
    peak_concurrent: Optional[int] = None

    def record_serve(self, *, requests: int, padded_lanes: int,
                     dispatches: int, wall_s: float,
                     latencies_s: Sequence[float] = ()) -> None:
        """Fill the serve-level fields from one completed serve/drain."""
        self.requests = requests
        self.padded_lanes = padded_lanes
        self.dispatches = dispatches
        self.wall_s = wall_s
        self.us_per_request = wall_s * 1e6 / requests if requests else 0.0
        self.requests_per_s = requests / wall_s if wall_s > 0 else 0.0
        self.p50_ms = percentile_ms(latencies_s, 50)
        self.p99_ms = percentile_ms(latencies_s, 99)

    def as_json(self) -> Dict[str, object]:
        """Measured fields only — the ``run.py --json`` row payload."""
        out: Dict[str, object] = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v is None:
                continue
            if f.name in ("requests", "dispatches", "padded_lanes",
                          "wall_s", "us_per_request", "requests_per_s",
                          "p50_ms", "p99_ms") and not v:
                continue                  # never measured: drop, not 0
            out[f.name] = v
        return out

    # ------------------------------------------------- legacy dict API
    def __getitem__(self, key: str):
        name = _LEGACY_KEYS.get(key, key)
        try:
            return getattr(self, name)
        except AttributeError:
            raise KeyError(key) from None

    def __contains__(self, key: str) -> bool:
        return hasattr(self, _LEGACY_KEYS.get(key, key))


__all__: List[str] = ["EngineStats", "percentile_ms"]
