from .engine import (GraphServingEngine, Request, RequestResult,
                     ServingEngine)

__all__ = ["GraphServingEngine", "Request", "RequestResult", "ServingEngine"]
