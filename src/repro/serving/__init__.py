from .engine import Request, RequestResult, ServingEngine

__all__ = ["Request", "RequestResult", "ServingEngine"]
