"""Serving package: micro-batched and sharded continuous-batching engines.

Submodules are imported lazily (PEP 562) so that ``force_host_devices``
can be imported and called **before anything initialises jax** — the CPU
replica mesh only exists if ``--xla_force_host_platform_device_count=N``
is in ``XLA_FLAGS`` at first jax init (SNIPPETS.md Snippets 2–3)::

    from repro.serving import force_host_devices
    force_host_devices(4)           # must precede the first jax import
    import repro.deploy as deploy   # ... now jax sees 4 host devices
"""
from __future__ import annotations

import importlib
import os
import sys

_FLAG = "--xla_force_host_platform_device_count"


def force_host_devices(n: int) -> None:
    """Put ``--xla_force_host_platform_device_count=n`` into ``XLA_FLAGS``.

    Only effective before jax initialises its backends; raises if jax has
    already locked in fewer devices (re-exec with the flag set instead —
    ``benchmarks/bench_serving.py`` shows the subprocess recipe).
    """
    n = int(n)
    flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
             if not f.startswith(_FLAG)]
    os.environ["XLA_FLAGS"] = " ".join(flags + [f"{_FLAG}={n}"])
    if "jax" in sys.modules:
        import jax
        have = jax.local_device_count()
        if have < n:
            raise RuntimeError(
                f"jax already initialised with {have} device(s); call "
                f"force_host_devices({n}) (or export XLA_FLAGS={_FLAG}={n}) "
                f"before the first jax import")


_EXPORTS = {
    "GraphServingEngine": ".engine",
    "Request": ".engine",
    "RequestResult": ".engine",
    "ServingEngine": ".engine",
    "kv_block_bytes": ".engine",
    "ShardedServingEngine": ".sharded",
    "EngineStats": ".stats",
    "percentile_ms": ".stats",
    # failure layer (DESIGN.md §12)
    "AdmissionQueue": ".admission",
    "QueuedRequest": ".admission",
    "RequestError": ".admission",
    "FaultPlan": ".faults",
    "FaultInjector": ".faults",
    "dispatch_with_retry": ".faults",
}

__all__ = ["force_host_devices"] + sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(mod, __name__), name)


def __dir__():
    return sorted(set(globals()) | set(__all__))
