"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b@smoke \
        --steps 50 --batch 8 --seq 128

On a real TPU pod this builds the production mesh and shards the state with
``param_specs``; on the CPU rig it runs the same code path on a 1-device
mesh (pass --smoke-mesh to exercise a tiny data×model mesh over forced host
devices — must be the FIRST thing the process does, so it is a flag here,
not an afterthought).
"""
import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b@smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--production-mesh", action="store_true",
                    help="build the 16x16 mesh (requires 256 devices)")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    from repro.configs import get_config
    from repro.data import SyntheticLMDataset
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import Model, param_specs
    from repro.training import make_train_step, train_state_init
    from repro.training.checkpoint import save_checkpoint

    cfg = get_config(args.arch)
    mesh = make_production_mesh() if args.production_mesh else None
    model = Model(cfg, mesh)
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    if mesh is not None:
        specs = param_specs(cfg, mesh)
        def shard(t, s):
            return jax.device_put(t, NamedSharding(mesh, s))
        state = state._replace(
            params=jax.tree_util.tree_map(shard, state.params, specs),
            opt=state.opt._replace(
                mu=jax.tree_util.tree_map(shard, state.opt.mu, specs),
                nu=jax.tree_util.tree_map(shard, state.opt.nu, specs)))
    n = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"arch={cfg.name} params={n/1e6:.1f}M mesh="
          f"{dict(mesh.shape) if mesh else None}")

    ds = SyntheticLMDataset(cfg, args.batch, args.seq, seed=0)
    step_fn = jax.jit(make_train_step(
        model, peak_lr=args.lr, warmup=max(args.steps // 10, 1),
        total_steps=args.steps, microbatches=args.microbatches))
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        state, m = step_fn(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.3f} "
                  f"gnorm={float(m['grad_norm']):.2f}", flush=True)
    if args.ckpt_dir:
        print("saved:", save_checkpoint(args.ckpt_dir, state.params,
                                        args.steps))


if __name__ == "__main__":
    main()
