"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax init).

Production target: TPU v5e, 256 chips/pod as a (16, 16) ("data", "model")
mesh; two pods as (2, 16, 16) ("pod", "data", "model").  Batch shards over
("pod", "data"); "model" carries TP/expert sharding.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — the dry-run must set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            f"any jax import")
    try:
        return jax.make_mesh(shape, axes, devices=devices[:n])
    except TypeError:  # older make_mesh without devices kwarg
        from jax.sharding import Mesh
        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_local_mesh(model_parallel: int = 1):
    """Tiny mesh over whatever devices exist (CPU tests)."""
    import jax
    devs = jax.devices()
    m = min(model_parallel, len(devs))
    d = len(devs) // m
    try:
        return jax.make_mesh((d, m), ("data", "model"),
                             devices=devs[:d * m])
    except TypeError:
        from jax.sharding import Mesh
        return Mesh(np.asarray(devs[:d * m]).reshape(d, m),
                    ("data", "model"))
