import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below may touch jax.

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes and extract memory/cost/collective statistics.

    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch <id>] [--shape <name>] [--mesh single|multi|both] \
        [--out benchmarks/results/dryrun]

Each combo writes one JSON with:
  - memory_analysis (bytes per device: arguments/outputs/temps/peak)
  - cost_analysis   (per-device FLOPs and bytes accessed)
  - collective bytes by kind (parsed from the optimized HLO)
  - the §Roofline three-term report

A failure to lower/compile any combo is a bug in the distribution config —
the process exits non-zero listing the failures.
"""
import argparse
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.roofline import (collective_bytes_from_hlo,
                                     model_flops, roofline_report)
from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (SHAPES, batch_specs, config_for_shape,
                                 shape_applicable)
from repro.models.model import (Model, cache_specs, init_cache, init_params,
                                param_specs)
from repro.training.optimizer import adamw_init
from repro.training.train import TrainState, make_train_step


def _named(mesh, spec_tree, template):
    """PartitionSpec pytree -> NamedSharding pytree shaped like template."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _batch_shardings(mesh, specs):
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    out = {}
    for k, v in specs.items():
        sh = dp if v.shape[0] % dp_size == 0 else None
        out[k] = NamedSharding(mesh, P(sh, *([None] * (len(v.shape) - 1))))
    return out


def lower_combo(arch: str, shape_name: str, mesh, *, donate: bool = True,
                overrides=None):
    """Build and lower the right step function.  Returns (lowered, meta)."""
    from repro.models import runtime
    shape = SHAPES[shape_name]
    cfg = config_for_shape(get_config(arch), shape_name)
    if overrides:
        cfg = cfg.replace(**overrides)
    if runtime.UNROLL_SCANS:
        # analysis pass: fewer, larger chunk steps (identical FLOP totals,
        # far fewer unrolled bodies -> tractable compile times; 54-layer
        # zamba at 32k needs the ssm chunk at 4096 or XLA chokes on ~1.7k
        # unrolled bodies)
        cfg = cfg.replace(attn_chunk=min(4096, shape.seq_len),
                          ssm_chunk=min(4096, shape.seq_len))
    model = Model(cfg, mesh)
    pspecs = param_specs(cfg, mesh)
    params_shape = jax.eval_shape(partial(init_params, cfg),
                                  jax.random.PRNGKey(0))
    params_sh = _named(mesh, pspecs, params_shape)

    if shape.kind == "train":
        specs = batch_specs(cfg, shape)
        step = make_train_step(model, remat=True)
        state_shape = jax.eval_shape(
            lambda: TrainState(params=params_shape,
                               opt=adamw_init(params_shape)))
        # optimizer states ALWAYS keep the fsdp sharding — ZeRO-1 variants
        # change only where the bf16 params live (moe_fsdp=False drops the
        # experts' data axis from params, not from mu/nu)
        opt_pspecs = param_specs(cfg.replace(moe_fsdp=True), mesh)
        opt_sh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s),
            {"mu": opt_pspecs, "nu": opt_pspecs},
            is_leaf=lambda x: isinstance(x, P))
        state_sh = TrainState(
            params=params_sh,
            opt=type(state_shape.opt)(
                step=NamedSharding(mesh, P()),
                mu=opt_sh["mu"], nu=opt_sh["nu"]))
        state_in = jax.tree_util.tree_map(
            lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
            state_shape, state_sh)
        batch_in = {
            k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=s)
            for (k, v), s in zip(specs.items(),
                                 _batch_shardings(mesh, specs).values())}
        fn = jax.jit(step, donate_argnums=(0,) if donate else ())
        lowered = fn.lower(state_in, batch_in)
        tokens = shape.global_batch * shape.seq_len

    elif shape.kind == "prefill":
        specs = batch_specs(cfg, shape)
        batch_sh = _batch_shardings(mesh, specs)
        batch_in = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                            sharding=batch_sh[k])
                    for k, v in specs.items()}
        params_in = jax.tree_util.tree_map(
            lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
            params_shape, params_sh)
        fn = jax.jit(lambda p, b: model.prefill(p, b,
                                                cache_len=shape.seq_len))
        lowered = fn.lower(params_in, batch_in)
        tokens = shape.global_batch * shape.seq_len

    else:  # decode
        csp = cache_specs(cfg, mesh, batch_size=shape.global_batch)
        cache_shape = jax.eval_shape(
            partial(init_cache, cfg, shape.global_batch, shape.seq_len))
        cache_sh = _named(mesh, csp, cache_shape)
        cache_in = jax.tree_util.tree_map(
            lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
            cache_shape, cache_sh)
        params_in = jax.tree_util.tree_map(
            lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
            params_shape, params_sh)
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        dp_size = int(np.prod([mesh.shape[a] for a in dp]))
        tok_sh = dp if shape.global_batch % dp_size == 0 else None
        tok_in = jax.ShapeDtypeStruct(
            (shape.global_batch,), jnp.int32,
            sharding=NamedSharding(mesh, P(tok_sh)))
        fn = jax.jit(model.decode_step,
                     donate_argnums=(1,) if donate else ())
        lowered = fn.lower(params_in, cache_in, tok_in)
        tokens = shape.global_batch          # one token per sequence

    return lowered, {"cfg": cfg, "tokens": tokens, "kind": shape.kind}


VARIANTS = {
    "": {},
    "cp": {"act_shard": "cp"},          # context-parallel prefill (§Perf)
    "zero1": {"moe_fsdp": False},       # ZeRO-1 expert weights (§Perf)
    "kvheads": {"kv_mode": "heads"},    # naive replicated-KV baseline
}


def run_combo(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
              keep_hlo: bool = False, analysis_unroll: bool = True,
              variant: str = ""):
    from repro.models import runtime

    overrides = VARIANTS[variant]
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    lowered, meta = lower_combo(arch, shape_name, mesh, overrides=overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes", "peak_memory_in_bytes"):
            v = getattr(mem, f, None)
            if v is not None:
                mem_d[f] = int(v)
        if "peak_memory_in_bytes" not in mem_d and mem_d:
            # newer jaxlibs dropped the field; derive the standard proxy so
            # the artifact schema stays stable for downstream aggregation
            mem_d["peak_memory_in_bytes"] = max(
                0, mem_d.get("argument_size_in_bytes", 0)
                + mem_d.get("output_size_in_bytes", 0)
                + mem_d.get("temp_size_in_bytes", 0)
                - mem_d.get("alias_size_in_bytes", 0))
            mem_d["peak_memory_is_derived"] = 1

    # ---- analysis pass: re-lower with layer/chunk scans UNROLLED so that
    # cost_analysis and the HLO collective census count every iteration
    # (HloCostAnalysis visits a while body once; see models/runtime.py).
    analysis_mode = "scan"
    a_compiled = compiled
    if analysis_unroll:
        try:
            runtime.UNROLL_SCANS = True
            a_lowered, _ = lower_combo(arch, shape_name, mesh,
                                       overrides=overrides)
            a_compiled = a_lowered.compile()
            analysis_mode = "unrolled"
        except Exception as e:          # fall back to rolled numbers
            print(f"  (unrolled analysis failed: {e!r} - using scan counts)")
        finally:
            runtime.UNROLL_SCANS = False
    cost = a_compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # older jaxlib: [dict] per program
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    hlo = a_compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)

    cfg = meta["cfg"]
    mf = model_flops(cfg, meta["kind"], meta["tokens"])
    roof = roofline_report(flops=flops, bytes_accessed=bytes_acc,
                           collective_bytes=coll["total"],
                           model_flops_global=mf, chips=chips)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_d,
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collectives": coll,
        "model_flops_global": mf,
        "roofline": roof,
        "sliding_window": cfg.sliding_window,
        "analysis_mode": analysis_mode,
        "variant": variant,
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{variant}" if variant else ""
    fname = os.path.join(out_dir,
                         f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    if keep_hlo:
        with open(fname.replace(".json", ".hlo.txt"), "w") as f:
            f.write(hlo)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id (default all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-unroll", action="store_true",
                    help="skip the unrolled analysis pass (compile check "
                         "only; used for the multi-pod sweep)")
    ap.add_argument("--variant", default="", choices=list(VARIANTS),
                    help="sharding variant for §Perf A/B runs")
    args = ap.parse_args(argv)

    arches = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in arches:
        for shape in shapes:
            if not shape_applicable(get_config(arch), shape):
                print(f"SKIP  {arch} x {shape} (documented inapplicable)")
                continue
            for mk in meshes:
                fname = os.path.join(args.out,
                                     f"{arch}__{shape}__{mk}.json")
                if args.skip_existing and os.path.exists(fname):
                    print(f"SKIP  {arch} x {shape} x {mk} (exists)")
                    continue
                try:
                    rec = run_combo(arch, shape, mk, args.out,
                                    keep_hlo=args.keep_hlo,
                                    analysis_unroll=not args.no_unroll,
                                    variant=args.variant)
                    r = rec["roofline"]
                    print(f"OK    {arch:24s} {shape:12s} {mk:6s} "
                          f"compile={rec['compile_s']:6.1f}s "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"dom={r['dominant']:12s} "
                          f"bound={r['step_time_lb_s']*1e3:8.2f}ms",
                          flush=True)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mk, repr(e)))
                    print(f"FAIL  {arch} x {shape} x {mk}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("\nall combos lowered + compiled OK")


if __name__ == "__main__":
    main()
