"""Serving launcher: batched requests through the ServingEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b@smoke \
        --requests 8 --max-new 12
"""
import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b@smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=96)
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models.model import init_params
    from repro.serving import Request, ServingEngine

    cfg = get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                           cache_len=args.cache_len)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, min(500, cfg.vocab_size),
                                        rng.integers(4, 24))
                    .astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    results = engine.serve(reqs)
    for r in results:
        print(f"req {r.rid}: prefill {r.prefill_ms:.0f}ms "
              f"decode {r.decode_ms:.0f}ms tokens={r.tokens}")
    print(f"\narena peak {engine.stats['arena_peak_bytes']/1e6:.1f} MB "
          f"(static {engine.stats['static_bytes']/1e6:.1f} MB)")
    print(engine.analyse_decode_schedule(args.max_batch))


if __name__ == "__main__":
    main()
