"""The four assigned input shapes and per-(arch, shape) lowering policy.

  train_4k      seq 4,096    global_batch 256   -> train_step
  prefill_32k   seq 32,768   global_batch 32    -> prefill
  decode_32k    seq 32,768   global_batch 128   -> serve_step (1 new token,
                                                   KV cache = 32,768)
  long_500k     seq 524,288  global_batch 1     -> serve_step, sub-quadratic
                                                   attention required

long_500k policy (DESIGN.md §6): SSM/hybrid run natively (O(1)/O(S) state);
dense/MoE/VLM run a sliding-window (8,192) KV-cache variant; whisper is
skipped (30 s audio ≤ 448 tokens — half-megatoken decode is outside the
family's semantics).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import make_batch_specs

SLIDING_WINDOW = 8192


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k" and cfg.arch_type == "audio":
        return False             # enc-dec: skip, per DESIGN.md §6
    return True


def config_for_shape(cfg: ModelConfig, shape: str) -> ModelConfig:
    """Sliding-window variant for attention archs at 500k decode."""
    if shape == "long_500k" and cfg.arch_type in ("dense", "moe", "vlm"):
        return cfg.with_sliding_window(SLIDING_WINDOW)
    return cfg


def batch_specs(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStruct inputs for train/prefill lowering."""
    return make_batch_specs(cfg, shape.global_batch, shape.seq_len)


def decode_token_specs(shape: ShapeSpec):
    return jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
