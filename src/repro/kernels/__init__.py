from .flash_attention.ops import flash_attention
from .decode_attention.ops import decode_attention
from .conv_pointwise.ops import conv1x1_fused
from .conv_quant.ops import qconv_add_fused, qconv_fused, qdwconv_fused

__all__ = ["flash_attention", "decode_attention", "conv1x1_fused",
           "qconv_fused", "qdwconv_fused", "qconv_add_fused"]
