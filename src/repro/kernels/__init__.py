from .flash_attention.ops import flash_attention
from .decode_attention.ops import decode_attention

__all__ = ["flash_attention", "decode_attention"]
