from .kernel import qconv1x1_pallas, qconv_pallas, qdwconv_pallas
from .ops import qconv_fused, qdwconv_fused

__all__ = ["qconv1x1_pallas", "qconv_pallas", "qdwconv_pallas",
           "qconv_fused", "qdwconv_fused"]
