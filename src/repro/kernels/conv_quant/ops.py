"""Jitted public wrappers for the fused int8 kernels, mirroring the q-op
semantics signatures (``qconv2d``/``qdwconv2d``): SAME padding by default,
``hpad`` overriding the height pads for Pex slices, weights in the graph's
``(k, k, Cin, Cout)`` / ``(k, k, Cin, 1)`` layouts.  On CPU the kernels run
in interpret mode (lowering to int32 dot_generals — the entire speedup over
XLA's naive int32 convs); on TPU they compile to Mosaic."""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.partition import same_pads

from .kernel import qconv1x1_pallas, qconv_pallas, qdwconv_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pads(n: int, k: int, stride: int) -> Tuple[int, int]:
    _, beg, end = same_pads(n, k, stride)
    return beg, end


@partial(jax.jit, static_argnames=("stride", "mult", "zp_in", "zp_out",
                                   "hpad", "block_rows", "interpret"))
def qconv_fused(x, w, *, stride: int, mult: float, zp_in: int, zp_out: int,
                hpad: Optional[Tuple[int, int]] = None,
                block_rows: Optional[int] = None,
                interpret: Optional[bool] = None):
    """Fused-kernel drop-in for ``qconv2d`` — bit-identical outputs."""
    if interpret is None:
        interpret = not _on_tpu()
    k = w.shape[0]
    if k == 1 and stride == 1 and hpad in (None, (0, 0)):
        return qconv1x1_pallas(
            x, jnp.reshape(w, w.shape[2:]), mult=mult, zp_in=zp_in,
            zp_out=zp_out, block_rows=block_rows or 256, interpret=interpret)
    hp = _pads(x.shape[0], k, stride) if hpad is None else tuple(hpad)
    wp = _pads(x.shape[1], k, stride)
    return qconv_pallas(x, w, stride=stride, mult=mult, zp_in=zp_in,
                        zp_out=zp_out, hpad=hp, wpad=wp,
                        block_rows=block_rows or 128, interpret=interpret)


@partial(jax.jit, static_argnames=("stride", "mult", "zp_in", "zp_out",
                                   "hpad", "block_rows", "interpret"))
def qdwconv_fused(x, w, *, stride: int, mult: float, zp_in: int, zp_out: int,
                  hpad: Optional[Tuple[int, int]] = None,
                  block_rows: Optional[int] = None,
                  interpret: Optional[bool] = None):
    """Fused-kernel drop-in for ``qdwconv2d`` — bit-identical outputs."""
    if interpret is None:
        interpret = not _on_tpu()
    k = w.shape[0]
    hp = _pads(x.shape[0], k, stride) if hpad is None else tuple(hpad)
    wp = _pads(x.shape[1], k, stride)
    wc = jnp.reshape(w, (k, w.shape[1], x.shape[-1]))   # (k,k,Cin,1)->(k,k,C)
    return qdwconv_pallas(x, wc, stride=stride, mult=mult, zp_in=zp_in,
                          zp_out=zp_out, hpad=hp, wpad=wp,
                          block_rows=block_rows or 128, interpret=interpret)
