"""Jitted public wrappers for the fused int8 kernels, mirroring the q-op
semantics signatures (``qconv2d``/``qdwconv2d``): SAME padding by default,
``hpad`` overriding the height pads for Pex slices, weights in the graph's
``(k, k, Cin, Cout)`` / ``(k, k, Cin, 1)`` layouts.  On CPU the kernels run
in interpret mode (lowering to int32 dot_generals — the entire speedup over
XLA's naive int32 convs); on TPU they compile to Mosaic."""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.partition import same_pads

from .kernel import (AddParams, qconv1x1_add_pallas, qconv1x1_pallas,
                     qconv_add_pallas, qconv_pallas, qdwconv_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pads(n: int, k: int, stride: int) -> Tuple[int, int]:
    _, beg, end = same_pads(n, k, stride)
    return beg, end


@partial(jax.jit, static_argnames=("stride", "mult", "zp_in", "zp_out",
                                   "hpad", "wpad", "block_rows", "interpret"))
def qconv_fused(x, w, *, stride: int, mult: float, zp_in: int, zp_out: int,
                hpad: Optional[Tuple[int, int]] = None,
                wpad: Optional[Tuple[int, int]] = None,
                block_rows: Optional[int] = None,
                interpret: Optional[bool] = None):
    """Fused-kernel drop-in for ``qconv2d`` — bit-identical outputs.
    ``wpad`` overrides the width pads for 2-D tile clones (None = SAME)."""
    if interpret is None:
        interpret = not _on_tpu()
    k = w.shape[0]
    if (k == 1 and stride == 1 and hpad in (None, (0, 0))
            and wpad in (None, (0, 0))):
        return qconv1x1_pallas(
            x, jnp.reshape(w, w.shape[2:]), mult=mult, zp_in=zp_in,
            zp_out=zp_out, block_rows=block_rows or 256, interpret=interpret)
    hp = _pads(x.shape[0], k, stride) if hpad is None else tuple(hpad)
    wp = _pads(x.shape[1], k, stride) if wpad is None else tuple(wpad)
    return qconv_pallas(x, w, stride=stride, mult=mult, zp_in=zp_in,
                        zp_out=zp_out, hpad=hp, wpad=wp,
                        block_rows=block_rows or 128, interpret=interpret)


@partial(jax.jit, static_argnames=("stride", "mult", "zp_in", "zp_out",
                                   "add_params", "hpad", "wpad",
                                   "block_rows", "interpret"))
def qconv_add_fused(x, w, r, *, stride: int, mult: float, zp_in: int,
                    zp_out: int, add_params: AddParams,
                    hpad: Optional[Tuple[int, int]] = None,
                    wpad: Optional[Tuple[int, int]] = None,
                    block_rows: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """Fused drop-in for a ``qconv2d -> qadd`` chain (residual ``r`` is the
    add's second leg): one kernel pass, bit-identical outputs.
    ``add_params = (mult_a, mult_b, zp_a, zp_b, zp_out)`` in the qadd
    argument order, where leg *a* is the conv's output."""
    if interpret is None:
        interpret = not _on_tpu()
    k = w.shape[0]
    if (k == 1 and stride == 1 and hpad in (None, (0, 0))
            and wpad in (None, (0, 0))):
        return qconv1x1_add_pallas(
            x, jnp.reshape(w, w.shape[2:]), r, mult=mult, zp_in=zp_in,
            zp_out=zp_out, add_params=tuple(add_params),
            block_rows=block_rows or 256, interpret=interpret)
    hp = _pads(x.shape[0], k, stride) if hpad is None else tuple(hpad)
    wp = _pads(x.shape[1], k, stride) if wpad is None else tuple(wpad)
    return qconv_add_pallas(x, w, r, stride=stride, mult=mult, zp_in=zp_in,
                            zp_out=zp_out, add_params=tuple(add_params),
                            hpad=hp, wpad=wp, block_rows=block_rows or 128,
                            interpret=interpret)


@partial(jax.jit, static_argnames=("stride", "mult", "zp_in", "zp_out",
                                   "hpad", "wpad", "block_rows", "interpret"))
def qdwconv_fused(x, w, *, stride: int, mult: float, zp_in: int, zp_out: int,
                  hpad: Optional[Tuple[int, int]] = None,
                  wpad: Optional[Tuple[int, int]] = None,
                  block_rows: Optional[int] = None,
                  interpret: Optional[bool] = None):
    """Fused-kernel drop-in for ``qdwconv2d`` — bit-identical outputs."""
    if interpret is None:
        interpret = not _on_tpu()
    k = w.shape[0]
    hp = _pads(x.shape[0], k, stride) if hpad is None else tuple(hpad)
    wp = _pads(x.shape[1], k, stride) if wpad is None else tuple(wpad)
    wc = jnp.reshape(w, (k, w.shape[1], x.shape[-1]))   # (k,k,Cin,1)->(k,k,C)
    return qdwconv_pallas(x, wc, stride=stride, mult=mult, zp_in=zp_in,
                          zp_out=zp_out, hpad=hp, wpad=wp,
                          block_rows=block_rows or 128, interpret=interpret)
