"""Fused int8 conv/dwconv + requantize + ReLU Pallas kernels.

The quantized q-ops in ``graphs/cnn_ops.py`` lower to
``lax.conv_general_dilated`` over int32, which XLA CPU executes as a naive
convolution loop — the dominant cost of the compiled executor's warm path
(DESIGN.md §7).  These kernels recast each q-op as the int32 matmul /
shifted multiply-accumulate it really is and fuse the whole op — zero-point
subtract, int32 accumulate, round-half-even requantize, zero-point-clamped
ReLU — into one pass over output row tiles, so the int32 accumulator never
round-trips through memory between the three stages:

* ``qconv1x1_pallas`` — the MobileNet-dominant case: x viewed as
  (H·W, Cin) int8 against w (Cin, Cout), a 1-D grid over row blocks with
  one int32 MXU contraction per tile;
* ``qconv_pallas`` — general k×k/stride: the padded input is VMEM-resident
  per step (MCU-sized by construction) and each output row tile accumulates
  k² shifted (rows, Cin) @ (Cin, Cout) int32 contractions;
* ``qdwconv_pallas`` — depthwise: k² shifted elementwise int32
  multiply-accumulates over the channel lane.

Numerics contract (unlike the f32 ``conv_pointwise`` kernel's float
tolerance): **bit-identical** to ``qconv2d``/``qdwconv2d``.  Integer
accumulation is exact and order-independent, so regrouping the convolution
into matmuls cannot change the int32 sums; the fused requantize then applies
literally the same element-wise sequence as ``cnn_ops.requantize`` —
``round(acc.astype(f32) * f32(mult)) + zp_out``, clip to [zp_out, 127],
cast to int8 — and element-wise f32 ops are deterministic regardless of
fusion context.  Property-tested against the q-op semantics in
``tests/test_qkernels.py``.

SAME padding is materialised outside the kernel by padding with ``zp_in``
(those entries become 0 after the in-kernel zero-point subtract, exactly the
pad-after-subtract formulation of ``qconv2d``); explicit ``hpad`` carries a
Pex slice's halo padding the same way.  Row padding up to the block size is
dead compute sliced off after, never dead loads.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

INT8_MAX = 127
INT8_MIN = -128


def _require_int8(name: str, arr) -> None:
    if arr.dtype != jnp.int8:
        raise TypeError(
            f"{name} must be int8 for the fused quantized kernels, got "
            f"{arr.dtype}; float convs go through conv_pointwise instead")


def _requant(acc, mult: float, zp_out: int, lo: int):
    # Must stay literally the element-wise sequence of cnn_ops.requantize:
    # any deviation (fma, different rounding) breaks the bit-identity
    # contract with the interpreter.
    y = jnp.round(acc.astype(jnp.float32) * jnp.float32(mult)) + zp_out
    return jnp.clip(y, lo, INT8_MAX).astype(jnp.int8)


# add_params of the fused conv→add kernels, in cnn_ops.qadd argument order:
# (mult_a, mult_b, zp_a, zp_b, zp_out) where leg *a* is the conv's int8
# output and leg *b* the residual input.
AddParams = Tuple[float, float, int, int, int]

_QADD_SHIFT = 16    # must stay in lock-step with cnn_ops.QADD_SHIFT


def _qadd_replay(y, r, addp: AddParams):
    # Must stay literally the fixed-point sequence of cnn_ops.qadd: both
    # multipliers quantized to _QADD_SHIFT fractional bits at trace time,
    # int32 accumulate, integer round-half-even — integer ops cannot be
    # FMA-contracted, so this is bit-identical in every execution context
    # (no ReLU: the add has no fused activation in the q-graphs).
    mult_a, mult_b, zp_a, zp_b, zp_out = addp
    ma = int(round(float(mult_a) * (1 << _QADD_SHIFT)))
    mb = int(round(float(mult_b) * (1 << _QADD_SHIFT)))
    acc = ((y.astype(jnp.int32) - zp_a) * ma
           + (r.astype(jnp.int32) - zp_b) * mb)
    base = acc >> _QADD_SHIFT
    rem = acc - (base << _QADD_SHIFT)
    half = 1 << (_QADD_SHIFT - 1)
    z = jnp.where(rem > half, base + 1,
                  jnp.where(rem < half, base, base + (base & 1)))
    return jnp.clip(z + zp_out, INT8_MIN, INT8_MAX).astype(jnp.int8)


# ------------------------------------------------------------- 1x1 pointwise
def _qconv1x1_kernel(x_ref, w_ref, o_ref, *, mult: float, zp_in: int,
                     zp_out: int, lo: int):
    xi = x_ref[...].astype(jnp.int32) - zp_in     # [bm, Cin]
    wi = w_ref[...].astype(jnp.int32)             # [Cin, Cout]
    acc = lax.dot_general(xi, wi, (((1,), (0,)), ((), ())),
                          preferred_element_type=jnp.int32)
    o_ref[...] = _requant(acc, mult, zp_out, lo)


def qconv1x1_pallas(x: jax.Array, w: jax.Array, *, mult: float, zp_in: int,
                    zp_out: int, lo: Optional[int] = None,
                    block_rows: int = 256,
                    interpret: bool = False) -> jax.Array:
    """x [H,W,Cin] int8; w [Cin,Cout] int8 -> [H,W,Cout] int8.

    The stride-1 1×1 case of ``qconv2d`` (no spatial window, no padding):
    one fused int32 matmul + requantize over (H·W, Cin) row tiles.  ``lo``
    is the lower clamp (default ``zp_out``: fused ReLU, as in ``qconv2d``).
    """
    _require_int8("x", x)
    _require_int8("w", w)
    H, W, Cin = x.shape
    Cout = w.shape[1]
    lo = zp_out if lo is None else lo
    M = H * W
    bm = min(block_rows, M)
    pad = (-M) % bm
    xm = x.reshape(M, Cin)
    if pad:     # zp_in rows: dead compute, sliced off below
        xm = jnp.concatenate(
            [xm, jnp.full((pad, Cin), zp_in, jnp.int8)], axis=0)
    out = pl.pallas_call(
        functools.partial(_qconv1x1_kernel, mult=mult, zp_in=zp_in,
                          zp_out=zp_out, lo=lo),
        grid=((M + pad) // bm,),
        in_specs=[pl.BlockSpec((bm, Cin), lambda i: (i, 0)),
                  pl.BlockSpec((Cin, Cout), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bm, Cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M + pad, Cout), jnp.int8),
        interpret=interpret,
    )(xm, w)
    return out[:M].reshape(H, W, Cout)


def _qconv1x1_add_kernel(x_ref, w_ref, r_ref, o_ref, *, mult: float,
                         zp_in: int, zp_out: int, lo: int, addp: AddParams):
    xi = x_ref[...].astype(jnp.int32) - zp_in     # [bm, Cin]
    wi = w_ref[...].astype(jnp.int32)             # [Cin, Cout]
    acc = lax.dot_general(xi, wi, (((1,), (0,)), ((), ())),
                          preferred_element_type=jnp.int32)
    y = _requant(acc, mult, zp_out, lo)           # the conv's int8 output,
    o_ref[...] = _qadd_replay(y, r_ref[...], addp)  # never leaves VMEM


def qconv1x1_add_pallas(x: jax.Array, w: jax.Array, r: jax.Array, *,
                        mult: float, zp_in: int, zp_out: int,
                        add_params: AddParams, lo: Optional[int] = None,
                        block_rows: int = 256,
                        interpret: bool = False) -> jax.Array:
    """Fused ``qconv2d(1x1) -> qadd`` in one pass: x [H,W,Cin] int8 against
    w [Cin,Cout] plus residual r [H,W,Cout] int8 -> [H,W,Cout] int8.

    The conv's requantized int8 tile feeds the add's requantize without a
    memory round-trip — the PR 7 leftover the row-tile structure of
    ``qconv1x1_pallas`` was built for.  Bit-identical to running the two
    q-ops back to back (both requantize sequences are replayed literally).
    """
    _require_int8("x", x)
    _require_int8("w", w)
    _require_int8("r", r)
    H, W, Cin = x.shape
    Cout = w.shape[1]
    lo = zp_out if lo is None else lo
    M = H * W
    bm = min(block_rows, M)
    pad = (-M) % bm
    xm = x.reshape(M, Cin)
    rm = r.reshape(M, Cout)
    if pad:     # zp_in rows: dead compute, sliced off below
        xm = jnp.concatenate(
            [xm, jnp.full((pad, Cin), zp_in, jnp.int8)], axis=0)
        rm = jnp.concatenate(
            [rm, jnp.zeros((pad, Cout), jnp.int8)], axis=0)
    out = pl.pallas_call(
        functools.partial(_qconv1x1_add_kernel, mult=mult, zp_in=zp_in,
                          zp_out=zp_out, lo=lo, addp=tuple(add_params)),
        grid=((M + pad) // bm,),
        in_specs=[pl.BlockSpec((bm, Cin), lambda i: (i, 0)),
                  pl.BlockSpec((Cin, Cout), lambda i: (0, 0)),
                  pl.BlockSpec((bm, Cout), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, Cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M + pad, Cout), jnp.int8),
        interpret=interpret,
    )(xm, w, rm)
    return out[:M].reshape(H, W, Cout)


# ------------------------------------------------------- k×k conv / dwconv
def _pad_for_blocks(x, k: int, stride: int, hpad: Tuple[int, int],
                    wpad: Tuple[int, int], zp_in: int, oh: int, ow: int,
                    bm: int) -> jax.Array:
    """Materialise SAME/halo padding with ``zp_in`` and extend the bottom so
    every grid step's input window is in bounds (extra rows feed the dead
    output rows of the last partial block)."""
    H, W, _ = x.shape
    nblk = -(-oh // bm)                     # ceil
    span_h = (nblk * bm - 1) * stride + k   # rows reachable by any step
    bottom = max(span_h - (H + hpad[0]), 0)
    wp_hi = max((ow - 1) * stride + k - (W + wpad[0]), 0)
    return jnp.pad(x, ((hpad[0], bottom), (wpad[0], wp_hi), (0, 0)),
                   constant_values=jnp.int8(zp_in))


def _qconv_kernel(x_ref, w_ref, o_ref, *, k: int, stride: int, mult: float,
                  zp_in: int, zp_out: int, lo: int, bm: int, ow: int):
    base = pl.program_id(0) * (bm * stride)
    span = (bm - 1) * stride + k
    xs = pl.load(x_ref, (pl.dslice(base, span), slice(None), slice(None)))
    xi = xs.astype(jnp.int32) - zp_in             # [span, Wp, Cin]
    wi = w_ref[...].astype(jnp.int32)             # [k, k, Cin, Cout]
    cin, cout = wi.shape[2], wi.shape[3]
    acc = jnp.zeros((bm * ow, cout), jnp.int32)
    for dy in range(k):
        for dx in range(k):
            win = xi[dy:dy + (bm - 1) * stride + 1:stride,
                     dx:dx + (ow - 1) * stride + 1:stride, :]
            acc += lax.dot_general(win.reshape(bm * ow, cin), wi[dy, dx],
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.int32)
    o_ref[...] = _requant(acc, mult, zp_out, lo).reshape(bm, ow, cout)


def _qconv_add_kernel(x_ref, w_ref, r_ref, o_ref, *, k: int, stride: int,
                      mult: float, zp_in: int, zp_out: int, lo: int, bm: int,
                      ow: int, addp: AddParams):
    base = pl.program_id(0) * (bm * stride)
    span = (bm - 1) * stride + k
    xs = pl.load(x_ref, (pl.dslice(base, span), slice(None), slice(None)))
    xi = xs.astype(jnp.int32) - zp_in             # [span, Wp, Cin]
    wi = w_ref[...].astype(jnp.int32)             # [k, k, Cin, Cout]
    cin, cout = wi.shape[2], wi.shape[3]
    acc = jnp.zeros((bm * ow, cout), jnp.int32)
    for dy in range(k):
        for dx in range(k):
            win = xi[dy:dy + (bm - 1) * stride + 1:stride,
                     dx:dx + (ow - 1) * stride + 1:stride, :]
            acc += lax.dot_general(win.reshape(bm * ow, cin), wi[dy, dx],
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.int32)
    y = _requant(acc, mult, zp_out, lo).reshape(bm, ow, cout)
    o_ref[...] = _qadd_replay(y, r_ref[...], addp)


def _qdwconv_kernel(x_ref, w_ref, o_ref, *, k: int, stride: int, mult: float,
                    zp_in: int, zp_out: int, lo: int, bm: int, ow: int):
    base = pl.program_id(0) * (bm * stride)
    span = (bm - 1) * stride + k
    xs = pl.load(x_ref, (pl.dslice(base, span), slice(None), slice(None)))
    xi = xs.astype(jnp.int32) - zp_in             # [span, Wp, C]
    wi = w_ref[...].astype(jnp.int32)             # [k, k, C]
    acc = jnp.zeros((bm, ow, wi.shape[2]), jnp.int32)
    for dy in range(k):
        for dx in range(k):
            win = xi[dy:dy + (bm - 1) * stride + 1:stride,
                     dx:dx + (ow - 1) * stride + 1:stride, :]
            acc += win * wi[dy, dx][None, None, :]
    o_ref[...] = _requant(acc, mult, zp_out, lo)


def _windowed_call(kernel_body, x, w, w_shape, cout: int, *, k: int,
                   stride: int, mult: float, zp_in: int, zp_out: int,
                   lo: int, hpad: Tuple[int, int], wpad: Tuple[int, int],
                   block_rows: int, interpret: bool,
                   residual: Optional[jax.Array] = None,
                   addp: Optional[AddParams] = None) -> jax.Array:
    H, W, _ = x.shape
    oh = (H + hpad[0] + hpad[1] - k) // stride + 1
    ow = (W + wpad[0] + wpad[1] - k) // stride + 1
    bm = min(block_rows, oh)
    nblk = -(-oh // bm)
    xp = _pad_for_blocks(x, k, stride, hpad, wpad, zp_in, oh, ow, bm)
    Hp, Wp, Cl = xp.shape
    operands = [xp, w]
    in_specs = [pl.BlockSpec((Hp, Wp, Cl), lambda i: (0, 0, 0)),
                pl.BlockSpec(w_shape, lambda i: (0,) * len(w_shape))]
    extra = {}
    if residual is not None:
        # residual rows pad to the block grid (dead compute, sliced off)
        operands.append(jnp.pad(residual,
                                ((0, nblk * bm - oh), (0, 0), (0, 0))))
        in_specs.append(pl.BlockSpec((bm, ow, cout), lambda i: (i, 0, 0)))
        extra["addp"] = tuple(addp)
    out = pl.pallas_call(
        functools.partial(kernel_body, k=k, stride=stride, mult=mult,
                          zp_in=zp_in, zp_out=zp_out, lo=lo, bm=bm, ow=ow,
                          **extra),
        grid=(nblk,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, ow, cout), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk * bm, ow, cout), jnp.int8),
        interpret=interpret,
    )(*operands)
    return out[:oh]


def qconv_pallas(x: jax.Array, w: jax.Array, *, stride: int, mult: float,
                 zp_in: int, zp_out: int, lo: Optional[int] = None,
                 hpad: Optional[Tuple[int, int]] = None,
                 wpad: Tuple[int, int] = (0, 0),
                 block_rows: int = 128, interpret: bool = False) -> jax.Array:
    """x [H,W,Cin] int8; w [k,k,Cin,Cout] int8 -> [OH,OW,Cout] int8.

    General k×k/stride quantized conv with fused requantize + ReLU
    (``lo`` defaults to ``zp_out``).  ``hpad``/``wpad`` are the explicit
    (before, after) paddings — pass the SAME pads for a whole op, a Pex
    slice's halo pads for a partial run.  Bit-identical to ``qconv2d``.
    """
    _require_int8("x", x)
    _require_int8("w", w)
    k = w.shape[0]
    hpad = (0, 0) if hpad is None else tuple(hpad)
    return _windowed_call(
        _qconv_kernel, x, w, tuple(w.shape), w.shape[3], k=k, stride=stride,
        mult=mult, zp_in=zp_in, zp_out=zp_out,
        lo=zp_out if lo is None else lo, hpad=hpad, wpad=tuple(wpad),
        block_rows=block_rows, interpret=interpret)


def qconv_add_pallas(x: jax.Array, w: jax.Array, r: jax.Array, *,
                     stride: int, mult: float, zp_in: int, zp_out: int,
                     add_params: AddParams, lo: Optional[int] = None,
                     hpad: Optional[Tuple[int, int]] = None,
                     wpad: Tuple[int, int] = (0, 0),
                     block_rows: int = 128,
                     interpret: bool = False) -> jax.Array:
    """Fused ``qconv2d -> qadd``: x [H,W,Cin] int8, w [k,k,Cin,Cout] int8,
    residual r [OH,OW,Cout] int8 -> [OH,OW,Cout] int8.

    General k×k/stride twin of ``qconv1x1_add_pallas``: the conv tile's
    requantized int8 rows feed the add's requantize in the same grid step.
    Bit-identical to the two q-ops run separately.
    """
    _require_int8("x", x)
    _require_int8("w", w)
    _require_int8("r", r)
    k = w.shape[0]
    hpad = (0, 0) if hpad is None else tuple(hpad)
    return _windowed_call(
        _qconv_add_kernel, x, w, tuple(w.shape), w.shape[3], k=k,
        stride=stride, mult=mult, zp_in=zp_in, zp_out=zp_out,
        lo=zp_out if lo is None else lo, hpad=hpad, wpad=tuple(wpad),
        block_rows=block_rows, interpret=interpret, residual=r,
        addp=add_params)


def qdwconv_pallas(x: jax.Array, w: jax.Array, *, stride: int, mult: float,
                   zp_in: int, zp_out: int, lo: Optional[int] = None,
                   hpad: Optional[Tuple[int, int]] = None,
                   wpad: Tuple[int, int] = (0, 0),
                   block_rows: int = 128,
                   interpret: bool = False) -> jax.Array:
    """x [H,W,C] int8; w [k,k,C] int8 -> [OH,OW,C] int8 (depthwise)."""
    _require_int8("x", x)
    _require_int8("w", w)
    k = w.shape[0]
    hpad = (0, 0) if hpad is None else tuple(hpad)
    return _windowed_call(
        _qdwconv_kernel, x, w, tuple(w.shape), w.shape[2], k=k,
        stride=stride, mult=mult, zp_in=zp_in, zp_out=zp_out,
        lo=zp_out if lo is None else lo, hpad=hpad, wpad=tuple(wpad),
        block_rows=block_rows, interpret=interpret)
