"""Flash-attention (prefill) Pallas TPU kernel.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
* the grid is (batch·q_heads, Sq/bq, Skv/bk) and TPU executes it
  *sequentially* with the last axis innermost, so the online-softmax carry
  (acc, running max, denominator) lives in VMEM scratch that persists across
  the kv-block axis — no atomics / shared-memory tiling as on GPU;
* block shapes keep the lane dimension at the head_dim and the sublane at
  bq/bk multiples of 8 (f32) — MXU-aligned when bq=bk=128 and D∈{64,128};
* GQA is expressed in the BlockSpec index_map (kv head = q head // group),
  so no head-replicated HBM traffic.

Validated against ``ref.attention_ref`` in interpret mode on CPU; compiled
path requires a real TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, bq: int, bk: int,
                  n_kv_blocks: int, q_offset: int = 0):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # [bq, D]
    k = k_ref[0].astype(jnp.float32)                  # [bk, D]
    v = v_ref[0].astype(jnp.float32)                  # [bk, D]
    s = q @ k.T                                       # [bq, bk]

    if causal:
        # queries are the LAST Sq positions of the kv axis (prefill with a
        # shorter query window): absolute q position = q_offset + row
        rows = q_offset + q_idx * bq \
            + lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = kv_idx * bk + lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_ref[...]                               # [bq]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(kv_idx == n_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = 128, bk: int = 128,
                           softmax_scale: Optional[float] = None,
                           interpret: bool = False) -> jax.Array:
    """q [B,Sq,H,D]; k,v [B,Skv,K,D].  Layout is transposed to
    head-major [B·H, S, D] so each grid step owns one (head, q-block)."""
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    assert H % K == 0
    groups = H // K
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk

    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, Skv, D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, Skv, D)

    def q_map(bh, i, j):
        return (bh, i, 0)

    def kv_map(bh, i, j):
        b, h = bh // H, bh % H
        return (b * K + h // groups, j, 0)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, n_kv_blocks=nk,
                               q_offset=Skv - Sq)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), q_map),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),   # acc
            pltpu.VMEM((bq,), jnp.float32),     # running max
            pltpu.VMEM((bq,), jnp.float32),     # denominator
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
