"""Pure-jnp oracle for the flash-attention kernel: naive materialised
softmax attention with causal / GQA semantics.  O(S²) memory — test shapes
only."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  softmax_scale: Optional[float] = None) -> jax.Array:
    """q [B,Sq,H,D]; k,v [B,Skv,K,D], H % K == 0.  Returns [B,Sq,H,D]."""
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    groups = H // K
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Sq, K, groups, D)
    s = jnp.einsum("bikgd,bjkd->bkgij", qf * scale,
                   k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgij,bjkd->bikgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)
