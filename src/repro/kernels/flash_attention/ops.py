"""Jitted public wrapper for the flash-attention kernel.  On CPU (this test
rig) the kernel runs in interpret mode; on TPU it compiles to Mosaic."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from .kernel import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = not _on_tpu()
    return flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                  interpret=interpret)
