from .ops import conv1x1_fused

__all__ = ["conv1x1_fused"]
