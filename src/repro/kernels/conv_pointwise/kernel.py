"""Fused pointwise-conv (1x1) + bias + ReLU Pallas kernel.

The MCU-shaped NHWC case: an int8-era CNN's 1x1 convolutions dominate its
MACs (all of MobileNet's pointwise layers) and are matmuls over tiny channel
counts — x viewed as (H·W, Cin) against w (Cin, Cout).  The kernel fuses the
matmul, bias add and ReLU in one pass over row tiles, so the activation
tile never round-trips to HBM between the three ops:

* grid is 1-D over row blocks; each step owns a (bm, Cin) tile of x and the
  whole (Cin, Cout) weight (both tiny for MCU channel counts — VMEM-resident
  by construction);
* the MXU sees a (bm, Cin) @ (Cin, Cout) contraction with f32 accumulation
  (``preferred_element_type``); bias is kept (1, Cout) so the broadcast is
  lane-aligned on TPU;
* rows are zero-padded up to the block size and sliced off after — padding
  rows are dead compute, never dead loads.

Validated against ``ref.conv1x1_ref`` in interpret mode on CPU; the compiled
path targets TPU.  Accumulation order differs from
``lax.conv_general_dilated``, so results match the reference to float
tolerance, not bit-exactly — the compiled arena executor only routes convs
here when asked (``use_pallas=True``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv1x1_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    x = x_ref[...].astype(jnp.float32)            # [bm, Cin]
    w = w_ref[...].astype(jnp.float32)            # [Cin, Cout]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    y = y + b_ref[...]                            # [1, Cout] broadcast
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y.astype(o_ref.dtype)


def conv1x1_pallas(x: jax.Array, w: jax.Array,
                   b: Optional[jax.Array] = None, *, relu: bool = True,
                   block_rows: int = 256,
                   interpret: bool = False) -> jax.Array:
    """x [H,W,Cin]; w [Cin,Cout]; b [Cout] (None = zeros) -> [H,W,Cout]."""
    if not jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
        # the final ``y.astype(o_ref.dtype)`` would silently TRUNCATE the
        # f32 accumulator back to an integer dtype instead of requantizing
        # — int8 convs must go through kernels/conv_quant (fused requant)
        raise TypeError(
            f"conv1x1_pallas is the float kernel (got x dtype "
            f"{jnp.asarray(x).dtype}); quantized convs route through "
            f"repro.kernels.qconv_fused, which requantizes exactly")
    H, W, Cin = x.shape
    Cout = w.shape[1]
    if b is None:
        b = jnp.zeros((Cout,), jnp.float32)
    b2 = jnp.reshape(b, (1, Cout)).astype(jnp.float32)
    M = H * W
    bm = min(block_rows, M)
    pad = (-M) % bm
    xm = x.reshape(M, Cin)
    if pad:
        xm = jnp.concatenate([xm, jnp.zeros((pad, Cin), x.dtype)], axis=0)
    out = pl.pallas_call(
        functools.partial(_conv1x1_kernel, relu=relu),
        grid=((M + pad) // bm,),
        in_specs=[pl.BlockSpec((bm, Cin), lambda i: (i, 0)),
                  pl.BlockSpec((Cin, Cout), lambda i: (0, 0)),
                  pl.BlockSpec((1, Cout), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bm, Cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M + pad, Cout), x.dtype),
        interpret=interpret,
    )(xm, w, b2)
    return out[:M].reshape(H, W, Cout)
