"""Jitted public wrapper for the fused pointwise conv kernel.  On CPU (this
test rig) the kernel runs in interpret mode; on TPU it compiles to Mosaic."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from .kernel import conv1x1_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("relu", "block_rows", "interpret"))
def conv1x1_fused(x, w, b=None, *, relu: bool = True, block_rows: int = 256,
                  interpret: Optional[bool] = None):
    if interpret is None:
        interpret = not _on_tpu()
    return conv1x1_pallas(x, w, b, relu=relu, block_rows=block_rows,
                          interpret=interpret)
