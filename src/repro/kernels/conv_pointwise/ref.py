"""Pure-jnp oracle for the fused pointwise conv kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def conv1x1_ref(x: jax.Array, w: jax.Array,
                b: Optional[jax.Array] = None,
                relu: bool = True) -> jax.Array:
    """x [H,W,Cin]; w [Cin,Cout]; b [Cout] or None -> [H,W,Cout]."""
    H, W, Cin = x.shape
    y = x.reshape(H * W, Cin).astype(jnp.float32) @ w.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.reshape(H, W, -1).astype(x.dtype)
