"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, lengths: jax.Array, *,
                         softmax_scale: Optional[float] = None) -> jax.Array:
    """q [B,H,D]; caches [B,S,K,D]; lengths [B] (valid prefix).  -> [B,H,D]"""
    B, H, D = q.shape
    _, S, K, _ = k_cache.shape
    groups = H // K
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    qg = (q.astype(jnp.float32) * scale).reshape(B, K, groups, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    mask = jnp.arange(S)[None] < lengths[:, None]          # [B,S]
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)
