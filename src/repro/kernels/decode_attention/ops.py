"""Jitted public wrapper for the decode-attention kernel."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from .kernel import decode_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, *, bs: int = 256,
                     interpret: Optional[bool] = None):
    if interpret is None:
        interpret = not _on_tpu()
    return decode_attention_pallas(q, k_cache, v_cache, lengths, bs=bs,
                                   interpret=interpret)
