"""Single-token decode attention Pallas TPU kernel.

Decode is memory-bound: the whole KV cache streams HBM→VMEM once per step
while compute is tiny (1 query row).  The kernel therefore:

* blocks over the cache sequence axis (grid = (B·H, S/bs)) so each step
  pulls one [bs, D] K tile + one [bs, D] V tile into VMEM,
* keeps the online-softmax carry (acc[D], m, l) in VMEM scratch across the
  sequence axis (sequential TPU grid),
* masks invalid cache rows from per-batch ``lengths`` (scalar prefetch-style
  operand, replicated to each grid step).

The length mask uses broadcasted_iota on the sublane axis — TPU requires
≥2D iota.  Oracle: ``ref.decode_attention_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, bs: int,
                   n_blocks: int):
    blk = pl.program_id(1)

    @pl.when(blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # [1, D] row
    k = k_ref[0].astype(jnp.float32)                  # [bs, D]
    v = v_ref[0].astype(jnp.float32)

    s = (k @ q[0][:, None])[:, 0]                     # [bs]
    rows = blk * bs + lax.broadcasted_iota(jnp.int32, (bs, 1), 0)[:, 0]
    valid = rows < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, s.max())
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                            # [bs]
    l_ref[0] = l_ref[0] * alpha + p.sum()
    acc_ref[...] = acc_ref[...] * alpha + (p[None, :] @ v)
    m_ref[0] = m_new

    @pl.when(blk == n_blocks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[0], 1e-30)
                    ).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, lengths: jax.Array, *,
                            bs: int = 256,
                            softmax_scale: Optional[float] = None,
                            interpret: bool = False) -> jax.Array:
    """q [B,H,D]; caches [B,S,K,D]; lengths [B] int32 -> [B,H,D]."""
    B, H, D = q.shape
    _, S, K, _ = k_cache.shape
    assert H % K == 0
    groups = H // K
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    bs = min(bs, S)
    assert S % bs == 0
    nb = S // bs

    qh = q.reshape(B * H, 1, D)
    kh = k_cache.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    vh = v_cache.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    len_h = jnp.repeat(lengths.astype(jnp.int32), H)   # [B*H]

    def q_map(bh, j):
        return (bh, 0, 0)

    def kv_map(bh, j):
        b, h = bh // H, bh % H
        return (b * K + h // groups, j, 0)

    def len_map(bh, j):
        return (bh,)

    kernel = functools.partial(_decode_kernel, scale=scale, bs=bs,
                               n_blocks=nb)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nb),
        in_specs=[
            pl.BlockSpec((1,), len_map),
            pl.BlockSpec((1, 1, D), q_map),
            pl.BlockSpec((1, bs, D), kv_map),
            pl.BlockSpec((1, bs, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(len_h, qh, kh, vh)
    return out.reshape(B, H, D)
