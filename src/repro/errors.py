"""Typed error hierarchy for the deploy/serving failure layer.

On a microcontroller the failure modes this repo's pipeline can hit — an
out-of-bounds arena write, an unserviceable memory budget, a dispatch that
never returns — are bricked products, not stack traces.  Every failure the
runtime can *detect* therefore maps to a named exception below (or to a
typed ``serving.RequestError`` result for per-request failures that must
not tear down the engine), so callers can branch on the class instead of
parsing message strings, and the chaos suite (tests/test_chaos.py) can
assert that each injected fault resolves to exactly one of these — never a
hang, never a silent wrong answer.  DESIGN.md §12 is the policy document.

This module must stay import-light (no jax, no numpy): ``benchmarks`` and
``serving.force_host_devices`` import before jax initialises.
"""
from __future__ import annotations


class ReproError(Exception):
    """Base class for every typed failure this package raises."""


class InputValidationError(ReproError, ValueError):
    """A request's inputs are malformed: wrong dtype (would be silently
    cast), wrong shape (would be silently flattened), non-finite values,
    out of the int8 quantization domain, or unknown/missing tensors.
    Subclasses ValueError so pre-existing ``except ValueError`` callers
    keep working."""


class BudgetUnreachableError(ReproError):
    """``deploy.build(strict=True)``: the scheduler ladder exhausted every
    rung and the best arena still exceeds ``arena_budget``.  Pass
    ``strict=False`` to deploy best-effort with the miss recorded in
    ``Deployment.degraded``."""


class DeploymentError(ReproError):
    """``deploy.build(strict=False)``: every fallback rung of the scheduler
    ladder failed — there is nothing left to degrade to."""


class GuardViolation(ReproError):
    """A canary byte between arena placements was overwritten — an
    out-of-bounds write by a lowering or a planner placement bug
    (``guard_bytes`` debug mode, DESIGN.md §12)."""


class TransientDeviceError(ReproError):
    """A dispatch failed in a way worth retrying (injected by the fault
    layer; the slot a flaky DMA/bus error would occupy on hardware)."""


class DeviceInitError(ReproError):
    """Replica-mesh/device initialisation failed.  The sharded engine
    degrades to single-device serving instead of propagating this when
    ``fallback_single_device=True`` (the default)."""


class DispatchFailedError(ReproError):
    """A dispatch kept failing after the bounded retry budget
    (``max_retries``) was spent; per-request results become typed
    ``RequestError("dispatch_failed")`` entries."""


class NaNActivationError(ReproError):
    """A float output came back NaN under fault checking — numerically
    poisoned results must never be returned as answers."""


__all__ = [
    "ReproError", "InputValidationError", "BudgetUnreachableError",
    "DeploymentError", "GuardViolation", "TransientDeviceError",
    "DeviceInitError", "DispatchFailedError", "NaNActivationError",
]
