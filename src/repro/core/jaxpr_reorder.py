"""Operator reordering for JAX programs — the paper's technique applied to
``ClosedJaxpr`` equations (the TPU-native analogue of reordering TFLite
operators; see DESIGN.md §2).

A jaxpr is a linearised computation DAG: equations are operators, ``Var``s
are tensors, sizes come from avals (optionally divided by a sharding factor
to model per-device liveness under pjit).  We build the paper's graph IR,
minimise peak liveness with the core schedulers, and re-emit a ``ClosedJaxpr``
with the equations in the optimised order.  XLA runs its own scheduler
afterwards, so the reported metric is the schedule-induced peak liveness —
the same working-set accounting the paper reports for TFLite.

Guarantees:
* the reordered jaxpr is a valid topological order (checked);
* evaluation is numerically identical (tests assert bit-equality);
* effectful jaxprs are returned unchanged (reordering could reorder IO).

With ``partition_budget`` set, the partial-execution rewrite
(``jaxpr_partial``, DESIGN.md §3) may additionally split equation chains
into row slices; see that module for its (slightly weaker, dot_general
float-tolerance) numerics contract.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.extend import core as jcore           # public Jaxpr/ClosedJaxpr API
from jax._src.core import DropVar, eval_jaxpr  # no public equivalents yet

from .graph import Graph
from .heuristics import schedule as _schedule
from .scheduler import ScheduleResult

Literal = jcore.Literal


def aval_bytes(aval, shard_divisor: int = 1) -> int:
    try:
        size = int(np.prod(aval.shape)) * np.dtype(aval.dtype).itemsize
    except Exception:
        return 0
    return max(1, math.ceil(size / shard_divisor))


@dataclasses.dataclass
class ReorderReport:
    n_eqns: int
    peak_before: int
    peak_after: int
    method: str
    changed: bool

    @property
    def saving(self) -> int:
        return self.peak_before - self.peak_after

    def __str__(self) -> str:
        return (f"jaxpr reorder: {self.n_eqns} eqns, peak "
                f"{self.peak_before:,} -> {self.peak_after:,} B "
                f"(-{self.saving:,}, {self.method})")


def jaxpr_to_graph(jaxpr: jcore.Jaxpr,
                   shard_divisor: int = 1) -> Tuple[Graph, Dict[str, int]]:
    """Build the scheduling graph.  Multi-output equations become a single
    bundle tensor (sum of output sizes, union of lifetimes) — conservative
    but sound.  Returns (graph, eqn-name -> eqn index)."""
    g = Graph()
    var_tensor: Dict[int, str] = {}

    def ensure_input(v) -> Optional[str]:
        if isinstance(v, Literal):
            return None
        name = var_tensor.get(id(v))
        if name is None:
            name = f"in{len(var_tensor)}"
            g.add_tensor(name, aval_bytes(v.aval, shard_divisor))
            var_tensor[id(v)] = name
        return name

    for v in list(jaxpr.constvars) + list(jaxpr.invars):
        ensure_input(v)

    eqn_index: Dict[str, int] = {}
    for k, eqn in enumerate(jaxpr.eqns):
        ins: List[str] = []
        for v in eqn.invars:
            n = None if isinstance(v, Literal) else var_tensor.get(id(v))
            if n is None and not isinstance(v, Literal):
                n = ensure_input(v)
            if n is not None and n not in ins:
                ins.append(n)
        outs = [v for v in eqn.outvars if not isinstance(v, DropVar)]
        size = sum(aval_bytes(v.aval, shard_divisor) for v in eqn.outvars)
        name = f"e{k}_{eqn.primitive.name}"
        out_name = f"{name}.out"
        g.add_tensor(out_name, size)
        for v in outs:
            var_tensor[id(v)] = out_name
        # dynamic_update_slice writes into its operand (invars[0]); XLA
        # performs it in place when the operand is dead, which is exactly how
        # the partial-execution accumulator (jaxpr_partial) is built — model
        # it so the liveness accounting charges the buffer once.  Only the
        # operand is writable, so name it: a dying size-matched *update*
        # could not be aliased by XLA.
        attrs = {}
        if eqn.primitive.name == "dynamic_update_slice" and ins:
            operand = (None if isinstance(eqn.invars[0], Literal)
                       else var_tensor.get(id(eqn.invars[0])))
            if operand is not None:
                attrs = {"inplace": True, "inplace_input": operand}
        g.add_operator(name, ins, out_name, kind=eqn.primitive.name, **attrs)
        eqn_index[name] = k

    out_tensors: List[str] = []
    for v in jaxpr.outvars:
        if isinstance(v, Literal):
            continue
        n = var_tensor.get(id(v))
        if n is not None and n not in out_tensors:
            out_tensors.append(n)
    # Outputs may include passthrough invars (constants in graph terms);
    # Graph handles both.
    g.set_outputs(out_tensors)
    return g, eqn_index


def reorder_closed_jaxpr(closed: jcore.ClosedJaxpr,
                         shard_divisor: int = 1,
                         exact_limit: int = 16,
                         contract_limit: int = 36,
                         beam_width: int = 32,
                         partition_budget: Optional[int] = None,
                         ) -> Tuple[jcore.ClosedJaxpr, ReorderReport]:
    """Reorder equations for minimal peak liveness; when ``partition_budget``
    is given and reordering alone stays above it, additionally try the
    partial-execution rewrite (``jaxpr_partial``) and keep whichever jaxpr
    peaks lower."""
    jaxpr = closed.jaxpr
    if jaxpr.effects:
        g, _ = jaxpr_to_graph(jaxpr, shard_divisor)
        peak = g.peak_usage(g.default_schedule())
        return closed, ReorderReport(len(jaxpr.eqns), peak, peak,
                                     "skipped-effects", False)
    g, eqn_index = jaxpr_to_graph(jaxpr, shard_divisor)
    default_peak = g.peak_usage(g.default_schedule())
    res: ScheduleResult = _schedule(g, exact_limit=exact_limit,
                                    contract_limit=contract_limit,
                                    beam_width=beam_width)
    order = [eqn_index[op.name] for op in res.schedule]
    changed = order != sorted(order)
    if changed:
        new_eqns = [jaxpr.eqns[i] for i in order]
        new_closed = jcore.ClosedJaxpr(jaxpr.replace(eqns=new_eqns),
                                       closed.consts)
        best = (new_closed, ReorderReport(len(jaxpr.eqns), default_peak,
                                          res.peak, res.method, True))
    else:
        best = (closed, ReorderReport(len(jaxpr.eqns), default_peak,
                                      default_peak, res.method, False))
    if partition_budget is not None and best[1].peak_after > partition_budget:
        from .jaxpr_partial import partial_execute_closed_jaxpr
        pclosed, n_runs = partial_execute_closed_jaxpr(
            closed, budget=partition_budget, shard_divisor=shard_divisor)
        if n_runs:
            pc2, rep2 = reorder_closed_jaxpr(
                pclosed, shard_divisor, exact_limit, contract_limit,
                beam_width)
            if rep2.peak_after < best[1].peak_after:
                rep2 = dataclasses.replace(
                    rep2, peak_before=default_peak,
                    method=rep2.method + "+pex", changed=True)
                best = (pc2, rep2)
    return best


def peak_liveness(closed: jcore.ClosedJaxpr, shard_divisor: int = 1) -> int:
    """Schedule-induced peak live bytes of a jaxpr in its current eqn order."""
    g, _ = jaxpr_to_graph(closed.jaxpr, shard_divisor)
    return g.peak_usage(g.default_schedule())


def reorder(fn: Callable[..., Any], shard_divisor: int = 1,
            report_to: Optional[list] = None, **kw) -> Callable[..., Any]:
    """Function transform: trace → reorder equations → evaluate the
    reordered jaxpr.  ``report_to`` (a list) collects ``ReorderReport``s."""

    def wrapped(*args, **kwargs):
        closed = jax.make_jaxpr(fn)(*args, **kwargs)
        new_closed, rep = reorder_closed_jaxpr(closed, shard_divisor, **kw)
        if report_to is not None:
            report_to.append(rep)
        flat_args = jax.tree_util.tree_leaves((args, kwargs))
        out_flat = eval_jaxpr(new_closed.jaxpr, new_closed.consts, *flat_args)
        out_tree = jax.tree_util.tree_structure(
            jax.eval_shape(fn, *args, **kwargs))
        return jax.tree_util.tree_unflatten(out_tree, out_flat)

    return wrapped
