"""Schedulers beyond the paper's exact DP, for graphs too large for
O(|V|·2^|V|):

* ``build_chains`` + ``minimise_peak_memory_contracted`` — maximal linear
  chains have a forced internal order, so they are collapsed into
  super-operators before running the paper's DP.  The per-candidate memory
  term accounts the chain's internal liveness exactly (external inputs die at
  their last internal use unless also held for later operators).  NOTE: this
  is exact *over schedules that run each chain contiguously*; the true
  optimum may interleave chains (running another chain's op mid-chain can
  free a held tensor earlier), so the contracted DP is a near-exact
  heuristic — property tests assert ``contracted.peak >= exact.peak`` and
  benchmarks measure the observed gap (typically zero on CNN graphs).
* ``greedy_schedule`` — forward list scheduling picking the ready operator
  that minimises the post-execution live-set size (tie-break: step peak).
* ``beam_schedule`` — beam search over partial schedules, deduplicated by
  produced-set, scored by (peak so far, current liveness).

``schedule()`` is the one-stop API: exact DP (seeded with the greedy peak as
a branch-and-bound upper bound) when the contracted graph is small, beam
otherwise; always returns a schedule validated against the original graph.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .graph import Graph, Operator, linear_chains
from .scheduler import ScheduleResult, minimise_peak_memory


# --------------------------------------------------------------------- greedy
def greedy_schedule(graph: Graph) -> ScheduleResult:
    ops = graph.operators
    n = len(ops)
    produced: Set[str] = set()
    remaining_uses: Dict[str, int] = {}
    for op in ops:
        for i in op.inputs:
            remaining_uses[i] = remaining_uses.get(i, 0) + 1
    for o in graph.outputs:
        remaining_uses[o] = remaining_uses.get(o, 0) + 1  # pinned

    live: Set[str] = set(c for c in graph.constants()
                         if remaining_uses.get(c, 0) > 0)
    live_bytes = sum(graph.size(t) for t in live)
    scheduled: List[Operator] = []
    done: Set[int] = set()
    peak = live_bytes

    def ready(op: Operator) -> bool:
        return all(i in produced or graph.producer(i) is None
                   for i in op.inputs)

    while len(scheduled) < n:
        best = None
        for op in ops:
            if id(op) in done or not ready(op):
                continue
            # simulate executing op
            step_live = live_bytes + graph.size(op.output)
            after = step_live
            for i in set(op.inputs):
                if remaining_uses.get(i, 0) - op.inputs.count(i) <= 0 \
                        and i in live:
                    after -= graph.size(i)
            key = (after, max(peak, step_live), op.name)
            if best is None or key < best[0]:
                best = (key, op, step_live, after)
        assert best is not None, "graph has a cycle"
        _, op, step_live, after = best
        peak = max(peak, step_live)
        scheduled.append(op)
        done.add(id(op))
        produced.add(op.output)
        live.add(op.output)
        live_bytes = step_live
        for i in set(op.inputs):
            remaining_uses[i] -= op.inputs.count(i)
            if remaining_uses[i] <= 0 and i in live:
                live.remove(i)
                live_bytes -= graph.size(i)
        if remaining_uses.get(op.output, 0) <= 0:
            live.remove(op.output)
            live_bytes -= graph.size(op.output)
    true_peak = graph.peak_usage(scheduled)
    return ScheduleResult(scheduled, true_peak, n, method="greedy")


# ----------------------------------------------------------------------- beam
def beam_schedule(graph: Graph, width: int = 64) -> ScheduleResult:
    ops = graph.operators
    n = len(ops)
    consumers_left_init: Dict[str, int] = {}
    for op in ops:
        for i in set(op.inputs):
            consumers_left_init[i] = consumers_left_init.get(i, 0) + 1
    for o in graph.outputs:
        consumers_left_init[o] = consumers_left_init.get(o, 0) + 1

    # state: (peak, live_bytes, done frozenset, schedule tuple,
    #         uses-left dict) — uses carried incrementally, not replayed.
    const_live = sum(graph.size(c) for c in graph.constants()
                     if consumers_left_init.get(c, 0) > 0)
    init = (const_live, const_live, frozenset(), (), consumers_left_init)
    frontier = [init]

    for _ in range(n):
        candidates: Dict[FrozenSet[int], tuple] = {}
        for peak, live_bytes, done, sched, uses_left in frontier:
            produced = {ops[k].output for k in done}
            for k, op in enumerate(ops):
                if k in done:
                    continue
                if not all(i in produced or graph.producer(i) is None
                           for i in op.inputs):
                    continue
                step = live_bytes + graph.size(op.output)
                after = step
                for i in set(op.inputs):
                    if uses_left.get(i, 0) - 1 <= 0:
                        after -= graph.size(i)
                if uses_left.get(op.output, 0) <= 0:
                    after -= graph.size(op.output)
                nd = done | {k}
                prev = candidates.get(nd)
                if prev is not None and (prev[0], prev[1]) <= (max(peak,
                                                                   step),
                                                               after):
                    continue
                nu = dict(uses_left)
                for i in set(op.inputs):
                    nu[i] = nu.get(i, 0) - 1
                candidates[nd] = (max(peak, step), after, nd,
                                  sched + (k,), nu)
        frontier = heapq.nsmallest(width, candidates.values(),
                                   key=lambda s: (s[0], s[1]))
    best = min(frontier, key=lambda s: s[0])
    schedule = [ops[k] for k in best[3]]
    true_peak = graph.peak_usage(schedule)
    return ScheduleResult(schedule, true_peak, len(frontier), method=f"beam{width}")


# ------------------------------------------------------- chain-contracted DP
@dataclasses.dataclass
class _Chain:
    ops: List[Operator]
    output: str                      # final tensor of the chain
    exts: List[str]                  # external inputs (not produced inside)
    # per-step: (bytes of internal live tensors incl. this step's output,
    #            frozenset of exts still needed at/after this step)
    steps: List[Tuple[int, FrozenSet[str]]]

    def here_cost(self, graph: Graph, held: FrozenSet[str]) -> int:
        """Peak memory while this chain executes, given `held` tensors that
        stay live throughout (excluding this chain's own exts, which are
        accounted per-step unless also in `held`)."""
        held_out = sum(graph.size(t) for t in held if t not in self.exts)
        peak = 0
        for internal, live_exts in self.steps:
            e = sum(graph.size(t) for t in self.exts
                    if t in live_exts or t in held)
            peak = max(peak, held_out + e + internal)
        return peak


def build_chains(graph: Graph) -> Tuple[Dict[str, _Chain], List[_Chain]]:
    """Contract maximal linear chains. Returns (chain by output tensor, all)."""
    chains: List[_Chain] = []
    for ops in linear_chains(graph):
        internal_outputs = {o.output for o in ops}
        exts: List[str] = []
        for op in ops:
            for i in op.inputs:
                if i not in internal_outputs and i not in exts:
                    exts.append(i)
        # last internal use of each ext
        last_use: Dict[str, int] = {}
        for t, op in enumerate(ops):
            for i in op.inputs:
                if i in exts:
                    last_use[i] = t
        # internal tensor lifetime: produced at step t, last used at step u>t
        int_last: Dict[str, int] = {}
        for t, op in enumerate(ops):
            for i in op.inputs:
                if i in internal_outputs:
                    int_last[i] = t
        steps: List[Tuple[int, FrozenSet[str]]] = []
        for t, op in enumerate(ops):
            internal = graph.size(op.output)
            for u, prev in enumerate(ops[:t]):
                o = prev.output
                if int_last.get(o, -1) >= t or o == ops[-1].output:
                    internal += graph.size(o)
            live_exts = frozenset(e for e in exts if last_use[e] >= t)
            steps.append((internal, live_exts))
        chains.append(_Chain(ops, ops[-1].output, exts, steps))
    return {c.output: c for c in chains}, chains


def minimise_peak_memory_contracted(
        graph: Graph, upper_bound: Optional[int] = None,
        max_states: int = 300_000) -> Optional[ScheduleResult]:
    """The paper's DP over the chain-contracted graph (near-exact; see
    module docstring).  ``max_states`` budgets candidate evaluations (the
    unit of work); returns None when exhausted so callers fall back to
    beam search."""
    class _StateBudget(Exception):
        pass

    by_output, chains = build_chains(graph)
    # map: tensor -> chain that produces it (only chain outputs are visible
    # as schedulable units; internal tensors never appear in DP states).
    size = graph.size
    memo: Dict[FrozenSet[str], float] = {}
    choice: Dict[FrozenSet[str], str] = {}
    stats = {"states": 0}
    INF = float("inf")

    # predecessor relation on chain outputs
    pred_cache: Dict[str, FrozenSet[str]] = {}

    def preds(t: str) -> FrozenSet[str]:
        if t in pred_cache:
            return pred_cache[t]
        c = by_output.get(t)
        if c is None:
            res: FrozenSet[str] = frozenset()
        else:
            acc: Set[str] = set()
            for e in c.exts:
                if e in by_output:
                    acc.add(e)
                    acc.update(preds(e))
            res = frozenset(acc)
        pred_cache[t] = res
        return res

    def mem(x_set: FrozenSet[str]) -> float:
        if x_set in memo:
            return memo[x_set]
        cs = frozenset(t for t in x_set if t not in by_output)
        as_ = [t for t in x_set if t in by_output]
        if not as_:
            total = sum(size(c) for c in cs)
            memo[x_set] = total
            return total
        m, best = INF, None
        for x in sorted(as_):
            stats["states"] += 1          # work unit: candidate evaluation
            if stats["states"] > max_states:
                raise _StateBudget()
            rs = frozenset(a for a in as_ if a != x)
            if any(x in preds(r) for r in rs):
                continue
            chain = by_output[x]
            succ = rs | frozenset(chain.exts) | cs
            # Constants stay in the recursion set (deduplicated accounting —
            # see the note in scheduler.mem); here_cost treats them as held.
            here = chain.here_cost(graph, rs | cs)
            if upper_bound is not None and here >= upper_bound and m < INF:
                continue
            m_prime = max(mem(succ), here)
            if m_prime < m:
                m, best = m_prime, x
        if best is not None:
            choice[x_set] = best
        memo[x_set] = m if best is not None else INF
        return memo[x_set]

    try:
        top = frozenset(graph.outputs)
        peak = mem(top)
    except _StateBudget:
        return None
    if peak == INF:
        return None

    rev: List[Operator] = []
    x_set = frozenset(graph.outputs)
    while True:
        as_ = [t for t in x_set if t in by_output]
        if not as_:
            break
        x = choice[x_set]
        chain = by_output[x]
        rev.extend(reversed(chain.ops))
        x_set = (frozenset(a for a in as_ if a != x) | frozenset(chain.exts)
                 | frozenset(t for t in x_set if t not in by_output))
    rev.reverse()
    scheduled = {id(o) for o in rev}
    dead = [o for o in graph.operators if id(o) not in scheduled]
    schedule = dead + rev if dead else rev
    if not graph.is_valid_schedule(schedule):
        raise AssertionError("contracted schedule invalid")
    true_peak = graph.peak_usage(schedule)
    return ScheduleResult(schedule, true_peak, stats["states"],
                          method="exact-contracted")


# ----------------------------------------------------------------- one-stop
def _cheap_candidates(graph: Graph) -> List[ScheduleResult]:
    """Greedy plus the embedded (insertion) order — the tool must never make
    a model worse than the schedule it shipped with."""
    results = [greedy_schedule(graph)]
    try:
        default = graph.default_schedule()
        results.append(ScheduleResult(default, graph.peak_usage(default),
                                      0, method="default"))
    except ValueError:
        pass
    return results


def _schedule_plain(graph: Graph, exact_limit: int, contract_limit: int,
                    beam_width: int) -> ScheduleResult:
    results = _cheap_candidates(graph)
    ub = min(r.peak for r in results) + 1
    _, chains = build_chains(graph)
    if len(graph.operators) <= exact_limit:
        results.append(minimise_peak_memory(graph, upper_bound=ub))
    elif len(chains) <= contract_limit:
        r = minimise_peak_memory_contracted(graph, upper_bound=ub)
        if r is not None:
            results.append(r)
        else:
            results.append(beam_schedule(graph, width=beam_width))
    else:
        results.append(beam_schedule(graph, width=beam_width))
    best = min(results, key=lambda r: r.peak)
    return best


# Every escalation rung ``schedule()`` may climb, in order.  ``rungs``
# restricts the ladder to a subset — the graceful-degradation path in
# ``deploy.build(strict=False)`` walks progressively smaller subsets when a
# higher rung fails, so a cascade-rewrite bug degrades a deployment instead
# of sinking it (DESIGN.md §12).  "reorder" (the plain reordering base the
# paper starts from) is mandatory: with nothing else it is the identity
# fallback that can only fail if the graph itself is unschedulable.
_ALL_RUNGS = ("reorder", "pex", "cascade", "cascade2d", "solver")


def schedule(graph: Graph, exact_limit: int = 18, contract_limit: int = 40,
             beam_width: int = 64, arena_budget: Optional[int] = None,
             partition: bool = False,
             partition_opts: Optional[dict] = None,
             solver_nodes: int = 20_000, solver_op_limit: int = 24,
             objective: str = "memory",
             macs_cap: Optional[float] = None,
             rungs: Optional[Sequence[str]] = None) -> ScheduleResult:
    """Best-effort minimal-peak schedule:

    1. greedy (always) — provides a branch-and-bound upper bound;
    2. the paper's exact DP when the graph has ≤ ``exact_limit`` operators;
    3. chain-contracted DP when the contracted graph has ≤ ``contract_limit``
       super-nodes (near-exact; restricts chains to run contiguously);
    4. beam search otherwise;
    returns the best schedule found.

    **Partial-execution pre-pass.**  When ``partition`` is set — or
    ``arena_budget`` is given and reordering alone cannot reach it — the
    graph is rewritten by ``partition.partition_graph`` (operators split into
    K spatial slices plus an incremental concat) and the rewritten graph is
    scheduled too; whichever peak is lower wins.  A partitioned winner is
    returned with ``result.graph`` set to the rewritten graph (the schedule's
    operators belong to it); ``result.graph is None`` means the caller's
    graph.  The rewritten graph's insertion order already encodes the
    partial-execution order, so it is scheduled with the cheap candidates
    (default + greedy) only.

    **Cascaded-streaming escalation.**  When an ``arena_budget`` is given
    and whole-externals partial execution still misses it, the graph is
    rewritten by ``partition.cascade_graph`` — adjacent sliceable segments
    chained through ring buffers so no inter-segment tensor ever exists
    whole (capped on the halo-recompute MACs fraction) — followed by a
    whole-externals pass over the cascaded graph for any remaining
    over-budget runs (the cascade's tail).  When row rings alone still
    miss the budget, a final rung re-plans the cascade with W-strips
    (2-D tiled streaming: reorder → pex → 1-D cascade → 2-D tiled
    cascade).  The lowest peak wins at every rung.

    **Joint branch-and-bound rung.**  After the ladder, graphs with at most
    ``solver_op_limit`` operators get a bounded pass of the joint
    (order × Pex split) solver (``core/solver.py``), seeded with the
    ladder's winner so the result is never worse; ``solver_nodes`` caps its
    anytime search (0 disables the rung).  ``objective="memory"`` (default)
    keeps the ladder's contract — lowest peak wins, optionally bounded by
    ``macs_cap`` (max extra-MACs fraction) — while ``objective="latency"``
    (requires ``arena_budget``) returns the *cheapest* schedule that fits
    the budget: among in-budget Pareto points, minimal halo-recompute MACs.

    **Rung restriction.**  ``rungs`` limits the ladder to a subset of
    ``("reorder", "pex", "cascade", "cascade2d", "solver")`` — the
    graceful-degradation path (``deploy.build(strict=False)``) retries with
    shrinking subsets when a rung's rewrite fails.  ``"reorder"`` is
    mandatory (it is the base every other rung escalates from); ``None``
    (default) enables every rung, which is the historical behaviour.
    """
    if rungs is None:
        active = frozenset(_ALL_RUNGS)
    else:
        active = frozenset(rungs)
        unknown = active - frozenset(_ALL_RUNGS)
        if unknown:
            raise ValueError(f"unknown scheduler rungs {sorted(unknown)}; "
                             f"choose from {_ALL_RUNGS}")
        if "reorder" not in active:
            raise ValueError("the 'reorder' rung is the mandatory base of "
                             "the ladder and cannot be disabled")
    best = _ladder(graph, exact_limit, contract_limit, beam_width,
                   arena_budget, partition, partition_opts, active)
    if ("solver" in active and solver_nodes
            and 0 < len(graph.operators) <= solver_op_limit):
        from .solver import solve   # deferred: avoids import cycle
        mode = ("latency" if objective == "latency"
                and arena_budget is not None else "memory")
        joint = arena_budget is not None or partition
        sr = solve(graph, mode=mode, arena_budget=arena_budget,
                   macs_cap=macs_cap, max_nodes=solver_nodes,
                   max_rewrites=16 if joint else 0, seeds=[best])
        cand = sr.best
        if mode == "latency":
            if cand.peak <= arena_budget:
                return cand
            return cand if cand.peak < best.peak else best
        if cand.peak < best.peak:
            return cand
    return best


def _ladder(graph: Graph, exact_limit: int, contract_limit: int,
            beam_width: int, arena_budget: Optional[int],
            partition: bool,
            partition_opts: Optional[dict],
            active: FrozenSet[str] = frozenset(_ALL_RUNGS)
            ) -> ScheduleResult:
    """The fixed escalation ladder: reorder → pex → cascade → pex-over-tail
    → 2-D tiled cascade (greedy search inside each rung); the joint solver
    refines on top.  ``active`` gates which rungs may fire (degradation
    path; "reorder" is always implied)."""
    best = _schedule_plain(graph, exact_limit, contract_limit, beam_width)
    want = partition or (arena_budget is not None
                         and best.peak > arena_budget)
    if not want or not (active & {"pex", "cascade", "cascade2d"}):
        return best
    from .partition import (cascade_graph,    # deferred: partition is
                            partition_graph)  # optional
    if "pex" in active:
        pr = partition_graph(graph, budget=arena_budget,
                             **(partition_opts or {}))
        if pr.segments:
            pg = pr.graph
            pbest = min(_cheap_candidates(pg), key=lambda r: r.peak)
            if pbest.peak < best.peak:
                best = dataclasses.replace(pbest, graph=pg,
                                           method=pbest.method + "+pex",
                                           extra_macs=pr.extra_macs,
                                           total_macs=pr.total_macs,
                                           extra_macs_frac=pr.extra_macs_frac)
    if (arena_budget is None or best.peak <= arena_budget
            or not (active & {"cascade", "cascade2d"})):
        return best
    # the cascade planner honours the caller's shared partition knobs —
    # in particular a tightened overhead_cap (the halo-recompute latency
    # budget) must bind the escalation too, not just the whole-Pex passes
    shared = {k: v for k, v in (partition_opts or {}).items()
              if k in ("max_k", "overhead_cap", "k_choices")}

    def cascade_rung(strips_choices, tag):
        cr = cascade_graph(graph, budget=arena_budget,
                           strips_choices=strips_choices, **shared)
        if not cr.cascades:
            return None
        cg = cr.graph
        extra = cr.extra_macs
        cbest = min(_cheap_candidates(cg), key=lambda r: r.peak)
        method = cbest.method + tag
        if cbest.peak > arena_budget and "pex" in active:
            # the cascade's conventional tail may itself be over budget —
            # whole-externals partial execution composes over the cascaded
            # graph
            tr = partition_graph(cg, budget=arena_budget,
                                 **(partition_opts or {}))
            if tr.segments:
                tbest = min(_cheap_candidates(tr.graph),
                            key=lambda r: r.peak)
                if tbest.peak < cbest.peak:
                    cg, cbest = tr.graph, tbest
                    method = tbest.method + tag + "+pex"
                    # composed rewrites: halo recompute adds up — the Pex
                    # pass re-runs rows of the *cascaded* graph, on top of
                    # the cascade's own recompute.  Keep the fraction
                    # anchored on the original graph's MACs so it composes
                    # with the cascade rung and the solver's points.
                    extra += tr.extra_macs
        frac = extra / cr.total_macs if cr.total_macs else 0.0
        return dataclasses.replace(cbest, graph=cg, method=method,
                                   extra_macs=extra,
                                   total_macs=cr.total_macs,
                                   extra_macs_frac=frac)

    if "cascade" in active:
        cand = cascade_rung((1,), "+cascade")
        if cand is None:
            return best
        if cand.peak < best.peak:
            best = cand
    if best.peak > arena_budget and "cascade2d" in active:
        # 2-D tiled rung: row rings alone miss the budget, so re-plan with
        # W-strips in the search space (MCUNetV2-style patch streaming).
        # Gated on still-over-budget so in-budget row-cascade goldens are
        # byte-identical to the pre-2-D ladder.
        cand2d = cascade_rung((2, 3, 4), "+cascade2d")
        if cand2d is not None and cand2d.peak < best.peak:
            best = cand2d
    return best
