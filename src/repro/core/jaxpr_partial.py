"""Partial execution (Pex-style) for jaxprs.

``jaxpr_reorder`` applies the paper's operator reordering to jaxpr
equations; this module applies its sequel's transform: a chain of eligible
equations is split into K row-slices so the chain's interior tensors only
ever exist one slice at a time.  The rewritten jaxpr computes each output
slice with ``slice_p`` extracts + cloned equations, then writes it into a
shared accumulator with ``dynamic_update_slice`` — which XLA updates in
place when safe, and which ``jaxpr_to_graph`` marks ``inplace`` so the
liveness model charges the output buffer exactly once.

Eligible equations (split along the leading axis of the output):

* shape-preserving **elementwise** primitives (every non-scalar operand
  shares the output shape);
* **dot_general** whose lhs leading axis is a free (non-contracted,
  non-batch) dimension — slicing lhs rows slices output rows, the rhs is
  consumed whole (weights);
* **reduce_{sum,max,min,prod}** over axes not containing the leading axis.

All three have identity row-maps (no halo), so slicing costs no recompute.
Numerics: elementwise and reduce clones are bit-identical (slices copy bits
and per-element reduction order is unchanged); a sliced ``dot_general`` may
differ from the whole op within float accumulation tolerance (~1 ulp per
contraction step), because XLA's GEMM kernel selection — and with it the
K-dimension blocking order — depends on the row count.  The MCU graph path
(``core/partition.py``) keeps strict bit-identity; this jaxpr pass trades it
for the liveness win on matmul chains, which is the right call on TPU-class
backends where reductions are never bit-stable across tilings anyway.

The transform is conservative: anything it does not recognise leaves the
jaxpr unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
from jax.extend import core as jcore
from jax._src.core import (ShapedArray, Var, check_jaxpr, new_jaxpr_eqn,
                           no_effects)
from jax._src import source_info_util
from jax._src.lax import lax as lax_internal
from jax._src.lax import slicing as lax_slicing

from .graph import linear_chains
from .jaxpr_reorder import aval_bytes, jaxpr_to_graph

Literal = jcore.Literal

ELEMENTWISE_PRIMS = frozenset({
    "add", "sub", "mul", "div", "max", "min", "pow", "integer_pow", "rem",
    "neg", "abs", "sign", "exp", "expm1", "log", "log1p", "sqrt", "rsqrt",
    "cbrt", "tanh", "logistic", "erf", "sin", "cos", "tan", "sinh", "cosh",
    "floor", "ceil", "round", "convert_element_type", "select_n", "square",
    "and", "or", "xor", "not", "gt", "lt", "ge", "le", "eq", "ne",
})
REDUCE_PRIMS = frozenset({"reduce_sum", "reduce_max", "reduce_min",
                          "reduce_prod"})


@dataclasses.dataclass(frozen=True)
class EqnSlice:
    sliced: Tuple[int, ...]   # invar positions sliced along their leading axis


def eqn_sliceable(eqn) -> Optional[EqnSlice]:
    """Row-slice policy of an equation, or None when it cannot be split."""
    if eqn.effects or len(eqn.outvars) != 1:
        return None
    out = eqn.outvars[0]
    aval = getattr(out, "aval", None)
    shape = tuple(getattr(aval, "shape", ()) or ())
    if len(shape) < 1 or shape[0] < 2:
        return None
    name = eqn.primitive.name
    if name in ELEMENTWISE_PRIMS:
        sliced = []
        for pos, v in enumerate(eqn.invars):
            if isinstance(v, Literal):
                if np.shape(v.val) == ():
                    continue                      # scalar literal: keep as-is
                return None
            vshape = tuple(v.aval.shape)
            if vshape == shape:
                sliced.append(pos)
            elif vshape == ():
                continue
            else:
                return None                       # implicit broadcast: skip
        return EqnSlice(tuple(sliced)) if sliced else None
    if name == "dot_general":
        v = eqn.invars[0]
        if isinstance(v, Literal):
            return None
        (lc, _), (lb, rb) = eqn.params["dimension_numbers"]
        # out dim 0 is the lhs leading axis only when there are no batch
        # dims and that axis is free
        if lb or rb or 0 in lc or v.aval.shape[0] != shape[0]:
            return None
        return EqnSlice((0,))
    if name in REDUCE_PRIMS:
        v = eqn.invars[0]
        if isinstance(v, Literal) or 0 in eqn.params.get("axes", ()):
            return None
        if v.aval.shape[0] != shape[0]:
            return None
        return EqnSlice((0,))
    return None


def _find_runs(jaxpr) -> List[List[int]]:
    """Maximal runs (length >= 2) of sliceable equations along the linear
    chains of the jaxpr's scheduling graph, where each link enters its
    consumer only at sliced positions."""
    g, eqn_index = jaxpr_to_graph(jaxpr)
    runs: List[List[int]] = []
    for chain in linear_chains(g):
        cur: List[int] = []
        for node in chain:
            if node.name not in eqn_index:
                if len(cur) >= 2:
                    runs.append(cur)
                cur = []
                continue
            k = eqn_index[node.name]
            eqn = jaxpr.eqns[k]
            spec = eqn_sliceable(eqn)
            ok = spec is not None
            if ok and cur:
                prev_out = jaxpr.eqns[cur[-1]].outvars[0]
                positions = [p for p, v in enumerate(eqn.invars)
                             if v is prev_out]
                ok = bool(positions) and all(p in spec.sliced
                                             for p in positions)
            if ok:
                cur.append(k)
            else:
                if len(cur) >= 2:
                    runs.append(cur)
                cur = [k] if spec is not None else []
        if len(cur) >= 2:
            runs.append(cur)
    return runs


def _estimate_run(eqns: Sequence, k: int,
                  shard_divisor: int = 1) -> Tuple[int, int]:
    """(estimated local peak after splitting into k slices, before) — in the
    same per-device units the caller's budget uses."""
    def nbytes(aval):
        return aval_bytes(aval, shard_divisor)

    internal = {id(e.outvars[0]) for e in eqns}
    ext, seen = 0, set()
    for e in eqns:
        for v in e.invars:
            if isinstance(v, Literal) or id(v) in internal or id(v) in seen:
                continue
            seen.add(id(v))
            ext += nbytes(v.aval)
    out_b = nbytes(eqns[-1].outvars[0].aval)
    slice_live = before = 0
    for e in eqns:
        spec = eqn_sliceable(e)
        assert spec is not None
        whole = nbytes(e.outvars[0].aval) + sum(
            nbytes(v.aval) for v in e.invars
            if not isinstance(v, Literal))
        before = max(before, whole)
        step = -(-nbytes(e.outvars[0].aval) // k)
        for pos in spec.sliced:
            step += -(-nbytes(e.invars[pos].aval) // k)
        slice_live = max(slice_live, step)
    return ext + out_b + slice_live, before


def _src():
    return source_info_util.new_source_info()


def _expand_run(eqns: Sequence, k: int) -> List:
    """Replacement equations: zeros accumulator + per-slice extracts, clones
    and a dynamic_update_slice writing the slice into the accumulator.  The
    final update's outvar is the original output var, so consumers are
    untouched."""
    out = eqns[-1].outvars[0]
    oshape, odtype = tuple(out.aval.shape), out.aval.dtype
    h = oshape[0]
    bounds = [(s * h) // k for s in range(k + 1)]
    acc_aval = ShapedArray(oshape, odtype)
    idx_aval = ShapedArray((), np.dtype("int32"))
    res: List = []
    zero = Literal(np.zeros((), odtype), ShapedArray((), odtype))
    acc: object = Var("", acc_aval)
    res.append(new_jaxpr_eqn(
        [zero], [acc], lax_internal.broadcast_in_dim_p,
        dict(shape=oshape, broadcast_dimensions=(), sharding=None),
        no_effects, _src()))
    ext_slices: Dict[Tuple[int, int, int], Var] = {}
    for s in range(k):
        a, b = bounds[s], bounds[s + 1]
        clone_out: Dict[int, Var] = {}
        for d, eqn in enumerate(eqns):
            spec = eqn_sliceable(eqn)
            assert spec is not None
            ins = []
            for pos, v in enumerate(eqn.invars):
                if pos not in spec.sliced or isinstance(v, Literal):
                    ins.append(v)
                    continue
                if d > 0 and v is eqns[d - 1].outvars[0]:
                    ins.append(clone_out[d - 1])
                    continue
                key = (id(v), a, b)
                if key not in ext_slices:
                    vshape = tuple(v.aval.shape)
                    sv = Var("", ShapedArray((b - a,) + vshape[1:],
                                             v.aval.dtype))
                    res.append(new_jaxpr_eqn(
                        [v], [sv], lax_slicing.slice_p,
                        dict(start_indices=(a,) + (0,) * (len(vshape) - 1),
                             limit_indices=(b,) + vshape[1:], strides=None),
                        no_effects, _src()))
                    ext_slices[key] = sv
                ins.append(ext_slices[key])
            o = eqn.outvars[0]
            co = Var("", ShapedArray((b - a,) + tuple(o.aval.shape)[1:],
                                     o.aval.dtype))
            res.append(new_jaxpr_eqn(ins, [co], eqn.primitive,
                                     dict(eqn.params), no_effects, _src()))
            clone_out[d] = co
        nxt = out if s == k - 1 else Var("", acc_aval)
        idx = [Literal(np.int32(a), idx_aval)] + [
            Literal(np.int32(0), idx_aval)] * (len(oshape) - 1)
        res.append(new_jaxpr_eqn(
            [acc, clone_out[len(eqns) - 1], *idx], [nxt],
            lax_slicing.dynamic_update_slice_p, {}, no_effects, _src()))
        acc = nxt
    return res


def partial_execute_jaxpr(jaxpr, budget: Optional[int] = None,
                          k_choices: Sequence[int] = (2, 4, 8, 16),
                          shard_divisor: int = 1) -> Tuple[object, int]:
    """Split beneficial equation runs.  Returns (jaxpr, #runs split).
    ``budget`` is in the same per-device units as ``shard_divisor`` scales
    to (matching ``jaxpr_to_graph``'s liveness accounting)."""
    if jaxpr.effects:
        return jaxpr, 0
    chosen: Dict[int, Tuple[List[int], int]] = {}
    for run in _find_runs(jaxpr):
        eqns = [jaxpr.eqns[i] for i in run]
        h = tuple(eqns[-1].outvars[0].aval.shape)[0]
        best: Optional[Tuple[Tuple, int]] = None
        _, before = _estimate_run(eqns, 2, shard_divisor)
        for k in k_choices:
            if k > h:
                continue
            est, _ = _estimate_run(eqns, k, shard_divisor)
            if est >= before:
                continue
            meets = budget is not None and est <= budget
            key = (0 if meets else 1, est, k)
            if best is None or key < best[0]:
                best = (key, k)
        if best is not None:
            chosen[run[0]] = (run, best[1])
    if not chosen:
        return jaxpr, 0
    member = {i for run, _ in chosen.values() for i in run}
    new_eqns: List = []
    for i, eqn in enumerate(jaxpr.eqns):
        if i in chosen:
            run, k = chosen[i]
            new_eqns.extend(_expand_run([jaxpr.eqns[j] for j in run], k))
        elif i in member:
            continue
        else:
            new_eqns.append(eqn)
    new_jaxpr = jaxpr.replace(eqns=new_eqns)
    check_jaxpr(new_jaxpr)
    return new_jaxpr, len(chosen)


def partial_execute_closed_jaxpr(closed: jcore.ClosedJaxpr,
                                 budget: Optional[int] = None,
                                 k_choices: Sequence[int] = (2, 4, 8, 16),
                                 shard_divisor: int = 1
                                 ) -> Tuple[jcore.ClosedJaxpr, int]:
    new_jaxpr, n = partial_execute_jaxpr(closed.jaxpr, budget, k_choices,
                                         shard_divisor)
    if n == 0:
        return closed, 0
    return jcore.ClosedJaxpr(new_jaxpr, closed.consts), n
