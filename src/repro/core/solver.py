"""Joint branch-and-bound scheduler with a memory/latency Pareto front.

The paper's DP and the escalation ladder in ``heuristics.schedule`` treat
operator reordering and partial execution as separate rungs: reorder first,
then — if a budget is missed — rewrite with Pex/cascade and reorder the
rewrite greedily.  This module performs the *joint* search the ROADMAP calls
for (cf. SNIPPETS.md Snippet 1, HLS memory-resource-aware scheduling): one
anytime branch-and-bound over

* the operator order (all topological orders, pruned by an incumbent bound
  and a done-set dominance table), and
* the Pex split parameters (which contiguous sliceable sub-run to partition,
  and into how many slices K),

under two objective modes:

* ``mode="latency"`` — minimise extra MACs subject to ``peak <= arena_budget``
  (the deployment question: cheapest schedule that fits the SRAM);
* ``mode="memory"`` — minimise peak bytes subject to
  ``extra_macs_frac <= macs_cap`` (the headline question: smallest arena
  within a latency price).

Every solve also emits the full **Pareto front** of (arena bytes, extra
MACs) over the searched space, so benchmarks can pin points on the curve
instead of single scalars (see ``benchmarks/compare.py``).

Contracts (property- and oracle-tested in ``tests/test_solver_oracle.py``):

* **anytime** — ``max_nodes`` bounds the search; on exhaustion the best
  incumbent found so far is returned and ``SolverResult.complete`` is
  False.  The incumbent is seeded with the cheap candidates (default +
  greedy) of every candidate graph, so the result is always a *valid*
  schedule and never worse than those seeds.  More nodes never yields a
  worse result (the deterministic DFS explores a superset).
* **exact when complete** — with the node budget unexhausted and no
  rewrite candidates dropped, the front points are true optima over the
  searched space: brute-force enumeration of all topological orders and
  Pex splits of small graphs agrees exactly (``tests/oracle.py``).
* **deterministic** — no randomness, no wall-clock dependence; identical
  inputs give identical fronts and schedules.

The searched Pex space is ``{no split} ∪ {one (sub-run, K) split}`` — one
partitioned segment per solve, every contiguous sub-run of every sliceable
run, every K in ``2..min(max_k, rows)`` (or ``k_choices``).  Multi-segment
and cascade rewrites reach the solver only as *seeds* from the escalation
ladder (`heuristics.schedule` passes its rung results in).  The MACs
accounting is uniform on both sides: ``extra_macs`` is always absolute halo
recompute and ``extra_macs_frac`` is always relative to the whole graph's
MACs (``graph_macs`` — canonical definitions in ``core/partition.py``), for
solver points and ladder seeds alike.
"""
from __future__ import annotations

import dataclasses
import sys
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .graph import Graph, Operator, inplace_candidates
from .heuristics import _cheap_candidates
from .partition import (Segment, _height, apply_partition, estimate_segment,
                        graph_macs, op_macs, segment_extra_macs,
                        sliceable_runs)
from .scheduler import ScheduleResult

# Re-exported for the brute-force oracle and older call sites: the MACs
# accounting (op_macs / graph_macs / segment_extra_macs) now lives in
# ``core/partition.py`` next to the cost model whose units it defines.
__all__ = ["op_macs", "graph_macs", "segment_extra_macs", "solve",
           "enumerate_pex_configs", "pareto_front"]


# ------------------------------------------------------- incremental sim model
class _Sim:
    """Forward mirror of ``Graph.live_sets``: step cost and the post-step
    live set, order-independent given the set of already-executed ops.

    ``uses[t]`` counts t's remaining consumptions (graph outputs get +1 so
    they never die — the paper pins outputs to the end of the schedule).
    A step executing ``op`` charges the current live bytes plus the output
    buffer, unless the op is ``inplace`` and may overwrite an input that
    dies at this very step (same bytes, has a producer) — exactly the
    ``live_sets`` aliasing rule."""

    __slots__ = ("graph", "uses", "live", "live_bytes", "produced")

    def __init__(self, graph: Graph) -> None:
        self.graph = graph
        uses: Dict[str, int] = {}
        for op in graph.operators:
            for i in op.inputs:
                uses[i] = uses.get(i, 0) + 1
        for o in graph.outputs:
            uses[o] = uses.get(o, 0) + 1
        self.uses = uses
        self.live: Set[str] = {c for c in graph.constants()
                               if uses.get(c, 0) > 0}
        self.live_bytes = sum(graph.size(t) for t in self.live)
        self.produced: Set[str] = set()

    def ready(self, op: Operator) -> bool:
        return all(i in self.produced or self.graph.producer(i) is None
                   for i in op.inputs)

    def _inplace_ok(self, op: Operator) -> bool:
        if not op.attrs.get("inplace"):
            return False
        g, uses = self.graph, self.uses
        out_b = g.size(op.output)
        counts: Dict[str, int] = {}
        for i in op.inputs:
            counts[i] = counts.get(i, 0) + 1
        return any(g.producer(i) is not None and g.size(i) == out_b
                   and uses.get(i, 0) - counts[i] == 0
                   for i in inplace_candidates(op))

    def peek(self, op: Operator) -> Tuple[int, int]:
        """(step cost, live bytes after) of executing ``op`` now — pure."""
        g, uses = self.graph, self.uses
        step = self.live_bytes + (0 if self._inplace_ok(op)
                                  else g.size(op.output))
        after = self.live_bytes
        counts: Dict[str, int] = {}
        for i in op.inputs:
            counts[i] = counts.get(i, 0) + 1
        for i, c in counts.items():
            if uses.get(i, 0) - c == 0 and i in self.live:
                after -= g.size(i)
        if uses.get(op.output, 0) > 0:
            after += g.size(op.output)
        return step, after

    def apply(self, op: Operator) -> tuple:
        """Execute ``op``; returns an undo token for :meth:`undo`."""
        g, uses = self.graph, self.uses
        died: List[str] = []
        counts: Dict[str, int] = {}
        for i in op.inputs:
            counts[i] = counts.get(i, 0) + 1
        for i, c in counts.items():
            uses[i] -= c
            if uses[i] == 0 and i in self.live:
                self.live.remove(i)
                self.live_bytes -= g.size(i)
                died.append(i)
        out_live = uses.get(op.output, 0) > 0
        if out_live:
            self.live.add(op.output)
            self.live_bytes += g.size(op.output)
        self.produced.add(op.output)
        return op, counts, died, out_live

    def undo(self, token: tuple) -> None:
        op, counts, died, out_live = token
        g, uses = self.graph, self.uses
        self.produced.discard(op.output)
        if out_live:
            self.live.remove(op.output)
            self.live_bytes -= g.size(op.output)
        for i in died:
            self.live.add(i)
            self.live_bytes += g.size(i)
        for i, c in counts.items():
            uses[i] += c


# ------------------------------------------------------------ order-space B&B
@dataclasses.dataclass
class _Budget:
    """Shared anytime node budget: one unit = one DFS node expansion."""

    limit: int
    used: int = 0
    exhausted: bool = False

    def tick(self) -> bool:
        if self.used >= self.limit:
            self.exhausted = True
            return False
        self.used += 1
        return True


def _op_lower_bound(graph: Graph, op: Operator) -> int:
    """A step executing ``op`` holds all its distinct inputs plus (unless an
    inplace alias is possible) its output — in *any* schedule."""
    lb = sum(graph.size(i) for i in set(op.inputs))
    out_b = graph.size(op.output)
    maybe_inplace = op.attrs.get("inplace") and any(
        graph.producer(i) is not None and graph.size(i) == out_b
        for i in inplace_candidates(op))
    if not maybe_inplace:
        lb += out_b
    return lb


def branch_and_bound_order(graph: Graph, budget: _Budget,
                           seeds: Sequence[ScheduleResult] = ()
                           ) -> Tuple[ScheduleResult, bool]:
    """Minimal-peak topological order of ``graph`` by anytime DFS B&B.

    Returns ``(result, complete)``; ``complete`` means the search space was
    exhausted (up to sound pruning), so the result is a true optimum.  The
    incumbent is seeded with ``seeds`` plus the graph's cheap candidates
    (default order + greedy), so the result is never worse than either.
    """
    ops = graph.operators
    n = len(ops)
    cand = list(_cheap_candidates(graph))
    cand += [s for s in seeds if s is not None]
    best = min(cand, key=lambda r: r.peak)
    if n == 0:
        return best, True

    incumbent_peak = best.peak
    by_id = {id(op): k for k, op in enumerate(ops)}
    incumbent_order = [by_id[id(op)] for op in best.schedule]

    lbs = [_op_lower_bound(graph, op) for op in ops]
    if max(lbs) >= incumbent_peak:
        # every schedule must pay the fattest step — the seed is optimal
        return best, True

    sim = _Sim(graph)
    visited: Dict[FrozenSet[int], int] = {}
    order: List[int] = []
    state = {"complete": True, "incumbent": incumbent_peak,
             "order": incumbent_order}
    depth_needed = n * 3 + 200
    if sys.getrecursionlimit() < depth_needed:
        sys.setrecursionlimit(depth_needed)

    def dfs(done: FrozenSet[int], peak: int) -> None:
        if len(order) == n:
            if peak < state["incumbent"]:
                state["incumbent"] = peak
                state["order"] = list(order)
            return
        rem_lb = max(lbs[k] for k in range(n) if k not in done)
        if max(peak, rem_lb) >= state["incumbent"]:
            return
        seen = visited.get(done)
        if seen is not None and seen <= peak:
            return
        visited[done] = peak
        if not budget.tick():
            state["complete"] = False
            return
        children: List[Tuple[int, int, int]] = []
        for k, op in enumerate(ops):
            if k in done or not sim.ready(op):
                continue
            step, after = sim.peek(op)
            if max(peak, step) >= state["incumbent"]:
                continue
            children.append((after, step, k))
        children.sort()
        for after, step, k in children:
            if max(peak, step) >= state["incumbent"]:
                continue  # the incumbent may have improved mid-loop
            token = sim.apply(ops[k])
            order.append(k)
            dfs(done | {k}, max(peak, step))
            order.pop()
            sim.undo(token)
            if budget.exhausted:
                state["complete"] = False
                return

    dfs(frozenset(), 0)
    schedule = [ops[k] for k in state["order"]]
    true_peak = graph.peak_usage(schedule)
    assert true_peak == state["incumbent"], \
        "B&B incremental model diverged from Graph.live_sets"
    res = ScheduleResult(schedule, true_peak, budget.used, method="bnb")
    return res, state["complete"]


# ------------------------------------------------------------ joint Pex space
def enumerate_pex_configs(graph: Graph, max_k: int = 16,
                          k_choices: Optional[Sequence[int]] = None
                          ) -> List[Tuple[Tuple[Operator, ...], int]]:
    """The solver's split space: every contiguous sub-run (length >= 2) of
    every sliceable run, crossed with every K in ``2..min(max_k, rows)``
    (or the explicit ``k_choices``).  Deterministic order."""
    configs: List[Tuple[Tuple[Operator, ...], int]] = []
    for run in sliceable_runs(graph):
        for i in range(len(run)):
            for j in range(i + 1, len(run)):
                ops = tuple(run[i:j + 1])
                h = _height(graph, ops[-1].output)
                assert h is not None
                cap = min(max_k, h)
                ks = (sorted(set(k_choices)) if k_choices is not None
                      else range(2, cap + 1))
                for k in ks:
                    if 2 <= k <= cap:
                        configs.append((ops, k))
    return configs


@dataclasses.dataclass(frozen=True)
class ParetoPoint:
    """One point of the arena-bytes × extra-MACs trade-off curve."""

    peak: int                 # arena/peak bytes of the best order found
    extra_macs: int           # absolute halo-recompute MACs of the split
    extra_macs_frac: float    # relative to graph_macs(original graph)
    method: str
    exact: bool = True        # order search completed for this point
    result: Optional[ScheduleResult] = dataclasses.field(
        default=None, compare=False, repr=False)

    def as_json(self) -> dict:
        return {"arena_bytes": self.peak, "extra_macs": self.extra_macs,
                "extra_macs_frac": round(self.extra_macs_frac, 6),
                "method": self.method, "exact": self.exact}


def pareto_front(points: Sequence[ParetoPoint]) -> List[ParetoPoint]:
    """Non-dominated subset, sorted by extra MACs ascending.  On the result
    ``extra_macs`` is strictly increasing and ``peak`` strictly decreasing —
    the two monotonicity invariants the property tests pin."""
    front: List[ParetoPoint] = []
    best_peak: Optional[int] = None
    for p in sorted(points, key=lambda p: (p.extra_macs, p.peak, p.method)):
        if best_peak is None or p.peak < best_peak:
            front.append(p)
            best_peak = p.peak
    return front


@dataclasses.dataclass
class SolverResult:
    best: ScheduleResult
    front: List[ParetoPoint]
    nodes: int                # DFS nodes expanded across all candidates
    complete: bool            # False: node budget hit or configs dropped
    mode: str

    def front_json(self) -> List[dict]:
        return [p.as_json() for p in self.front]


def solve(graph: Graph, mode: str = "memory",
          arena_budget: Optional[int] = None,
          macs_cap: Optional[float] = None,
          max_nodes: int = 200_000, max_k: int = 16,
          k_choices: Optional[Sequence[int]] = None,
          max_rewrites: int = 64,
          seeds: Sequence[ScheduleResult] = ()) -> SolverResult:
    """Joint (order × Pex split) solve of ``graph``.  See module docstring
    for the modes, the searched space, and the anytime contract."""
    if mode not in ("memory", "latency"):
        raise ValueError(f"unknown mode {mode!r}")
    if mode == "latency" and arena_budget is None:
        raise ValueError("mode='latency' needs an arena_budget")
    budget = _Budget(max_nodes)
    total_macs = graph_macs(graph)
    points: List[ParetoPoint] = []

    base_res, base_ok = branch_and_bound_order(graph, budget)
    base_res = dataclasses.replace(base_res, extra_macs=0,
                                   total_macs=total_macs)
    points.append(ParetoPoint(base_res.peak, 0, 0.0, "bnb", base_ok,
                              base_res))

    configs = enumerate_pex_configs(graph, max_k, k_choices)
    dropped = False
    if len(configs) > max_rewrites:
        # deterministic pre-screen: keep the most promising by estimated
        # peak (cheap, no rewrite), tie-broken structurally
        configs.sort(key=lambda c: (estimate_segment(graph, c[0], c[1])[0],
                                    c[0][0].name, c[0][-1].name, c[1]))
        configs = configs[:max_rewrites]
        dropped = True
    for ops, k in configs:
        est, frac_seg = estimate_segment(graph, ops, k)
        extra = segment_extra_macs(graph, ops, k)
        seg = Segment(list(ops), k, est, frac_seg, extra)
        rewritten = apply_partition(graph, [seg])
        res, ok = branch_and_bound_order(rewritten, budget)
        frac = extra / total_macs if total_macs else 0.0
        method = (f"bnb+pex[{ops[0].name}..{ops[-1].name}/k{k}]")
        res = dataclasses.replace(res, graph=rewritten, method=method,
                                  extra_macs_frac=frac, extra_macs=extra,
                                  total_macs=total_macs)
        points.append(ParetoPoint(res.peak, extra, frac, method, ok, res))

    front = pareto_front(points)
    complete = (not dropped and not budget.exhausted
                and all(p.exact for p in front))

    # ---- pick the mode's winner from the front ---------------------------
    if mode == "latency":
        fits = [p for p in front if p.peak <= arena_budget]
        if fits:
            pick = min(fits, key=lambda p: (p.extra_macs, p.peak))
        else:
            pick = min(front, key=lambda p: (p.peak, p.extra_macs))
    else:
        cap = float("inf") if macs_cap is None else macs_cap
        ok_pts = [p for p in front if p.extra_macs_frac <= cap + 1e-12]
        pick = min(ok_pts or front, key=lambda p: (p.peak, p.extra_macs))
    best = pick.result
    assert best is not None

    # ---- external seeds (ladder rungs: multi-segment pex, cascades) ------
    # Seeds carry the same whole-graph extra_macs_frac as solver points
    # (canonical MACs accounting in core/partition.py), so the macs_cap
    # check below compares like with like: a seed wins when it satisfies
    # the active constraint at a strictly lower peak, or fits a budget the
    # solver space misses.
    for s in seeds:
        if s is None:
            continue
        if mode == "latency":
            # same rule as the front pick: among in-budget candidates,
            # fewest extra MACs wins, peak breaks ties.  (Seeds used to
            # carry extra_macs=None and were judged on peak alone — a
            # recomputing cascade could displace a free in-budget point.)
            if s.peak <= arena_budget:
                s_key = (s.extra_macs or 0, s.peak)
                if (best.peak > arena_budget
                        or s_key < (best.extra_macs or 0, best.peak)):
                    best = s
        else:
            cap = float("inf") if macs_cap is None else macs_cap
            if s.extra_macs_frac <= cap + 1e-12 and s.peak < best.peak:
                best = s

    return SolverResult(best=best, front=front, nodes=budget.used,
                        complete=complete, mode=mode)
