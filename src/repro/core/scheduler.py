"""Algorithm 1 of Liberis & Lane (2019): minimal peak memory via operator
reordering, as an exact memoized dynamic program over tensor sets.

``MEM(X)`` is the minimum peak memory needed to produce *and keep live* the
tensors of ``X``.  It recurses backwards by "un-applying" the producer of each
activation in ``X``; a candidate is skipped when its tensor is a (transitive)
predecessor of another tensor still required, since that would force the
producer to execute twice.  Memoised on the full tensor set.

The optimal schedule is recovered by tracing the argmin choices forward.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Tuple

from .graph import Graph, Operator


@dataclasses.dataclass
class ScheduleResult:
    schedule: List[Operator]
    peak: int
    states_visited: int
    method: str = "exact"
    # When a partial-execution pre-pass rewrote the graph, the schedule's
    # operators belong to this graph (None = the graph passed by the caller).
    graph: Optional["Graph"] = None
    # Halo-recompute cost of a partial-execution/cascade rewrite: extra
    # MACs as a fraction of the *whole graph's* MACs (0.0 = whole-operator
    # schedule) — the model-wide latency price paid for the memory saving.
    # Uniform units everywhere: the ladder rungs, the cascade planner and
    # the joint solver all anchor on ``graph_macs(original graph)``
    # (canonical accounting in core/partition.py), so fractions from any
    # producer compare directly.
    extra_macs_frac: float = 0.0
    # The absolute figures behind the fraction: halo-recompute MACs of the
    # schedule's rewrite, and the original graph's estimated total MACs
    # (``extra_macs / total_macs == extra_macs_frac``).  None only on
    # plain reorder-only results that never touched a rewrite pass.
    extra_macs: Optional[int] = None
    total_macs: Optional[int] = None


def _split(graph: Graph, x_set: FrozenSet[str]) -> Tuple[List[str], List[str]]:
    """PARTITION(X, x: producer(x) is None) -> (constants, activations)."""
    cs, as_ = [], []
    for t in x_set:
        (cs if graph.producer(t) is None else as_).append(t)
    return cs, as_


def minimise_peak_memory(graph: Graph,
                         upper_bound: Optional[int] = None) -> ScheduleResult:
    """Exact Algorithm 1 with memoisation and (optionally) branch-and-bound.

    ``upper_bound``: prune any branch whose running max already reaches this
    value (e.g. the peak of a known schedule).  ``None`` disables pruning and
    yields the literal paper algorithm.
    """
    if not graph.outputs:
        raise ValueError("graph has no outputs set")
    size = graph.size
    memo: Dict[FrozenSet[str], int] = {}
    choice: Dict[FrozenSet[str], str] = {}
    stats = {"states": 0}

    INF = float("inf")

    def mem(x_set: FrozenSet[str]) -> float:
        # NOTE on a fixed edge case vs the literal paper pseudo-code: line 18
        # of Algorithm 1 adds sum(cs) on top of line 15's sum(rs ∪ is ∪ {x}),
        # which double-counts a constant that is simultaneously held in X and
        # consumed by producer(x) (possible when a constant has several
        # consumers).  We instead keep constants inside the recursion set and
        # compute every working-set total over a deduplicated set union —
        # identical to the paper whenever constants have one consumer (e.g.
        # its Figure 1), and consistent with Graph.live_sets() in general.
        if x_set in memo:
            return memo[x_set]
        stats["states"] += 1
        cs, as_ = _split(graph, x_set)
        if not as_:
            total = sum(size(c) for c in cs)
            memo[x_set] = total
            return total
        cs_f = frozenset(cs)
        m = INF
        best: Optional[str] = None
        for x in sorted(as_):  # deterministic tie-breaking
            rs = [a for a in as_ if a != x]
            # producer(x) would need to run again if x precedes a held tensor
            if any(x in graph.predecessors_of_tensor(r) for r in rs):
                continue
            op = graph.producer(x)
            assert op is not None
            succ = frozenset(rs) | frozenset(op.inputs) | cs_f
            here = sum(size(t)
                       for t in (set(rs) | set(op.inputs) | {x} | set(cs)))
            # Branch-and-bound: this candidate's step cost already reaches the
            # incumbent — it cannot improve on it (m' >= here).
            if upper_bound is not None and here >= upper_bound and m < INF:
                continue
            m_prime = max(mem(succ), here)
            if m_prime < m:
                m = m_prime
                best = x
        if best is not None:
            choice[x_set] = best
        memo[x_set] = m
        return m

    top = frozenset(graph.outputs)
    peak = mem(top)
    if peak == INF:
        raise RuntimeError("no valid schedule found (pruning too aggressive?)")

    # ---- trace the argmin choices to recover the (reversed) schedule -------
    rev: List[Operator] = []
    x_set = top
    while True:
        _, as_ = _split(graph, x_set)
        if not as_:
            break
        x = choice[x_set]
        op = graph.producer(x)
        assert op is not None
        rev.append(op)
        # Follow exactly the recursion key used by mem().
        x_set = (frozenset(a for a in as_ if a != x) | frozenset(op.inputs)
                 | frozenset(c for c in x_set if graph.producer(c) is None))
    rev.reverse()

    # The recursion covers operators reachable from the outputs; any operator
    # not reachable (dead code) is appended in original (topological) order.
    scheduled = {id(o) for o in rev}
    dead = [o for o in graph.operators if id(o) not in scheduled]
    schedule = dead + rev if dead else rev
    if not graph.is_valid_schedule(schedule):
        raise AssertionError("extracted schedule is invalid")
    return ScheduleResult(schedule=schedule, peak=int(peak),
                          states_visited=stats["states"], method="exact")
