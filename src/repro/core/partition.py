"""Partial execution (Pex-style) graph transform.

The paper (Liberis & Lane 2019) reorders whole operators; its sequel — *Pex:
Memory-efficient Microcontroller Deep Learning through Partial Execution*
(Liberis & Lane 2022) — goes further: an operator chain is split into K
spatial slices so that only a fraction of its interior tensors is ever live.
This module implements that transform over the reordering ``Graph`` IR:

* **Eligibility** is declared per-operator through a ``SliceSpec`` attached in
  ``Operator.attrs`` (see ``graphs/cnn_ops.py`` for the CNN classification:
  elementwise ops, depthwise/regular convolutions and spatial pooling are
  sliceable; global pooling, FC and concat are not).  A spec carries the
  row-map of the op — kernel/stride under TF-style SAME padding — which is
  everything needed to push an output row range back to the input rows it
  reads (including the halo that neighbouring slices recompute).

* **Segments** are contiguous runs of sliceable operators inside the maximal
  linear chains of the graph.  Splitting a single operator cannot save
  memory (its input and output buffers must coexist regardless); splitting a
  chain means the fat *interior* tensors only ever exist one slice at a time.

* **The rewrite** replaces a segment with, per slice ``s``:
  ``pex_slice`` extract operators (halo-aware row windows of the segment's
  external inputs), per-slice clones of the member operators (explicit
  padding replaces SAME so numerics are bit-identical), and an incremental
  ``pex_concat`` that writes the slice into the full output buffer.  The
  concat chain is marked ``inplace`` — each link dies as the next is written
  — so the memory model (``Graph.live_sets``), the arena planner and the
  micro-interpreter all charge the output buffer exactly once.  This mirrors
  Pex's "operators write into a shared buffer" execution.

* **The cost model** (``plan_partition``) picks per-segment boundaries and K
  to hit a target arena budget, subject to a cap on the extra MACs spent
  recomputing halo rows — the Pex latency/memory trade-off.

The transform never changes results: a partitioned graph evaluates
bit-identically to the original (property-tested through the
micro-interpreter).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import Graph, Operator, linear_chains

# Attribute key under which builders attach a SliceSpec to eligible ops.
PEX_ATTR = "pex_slice_spec"


@dataclasses.dataclass(frozen=True)
class SliceSpec:
    """Row-map of a spatially-sliceable operator (TF SAME padding semantics).

    ``kernel``/``stride`` describe how output rows map to input rows along
    the leading (height) axis.  ``sliced_inputs`` lists the input positions
    that follow the row map (``None`` = all of them — elementwise); inputs
    not listed are consumed whole by every slice.  ``make_fn(op, pad_top,
    pad_bottom)`` builds the executable for a clone whose input slice needs
    explicit edge padding; ``None`` leaves clones without semantics
    (scheduling-only graphs).  ``macs_per_row`` feeds the halo-recompute
    overhead model.

    ``kernel_w``/``stride_w`` describe the same window map along the second
    (width) axis for 2-D tiled streaming; ``None`` means square — the
    height values apply.  A 2-D-capable ``make_fn`` additionally accepts
    ``(pad_left, pad_right)``; 1-D callers never pass them, so legacy
    two-argument factories keep working unchanged.
    """

    kernel: int = 1
    stride: int = 1
    sliced_inputs: Optional[Tuple[int, ...]] = None
    make_fn: Optional[Callable[[Operator, int, int], Callable[..., Any]]] = None
    macs_per_row: int = 0
    kernel_w: Optional[int] = None
    stride_w: Optional[int] = None

    @property
    def kw(self) -> int:
        return self.kernel if self.kernel_w is None else self.kernel_w

    @property
    def sw(self) -> int:
        return self.stride if self.stride_w is None else self.stride_w


def spec_of(op: Operator) -> Optional[SliceSpec]:
    return op.attrs.get(PEX_ATTR)


# ------------------------------------------------------------------ row maps
def same_pads(h_in: int, kernel: int, stride: int) -> Tuple[int, int, int]:
    """(out_rows, pad_begin, pad_end) of TF-style SAME padding."""
    out = -(-h_in // stride)
    total = max((out - 1) * stride + kernel - h_in, 0)
    return out, total // 2, total - total // 2


def in_rows(kernel: int, stride: int, h_in: int, oa: int, ob: int
            ) -> Tuple[int, int, int, int]:
    """Input rows [lo, hi) and explicit pads (top, bottom) needed to produce
    output rows [oa, ob) of a SAME-padded windowed op."""
    _, pad_beg, _ = same_pads(h_in, kernel, stride)
    lo = oa * stride - pad_beg
    hi = (ob - 1) * stride - pad_beg + kernel
    top, bottom = max(0, -lo), max(0, hi - h_in)
    return max(lo, 0), min(hi, h_in), top, bottom


def _height(graph: Graph, tensor: str) -> Optional[int]:
    t = graph.tensors[tensor]
    if not t.shape:
        return None
    h = int(t.shape[0])
    if h < 1 or t.size % h != 0:
        return None
    return h


def _width(graph: Graph, tensor: str) -> Optional[int]:
    """Spatial width (second axis) of a tensor, or ``None`` when the shape
    has no width axis — 1-D tensors stream by rows only."""
    t = graph.tensors[tensor]
    if len(t.shape) < 2:
        return None
    h, w = int(t.shape[0]), int(t.shape[1])
    if w < 1 or h < 1 or t.size % (h * w) != 0:
        return None
    return w


def _win_row_bytes(graph: Graph, tensor: str, cols: Tuple[int, int]) -> int:
    """Bytes of ONE row narrowed to the column window [clo, chi): row bytes
    scale linearly in retained columns (rows are column-major contiguous in
    the byte model, so the division is exact)."""
    w = _width(graph, tensor)
    assert w is not None
    clo, chi = cols
    return _row_bytes(graph, tensor) * (chi - clo) // w


def _chain_input_index(op: Operator, pred_output: str) -> int:
    return op.inputs.index(pred_output)


def _op_eligible(graph: Graph, op: Operator) -> bool:
    spec = spec_of(op)
    if spec is None:
        return False
    h_out = _height(graph, op.output)
    if h_out is None or h_out < 2:
        return False
    sliced = (spec.sliced_inputs if spec.sliced_inputs is not None
              else tuple(range(len(op.inputs))))
    if not sliced:
        return False
    if spec.kernel > 1 or spec.stride > 1:
        # windowed ops: exactly one halo'd input whose SAME output height
        # matches the recorded output height
        if len(sliced) != 1:
            return False
        h_in = _height(graph, op.inputs[sliced[0]])
        if h_in is None or same_pads(h_in, spec.kernel, spec.stride)[0] != h_out:
            return False
    else:
        # elementwise family: every sliced input must share the output height
        for idx in sliced:
            if idx >= len(op.inputs) or _height(graph, op.inputs[idx]) != h_out:
                return False
    return True


def _sliced_indices(op: Operator) -> Tuple[int, ...]:
    spec = spec_of(op)
    assert spec is not None
    return (spec.sliced_inputs if spec.sliced_inputs is not None
            else tuple(range(len(op.inputs))))


def sliceable_runs(graph: Graph) -> List[List[Operator]]:
    """Contiguous runs (length >= 2) of sliceable ops within the maximal
    linear chains of the graph, where every chain link enters its consumer
    through a sliced input position."""
    runs: List[List[Operator]] = []
    for chain in linear_chains(graph):
        cur: List[Operator] = []
        for op in chain:
            links = (not cur or
                     _chain_input_index(op, cur[-1].output) in
                     (_sliced_indices(op) if spec_of(op) else ()))
            if _op_eligible(graph, op) and links:
                cur.append(op)
            else:
                if len(cur) >= 2:
                    runs.append(cur)
                cur = [op] if _op_eligible(graph, op) else []
        if len(cur) >= 2:
            runs.append(cur)
    return runs


# --------------------------------------------------------------- slice plans
@dataclasses.dataclass
class _SlicePlan:
    # per op name: output row range (oa, ob)
    out: Dict[str, Tuple[int, int]]
    # per op name: per input index -> (lo, hi, pad_top, pad_bottom) for
    # sliced inputs, None for whole inputs
    ins: Dict[str, List[Optional[Tuple[int, int, int, int]]]]


def slice_plans(graph: Graph, ops: Sequence[Operator], k: int
                ) -> List[_SlicePlan]:
    """Back-propagate output row ranges of the K slices through the segment.
    Slice ``s`` of the final output covers rows [s*H//K, (s+1)*H//K)."""
    h_final = _height(graph, ops[-1].output)
    assert h_final is not None and 2 <= k <= h_final
    bounds = [(s * h_final) // k for s in range(k + 1)]
    plans: List[_SlicePlan] = []
    for s in range(k):
        out: Dict[str, Tuple[int, int]] = {}
        ins: Dict[str, List[Optional[Tuple[int, int, int, int]]]] = {}
        oa, ob = bounds[s], bounds[s + 1]
        for d in range(len(ops) - 1, -1, -1):
            op = ops[d]
            spec = spec_of(op)
            assert spec is not None
            out[op.name] = (oa, ob)
            sliced = _sliced_indices(op)
            row_plan: List[Optional[Tuple[int, int, int, int]]] = []
            for idx, inp in enumerate(op.inputs):
                if idx not in sliced:
                    row_plan.append(None)
                    continue
                h_in = _height(graph, inp)
                assert h_in is not None
                row_plan.append(in_rows(spec.kernel, spec.stride, h_in,
                                        oa, ob))
            ins[op.name] = row_plan
            if d > 0:
                ci = _chain_input_index(op, ops[d - 1].output)
                lo, hi, _, _ = row_plan[ci]  # type: ignore[misc]
                oa, ob = lo, hi
        plans.append(_SlicePlan(out, ins))
    return plans


# ----------------------------------------------------------------- cost model
@dataclasses.dataclass
class Segment:
    ops: List[Operator]
    k: int
    est_peak: int            # local estimate: externals + output + slice live
    extra_macs_frac: float   # halo recompute cost relative to segment MACs
    extra_macs: int = 0      # absolute halo-recompute MACs (whole-graph units)


def _row_bytes(graph: Graph, tensor: str) -> int:
    h = _height(graph, tensor)
    assert h is not None
    return graph.size(tensor) // h


def _macs_per_row(graph: Graph, op: Operator) -> int:
    spec = spec_of(op)
    if spec is not None and spec.macs_per_row > 0:
        return spec.macs_per_row
    return max(1, _row_bytes(graph, op.output))


def _external_inputs(ops: Sequence[Operator]) -> List[str]:
    internal = {op.output for op in ops}
    exts: List[str] = []
    for op in ops:
        for i in op.inputs:
            if i not in internal and i not in exts:
                exts.append(i)
    return exts


# ----------------------------------------------------------- MACs accounting
# Canonical home of the latency cost model's units (the joint solver and the
# brute-force oracle import these via core/solver.py): absolute MACs so
# numbers from different rewrites — single segments, multi-segment Pex,
# cascades — are commensurable, and whole-graph totals so every reported
# ``extra_macs_frac`` means "fraction of the model's inference MACs".
def op_macs(graph: Graph, op: Operator) -> int:
    """Estimated MACs of one operator: ``rows * macs_per_row`` when the op
    has a spatial height (the Pex cost model's unit), otherwise the output
    bytes as a proxy."""
    h = _height(graph, op.output)
    if h is None:
        return max(1, graph.size(op.output))
    return h * _macs_per_row(graph, op)


def graph_macs(graph: Graph) -> int:
    """Estimated MACs of the whole (unpartitioned) graph."""
    return sum(op_macs(graph, op) for op in graph.operators)


def segment_extra_macs(graph: Graph, ops: Sequence[Operator], k: int) -> int:
    """Absolute halo-recompute MACs of splitting ``ops`` into K slices:
    rows computed beyond each op's height, priced at its per-row MACs."""
    rows_done: Dict[str, int] = {}
    for plan in slice_plans(graph, ops, k):
        for op in ops:
            oa, ob = plan.out[op.name]
            rows_done[op.name] = rows_done.get(op.name, 0) + (ob - oa)
    extra = 0
    for op in ops:
        h = _height(graph, op.output)
        assert h is not None
        extra += max(0, rows_done[op.name] - h) * _macs_per_row(graph, op)
    return extra


def estimate_segment(graph: Graph, ops: Sequence[Operator], k: int
                     ) -> Tuple[int, float]:
    """(estimated peak bytes while the partitioned segment runs, halo
    overhead as a fraction of the segment's MACs).

    The estimate charges: every external input whole (slices are extracted
    from it, so it lives until the last slice), the full output buffer (the
    inplace concat accumulator), and the fattest per-slice step
    (inputs + output of one clone).  Co-live tensors from elsewhere in the
    graph are not the segment's to know — callers verify the true peak by
    scheduling the rewritten graph.
    """
    plans = slice_plans(graph, ops, k)
    ext_bytes = sum(graph.size(e) for e in _external_inputs(ops))
    out_bytes = graph.size(ops[-1].output)
    slice_live = 0
    base_macs = extra_macs = 0
    rows_done: Dict[str, int] = {}
    for op in ops:
        base_macs += _height(graph, op.output) * _macs_per_row(graph, op)
    for plan in plans:
        for d, op in enumerate(ops):
            oa, ob = plan.out[op.name]
            step = (ob - oa) * _row_bytes(graph, op.output)
            for idx, rp in enumerate(plan.ins[op.name]):
                if rp is not None:
                    lo, hi, _, _ = rp
                    step += (hi - lo) * _row_bytes(graph, op.inputs[idx])
            slice_live = max(slice_live, step)
            rows_done[op.name] = rows_done.get(op.name, 0) + (ob - oa)
    for op in ops:
        extra = rows_done[op.name] - _height(graph, op.output)
        extra_macs += max(0, extra) * _macs_per_row(graph, op)
    frac = extra_macs / base_macs if base_macs else 0.0
    return ext_bytes + out_bytes + slice_live, frac


def _local_baseline(graph: Graph, ops: Sequence[Operator]) -> int:
    """Unpartitioned local peak proxy: fattest single step of the run."""
    return max(graph.size(op.output) + sum(graph.size(i) for i in op.inputs)
               for op in ops)


def _choose_in_run(graph: Graph, run: List[Operator],
                   budget: Optional[int], max_k: int, overhead_cap: float,
                   k_choices: Sequence[int]) -> List[Segment]:
    """Best (sub-segment, K) of a sliceable run, then recurse on what is left
    to the segment's sides (a long chain may need several segments)."""
    if len(run) < 2:
        return []
    best: Optional[Tuple[Tuple, int, int, int, float]] = None
    baseline = _local_baseline(graph, run)
    for i in range(len(run)):
        for j in range(i + 1, len(run)):
            ops = run[i:j + 1]
            h_final = _height(graph, ops[-1].output)
            floor = (sum(graph.size(e) for e in _external_inputs(ops))
                     + graph.size(ops[-1].output))
            if floor >= baseline and (budget is None or floor >= budget):
                continue            # cannot beat the unsplit run
            for k in k_choices:
                if k > min(max_k, h_final):
                    continue
                est, frac = estimate_segment(graph, ops, k)
                if frac > overhead_cap or est >= baseline:
                    continue
                meets = budget is not None and est <= budget
                # rank: meeting the budget first, then lowest estimated
                # peak, then cheapest halo recompute, then smallest K
                key = (0 if meets else 1, est, frac, k)
                if best is None or key < best[0]:
                    best = (key, i, j, k, frac)
    if best is None:
        return []
    _, i, j, k, frac = best
    ops = run[i:j + 1]
    est, frac = estimate_segment(graph, ops, k)
    segs = [Segment(list(ops), k, est, frac,
                    segment_extra_macs(graph, ops, k))]
    segs += _choose_in_run(graph, run[:i], budget, max_k, overhead_cap,
                           k_choices)
    segs += _choose_in_run(graph, run[j + 1:], budget, max_k, overhead_cap,
                           k_choices)
    return segs


def plan_partition(graph: Graph, budget: Optional[int] = None,
                   max_k: int = 16, overhead_cap: float = 0.5,
                   k_choices: Sequence[int] = (2, 3, 4, 6, 8, 12, 16)
                   ) -> List[Segment]:
    segs: List[Segment] = []
    for run in sliceable_runs(graph):
        segs.extend(_choose_in_run(graph, run, budget, max_k, overhead_cap,
                                   k_choices))
    return segs


# -------------------------------------------------------------------- rewrite
def _slice_fn(lo: int, hi: int, clo: Optional[int] = None,
              chi: Optional[int] = None) -> Callable[..., Any]:
    if clo is None:
        def fn(a, lo=lo, hi=hi):
            return np.asarray(a)[lo:hi]
    else:
        def fn(a, lo=lo, hi=hi, clo=clo, chi=chi):
            return np.asarray(a)[lo:hi, clo:chi]
    return fn


def _concat_fn(start: int, shape: Tuple[int, ...], first: bool,
               cstart: Optional[int] = None) -> Callable[..., Any]:
    if first:
        def fn(part, start=start, shape=shape, cstart=cstart):
            part = np.asarray(part)
            acc = np.zeros(shape, part.dtype)
            if cstart is None:
                acc[start:start + part.shape[0]] = part
            else:
                acc[start:start + part.shape[0],
                    cstart:cstart + part.shape[1]] = part
            return acc
    else:
        def fn(acc, part, start=start, cstart=cstart):
            part = np.asarray(part)
            out = np.array(acc)        # the simulator copies; on-device this
            if cstart is None:         # writes in place
                out[start:start + part.shape[0]] = part
            else:
                out[start:start + part.shape[0],
                    cstart:cstart + part.shape[1]] = part
            return out
    return fn


def _emit_segment(old: Graph, new: Graph, seg: Segment) -> None:
    ops, k = seg.ops, seg.k
    head = ops[0].name
    y = ops[-1].output
    ty = old.tensors[y]
    executable = all(op.fn is not None for op in ops) and all(
        spec_of(op).make_fn is not None for op in ops)  # type: ignore[union-attr]
    plans = slice_plans(old, ops, k)
    bounds = [plan.out[ops[-1].name] for plan in plans]
    extracts: Dict[Tuple[str, int, int], str] = {}
    acc_prev: Optional[str] = None

    # Every emitted op carries structured metadata (segment head, slice
    # index, row windows) so backends that cannot call the numpy closures —
    # the compiled arena executor lowers pex_slice/pex_concat to
    # lax.dynamic_slice/dynamic_update_slice and rolls uniform slices into a
    # fori_loop — can reconstruct the computation from attrs alone.
    def extract(inp: str, lo: int, hi: int, s: int) -> str:
        key = (inp, lo, hi)
        if key not in extracts:
            t_in = old.tensors[inp]
            tname = f"{inp}__pex_{head}_{lo}_{hi}"
            shape = (hi - lo,) + tuple(t_in.shape[1:]) if t_in.shape else ()
            new.add_tensor(tname, (hi - lo) * _row_bytes(old, inp), shape,
                           t_in.dtype)
            new.add_operator(f"pexsl__{head}_{len(extracts)}", [inp], tname,
                             kind="pex_slice",
                             fn=_slice_fn(lo, hi) if executable else None,
                             pex_seg=head, pex_slice_idx=s, pex_rows=(lo, hi))
            extracts[key] = tname
        return extracts[key]

    for s in range(k):
        plan = plans[s]
        for d, op in enumerate(ops):
            spec = spec_of(op)
            assert spec is not None
            oa, ob = plan.out[op.name]
            pads = (0, 0)
            ins: List[str] = []
            for idx, inp in enumerate(op.inputs):
                rp = plan.ins[op.name][idx]
                if rp is None:
                    ins.append(inp)               # consumed whole
                    continue
                lo, hi, top, bottom = rp
                if top or bottom:
                    pads = (top, bottom)
                if d > 0 and inp == ops[d - 1].output:
                    ins.append(f"{inp}__pex{s}")
                else:
                    ins.append(extract(inp, lo, hi, s))
            t_out = old.tensors[op.output]
            oname = f"{op.output}__pex{s}"
            shape = ((ob - oa,) + tuple(t_out.shape[1:])
                     if t_out.shape else ())
            new.add_tensor(oname, (ob - oa) * _row_bytes(old, op.output),
                           shape, t_out.dtype)
            attrs = {a: v for a, v in op.attrs.items() if a != PEX_ATTR}
            attrs["pex_of"] = op.name
            attrs["pex_seg"] = head
            attrs["pex_slice_idx"] = s
            attrs["pex_pads"] = pads
            fn = (spec.make_fn(op, pads[0], pads[1])
                  if executable else None)   # type: ignore[misc]
            new.add_operator(f"{op.name}__pex{s}", ins, oname, kind=op.kind,
                             fn=fn, **attrs)
        # incremental concat: write this slice into the shared output buffer
        part = f"{y}__pex{s}"
        start = bounds[s][0]
        out_name = y if s == k - 1 else f"{y}__pexacc{s}"
        if s < k - 1:
            new.add_tensor(out_name, ty.size, ty.shape, ty.dtype)
        if s == 0:
            new.add_operator(f"pexcat__{head}_0", [part], out_name,
                             kind="pex_concat",
                             fn=(_concat_fn(start, tuple(ty.shape), True)
                                 if executable else None),
                             pex_seg=head, pex_slice_idx=s, pex_start=start,
                             pex_first=True)
        else:
            new.add_operator(f"pexcat__{head}_{s}", [acc_prev, part],
                             out_name, kind="pex_concat",
                             fn=(_concat_fn(start, tuple(ty.shape), False)
                                 if executable else None),
                             inplace=True, inplace_input=acc_prev,
                             pex_seg=head, pex_slice_idx=s, pex_start=start,
                             pex_first=False)
        acc_prev = out_name


@dataclasses.dataclass
class PartitionResult:
    graph: Graph
    segments: List[Segment]
    total_macs: int = 0      # graph_macs of the ORIGINAL (unsplit) graph

    @property
    def n_slices(self) -> int:
        return sum(s.k for s in self.segments)

    @property
    def extra_macs(self) -> int:
        """Absolute halo-recompute MACs over all segments (disjoint ops)."""
        return sum(s.extra_macs for s in self.segments)

    @property
    def extra_macs_frac(self) -> float:
        """Halo recompute overhead as a fraction of the whole graph's MACs
        (the model-wide latency price — same units as the joint solver's
        front axis)."""
        return self.extra_macs / self.total_macs if self.total_macs else 0.0

    def __str__(self) -> str:
        return (f"pex: {len(self.segments)} segments, "
                f"{self.n_slices} slices, halo overhead "
                f"{self.extra_macs_frac:.1%} of graph MACs")


def apply_partition(graph: Graph, segments: Sequence[Segment]) -> Graph:
    """Rewrite ``graph`` with every segment split into its K slices.  The
    rewritten graph's insertion order is the Pex execution order (slice 0's
    chain, its concat, slice 1's chain, ...), so ``default_schedule`` of the
    result is already partial-execution-shaped; schedulers may still improve
    on it."""
    heads = {seg.ops[0].name: seg for seg in segments}
    member = {op.name for seg in segments for op in seg.ops}
    interior = {op.output for seg in segments for op in seg.ops[:-1]}
    new = Graph()
    for name, t in graph.tensors.items():
        if name not in interior:
            new.add_tensor(name, t.size, t.shape, t.dtype)
    for op in graph.operators:
        if op.name in heads:
            _emit_segment(graph, new, heads[op.name])
        elif op.name in member:
            continue
        else:
            new.add_operator(op.name, list(op.inputs), op.output,
                             kind=op.kind, fn=op.fn, **op.attrs)
    new.set_outputs(graph.outputs)
    return new


def partition_graph(graph: Graph, budget: Optional[int] = None,
                    max_k: int = 16, overhead_cap: float = 0.5,
                    k_choices: Sequence[int] = (2, 3, 4, 6, 8, 12, 16)
                    ) -> PartitionResult:
    """One-stop transform: plan segments/K against ``budget`` (None = just
    minimise the estimated peak) and rewrite the graph.  Returns the input
    graph unchanged (``result.graph is graph``) when nothing is eligible."""
    segments = plan_partition(graph, budget, max_k, overhead_cap, k_choices)
    if not segments:
        return PartitionResult(graph, [], graph_macs(graph))
    return PartitionResult(apply_partition(graph, segments), segments,
                           graph_macs(graph))


# ======================================================= cascaded streaming
# Whole-externals partial execution (above) charges every segment's external
# input whole and materialises its output whole — a ~280 KB floor under
# MobileNet-1.0@192 int8 (108 KB input + accumulator + slice live).  Full
# Pex (Liberis & Lane 2022) and MCUNetV2's patch-based inference break that
# floor by *cascading*: adjacent segments execute interleaved, slice by
# slice, and the tensor between two cascaded segments never exists whole.
# Only a rolling window of its most recent rows is kept — a **ring buffer**
# sized by the consumer's receptive field (kernel + stride carry from the
# SAME-padding row map):
#
#   input ──seg0──▶ [ring: R0 rows] ──seg1──▶ [ring: R1 rows] ──seg2──▶ out
#
# Per final-output slice s, each segment i produces only the *delta* rows
# its consumer newly needs (rows already in the ring are retained, not
# recomputed — cascades also cut halo recompute vs whole-externals), pushes
# them into the ring at position ``row % R`` (``pex_ring_push``, an inplace
# rolling write — the SSA chain of ring states aliases to ONE buffer via the
# existing inplace accounting), and the consumer reads its halo'd window
# back out as a contiguous tensor (``pex_ring_read``).  The cost model
# charges an inter-segment tensor ``ring_rows * row_bytes`` instead of its
# full size; externals of the first segment and the cascade's final output
# are still charged whole.


@dataclasses.dataclass
class Cascade:
    """A planned cascade: consecutive sub-segments of one sliceable run,
    ring row counts for the boundaries between them, and the slice count K
    of the final output that drives the interleaved execution."""

    segments: List[List[Operator]]
    k: int
    ring_rows: List[int]          # per boundary i (= output of segments[i])
    est_peak: int
    extra_macs_frac: float        # relative to the cascade's own MACs
    min_rows: int = 1             # per-iteration chunk floor (see plans)
    rate_div: int = 1             # pipeline slowdown factor (see plans)
    extra_macs: int = 0           # absolute halo MACs (whole-graph units)
    strips: int = 1               # W-strips: 1 = row rings, >1 = 2-D tiles

    @property
    def ops(self) -> List[Operator]:
        return [op for seg in self.segments for op in seg]


@dataclasses.dataclass
class _CascadeSlice:
    """Row bookkeeping of one final-output slice across the cascade."""

    deltas: List[Tuple[int, int]]               # per segment: new out rows
    plans: List[Optional[_SlicePlan]]           # per segment (None = empty)
    reads: List[Optional[Tuple[int, int]]]      # per segment i>0: ring window


def _backprop_segment(graph: Graph, ops: Sequence[Operator],
                      d_lo: int, d_hi: int) -> _SlicePlan:
    """Back-propagate an output row range through one segment's ops (the
    single-segment ``slice_plans`` inner loop, reused per cascade delta)."""
    out: Dict[str, Tuple[int, int]] = {}
    ins: Dict[str, List[Optional[Tuple[int, int, int, int]]]] = {}
    a, b = d_lo, d_hi
    for d in range(len(ops) - 1, -1, -1):
        op = ops[d]
        spec = spec_of(op)
        assert spec is not None
        out[op.name] = (a, b)
        sliced = _sliced_indices(op)
        row_plan: List[Optional[Tuple[int, int, int, int]]] = []
        for idx, inp in enumerate(op.inputs):
            if idx not in sliced:
                row_plan.append(None)
                continue
            h_in = _height(graph, inp)
            assert h_in is not None
            row_plan.append(in_rows(spec.kernel, spec.stride, h_in, a, b))
        ins[op.name] = row_plan
        if d > 0:
            ci = _chain_input_index(op, ops[d - 1].output)
            lo, hi, _, _ = row_plan[ci]  # type: ignore[misc]
            a, b = lo, hi
    return _SlicePlan(out, ins)


def _seg_need_hi(graph: Graph, ops: Sequence[Operator], ob: int) -> int:
    """Highest input row (exclusive) of the segment's chain input needed to
    produce output rows [*, ob) — the hi of the row-map composition."""
    b = ob
    for op in reversed(ops):
        spec = spec_of(op)
        assert spec is not None
        if spec.kernel == 1 and spec.stride == 1:
            continue                    # elementwise: hi passes through
        h_in = _height(graph, op.inputs[_sliced_indices(op)[0]])
        assert h_in is not None
        _, pad_beg, _ = same_pads(h_in, spec.kernel, spec.stride)
        b = min((b - 1) * spec.stride - pad_beg + spec.kernel, h_in)
    return b


def _backprop_cols(graph: Graph, members: Sequence[Operator],
                   ca: int, cb: int
                   ) -> Tuple[Dict[str, Tuple[int, int]],
                              Dict[str, List[Optional[Tuple[int, int,
                                                            int, int]]]]]:
    """Column twin of ``_backprop_segment`` over the whole member chain:
    compose the width-axis window maps backward from final-output columns
    [ca, cb).  Column windows are constant across row slices (the row and
    column maps are independent under SAME padding), so one pass per
    W-strip covers every iteration of the cascade.

    Returns (per-op output column window, per-op per-input column window
    ``(lo, hi, pad_left, pad_right)`` — ``None`` for whole inputs)."""
    out: Dict[str, Tuple[int, int]] = {}
    ins: Dict[str, List[Optional[Tuple[int, int, int, int]]]] = {}
    a, b = ca, cb
    for d in range(len(members) - 1, -1, -1):
        op = members[d]
        spec = spec_of(op)
        assert spec is not None
        out[op.name] = (a, b)
        sliced = _sliced_indices(op)
        col_plan: List[Optional[Tuple[int, int, int, int]]] = []
        for idx, inp in enumerate(op.inputs):
            if idx not in sliced:
                col_plan.append(None)
                continue
            w_in = _width(graph, inp)
            assert w_in is not None
            col_plan.append(in_rows(spec.kw, spec.sw, w_in, a, b))
        ins[op.name] = col_plan
        if d > 0:
            ci = _chain_input_index(op, members[d - 1].output)
            lo, hi, _, _ = col_plan[ci]  # type: ignore[misc]
            a, b = lo, hi
    return out, ins


def _strips_eligible(graph: Graph, members: Sequence[Operator],
                     strips: int) -> bool:
    """Whether the member chain supports ``strips`` W-strips: every tensor
    on the chain has a width axis, every windowed member's SAME width map
    is consistent (mirror of the height checks in ``_op_eligible``), and
    the final output is wide enough to split."""
    if strips < 2:
        return True
    w_final = _width(graph, members[-1].output)
    if w_final is None or w_final < strips:
        return False
    for op in members:
        spec = spec_of(op)
        assert spec is not None
        w_out = _width(graph, op.output)
        if w_out is None:
            return False
        sliced = _sliced_indices(op)
        if spec.kw > 1 or spec.sw > 1:
            if len(sliced) != 1:
                return False
            w_in = _width(graph, op.inputs[sliced[0]])
            if w_in is None or same_pads(w_in, spec.kw, spec.sw)[0] != w_out:
                return False
        else:
            for idx in sliced:
                if (idx >= len(op.inputs)
                        or _width(graph, op.inputs[idx]) != w_out):
                    return False
    return True


def _strip_bounds(w_final: int, strips: int) -> List[Tuple[int, int]]:
    """Final-output column ranges of the W-strips (same balanced split rule
    as the row-slice bounds in ``slice_plans``)."""
    bounds = [(j * w_final) // strips for j in range(strips + 1)]
    return [(bounds[j], bounds[j + 1]) for j in range(strips)]


def cascade_slice_plans(graph: Graph, segments: Sequence[List[Operator]],
                        k: int, min_rows: int = 1, rate_div: int = 1
                        ) -> Tuple[List[_CascadeSlice], List[int]]:
    """Forward streaming schedule of a cascade, plus the ring size (rows)
    of every boundary.

    Each iteration advances every segment (first to last) by at most its
    steady-state chunk — ``ceil(h_final / k)`` rows of the final output,
    scaled upstream by the consumer segments' stride product, floored at
    ``min_rows`` (deep low-resolution segments are cheap per row, so a
    bigger chunk there buys halo-recompute savings at almost no memory
    cost) — never past what its producer has already pushed into the
    ring, and never past what its consumer's next chunk demands (a
    backward demand pass per iteration; eager production would sit in the
    ring as pure lag).  Capping the
    chunk is what breaks the warm-up: the receptive field of the first
    output rows ramps up over several small iterations instead of being
    materialised in one fat step, so neither the rings nor the per-step
    working set ever hold a whole warm-up window.  The last segment
    finishes at the final iteration; early iterations may leave it (and
    any downstream segment) with an empty delta while upstream primes.

    A boundary's ring must hold, after iteration t, every row from the
    oldest one a future read still needs to the newest one pushed —
    ``ring_rows = max_t (pushed_hi - oldest_needed)``; rows are placed at
    ``row % ring_rows``, so a row is overwritten exactly when the ring has
    advanced a full revolution past it, by which time (monotone windows)
    no reader wants it."""
    m = len(segments)
    heights: List[int] = []
    for seg in segments:
        h = _height(graph, seg[-1].output)
        assert h is not None
        heights.append(h)
    h_final = heights[-1]
    assert 2 <= k <= h_final
    caps = list(_cascade_caps(graph, segments, k, min_rows, rate_div))
    prev = [0] * m                      # rows produced so far, per segment
    slices: List[_CascadeSlice] = []
    guard = m + 4 + sum(-(-heights[i] // caps[i]) for i in range(m))
    while prev[-1] < h_final and len(slices) < guard:
        # demand pass (backward): a producer must never run ahead of what
        # its consumer's next chunk will read — eager production would sit
        # in the ring as pure lag and inflate ring_rows past the
        # kernel+stride-carry window the cost model is built around
        demand = [0] * m
        demand[m - 1] = min(h_final, prev[m - 1] + caps[m - 1])
        for i in range(m - 2, -1, -1):
            demand[i] = min(heights[i],
                            max(prev[i],
                                _seg_need_hi(graph, segments[i + 1],
                                             demand[i + 1])))
        deltas: List[Tuple[int, int]] = [(0, 0)] * m
        plans: List[Optional[_SlicePlan]] = [None] * m
        reads: List[Optional[Tuple[int, int]]] = [None] * m
        for i in range(m):
            d_lo = prev[i]
            ob = min(d_lo + caps[i], demand[i])
            if i > 0:
                # never read past what the producer has pushed so far
                while ob > d_lo and _seg_need_hi(graph, segments[i],
                                                 ob) > prev[i - 1]:
                    ob -= 1
            if ob <= d_lo:
                deltas[i] = (d_lo, d_lo)
                continue
            deltas[i] = (d_lo, ob)
            plan = _backprop_segment(graph, segments[i], d_lo, ob)
            plans[i] = plan
            if i > 0:
                first = segments[i][0]
                ci = _chain_input_index(first, segments[i - 1][-1].output)
                lo, hi, _, _ = plan.ins[first.name][ci]  # type: ignore[misc]
                reads[i] = (lo, hi)
            prev[i] = ob
        slices.append(_CascadeSlice(deltas, plans, reads))
    assert prev[-1] == h_final, "cascade streaming failed to make progress"

    # ring sizing: occupancy after iteration t = pushed_hi - oldest row any
    # read at t' >= t still needs (window lows are monotone)
    ring_need = [0] * (m - 1)
    n = len(slices)
    for i in range(m - 1):
        hi_after = []
        h = 0
        for cs in slices:
            h = max(h, cs.deltas[i][1])
            hi_after.append(h)
        lo_next: List[Optional[int]] = [None] * n
        nxt: Optional[int] = None
        for t in range(n - 1, -1, -1):
            r = slices[t].reads[i + 1]
            if r is not None:
                nxt = r[0]
            lo_next[t] = nxt
        for t in range(n):
            if lo_next[t] is not None:
                ring_need[i] = max(ring_need[i],
                                   hi_after[t] - min(lo_next[t],
                                                     hi_after[t]))
    return slices, ring_need


def estimate_cascade(graph: Graph, segments: Sequence[List[Operator]],
                     k: int, min_rows: int = 1, rate_div: int = 1,
                     strips: int = 1) -> Tuple[int, float, List[int], int]:
    """(estimated peak bytes, halo-recompute MACs as a fraction of the
    cascade's own MACs — the planner's overhead-cap unit, ring rows,
    absolute halo-recompute MACs — the whole-graph reporting unit).

    Charges: every cascade-external input whole, each boundary at
    ``ring_rows * row_bytes`` (the streaming saving), the final output
    whole (the inplace concat accumulator), and the fattest per-slice
    step.  Boundary rows are produced exactly once — recompute happens
    only *inside* segments, so cascades also shrink the extra-MACs cost.

    With ``strips > 1`` the cascade runs once per W-strip: rings and
    working slices narrow to each strip's column windows (``tile_rows ×
    tile_cols × C`` working sets), the strips execute sequentially so the
    peak takes the max over strips, and the column halos show up as extra
    per-element work — MACs scale by retained-columns / full-width, which
    reduces exactly to the 1-D formula at ``strips == 1``."""
    slices, rings = cascade_slice_plans(graph, segments, k, min_rows,
                                        rate_div)
    members = [op for seg in segments for op in seg]
    ext_bytes = sum(graph.size(e) for e in _external_inputs(members))
    out_bytes = graph.size(segments[-1][-1].output)
    if strips == 1:
        ring_bytes = sum(r * _row_bytes(graph, seg[-1].output)
                         for r, seg in zip(rings, segments[:-1]))
        slice_live = 0
        rows_done: Dict[str, int] = {}
        for cs in slices:
            for i, seg in enumerate(segments):
                plan = cs.plans[i]
                if plan is None:
                    continue
                for op in seg:
                    oa, ob = plan.out[op.name]
                    step = (ob - oa) * _row_bytes(graph, op.output)
                    for idx, rp in enumerate(plan.ins[op.name]):
                        if rp is None:
                            continue
                        # boundary inputs: the ring itself is charged whole
                        # in ring_bytes; the read materialises the halo'd
                        # window once, same cost shape as an external
                        # extract
                        lo, hi, _, _ = rp
                        step += (hi - lo) * _row_bytes(graph, op.inputs[idx])
                    slice_live = max(slice_live, step)
                    rows_done[op.name] = rows_done.get(op.name, 0) + (ob - oa)
        base_macs = extra_macs = 0
        for op in members:
            h = _height(graph, op.output)
            assert h is not None
            base_macs += h * _macs_per_row(graph, op)
            extra = rows_done.get(op.name, 0) - h
            extra_macs += max(0, extra) * _macs_per_row(graph, op)
        frac = extra_macs / base_macs if base_macs else 0.0
        return (ext_bytes + ring_bytes + out_bytes + slice_live, frac, rings,
                extra_macs)

    assert _strips_eligible(graph, members, strips)
    w_final = _width(graph, members[-1].output)
    assert w_final is not None
    strip_peak = 0
    work: Dict[str, int] = {}        # per op: row-equivalents done (x W)
    for ca, cb in _strip_bounds(w_final, strips):
        cols_out, cols_ins = _backprop_cols(graph, members, ca, cb)
        ring_bytes = sum(
            r * _win_row_bytes(graph, seg[-1].output,
                               cols_out[seg[-1].name][:2])
            for r, seg in zip(rings, segments[:-1]))
        slice_live = 0
        for cs in slices:
            for i, seg in enumerate(segments):
                plan = cs.plans[i]
                if plan is None:
                    continue
                for op in seg:
                    oa, ob = plan.out[op.name]
                    oc = cols_out[op.name]
                    step = (ob - oa) * _win_row_bytes(graph, op.output,
                                                      oc[:2])
                    for idx, rp in enumerate(plan.ins[op.name]):
                        if rp is None:
                            continue
                        lo, hi, _, _ = rp
                        cc = cols_ins[op.name][idx]
                        assert cc is not None
                        step += (hi - lo) * _win_row_bytes(
                            graph, op.inputs[idx], cc[:2])
                    slice_live = max(slice_live, step)
                    w_op = _width(graph, op.output)
                    assert w_op is not None
                    # rows x retained columns, in per-full-row units x W
                    work[op.name] = (work.get(op.name, 0)
                                     + (ob - oa) * (oc[1] - oc[0]))
        strip_peak = max(strip_peak, ring_bytes + slice_live)
    base_macs = extra_macs = 0
    for op in members:
        h = _height(graph, op.output)
        w_op = _width(graph, op.output)
        assert h is not None and w_op is not None
        mpr = _macs_per_row(graph, op)
        base_macs += h * mpr
        extra_macs += max(0, work.get(op.name, 0) * mpr // w_op - h * mpr)
    frac = extra_macs / base_macs if base_macs else 0.0
    return (ext_bytes + out_bytes + strip_peak, frac, rings, extra_macs)


def _cut_candidates(graph: Graph, run: Sequence[Operator]) -> List[int]:
    """Positions p where ``run[:p]`` / ``run[p:]`` is a sensible boundary:
    after every op that shrinks the spatial height (stride > 1) — the
    boundary tensor is smallest right after a stride level."""
    cuts = []
    h_prev = _height(graph, run[0].inputs[_sliced_indices(run[0])[0]])
    for p, op in enumerate(run):
        h = _height(graph, op.output)
        if h is not None and h_prev is not None and h < h_prev \
                and 0 < p + 1 < len(run):
            cuts.append(p + 1)
        h_prev = h
    return cuts


def _cascade_caps(graph: Graph, segments: Sequence[List[Operator]],
                  k: int, min_rows: int, rate_div: int) -> Tuple[int, ...]:
    """The effective per-segment chunk caps a (k, min_rows, rate_div)
    triple resolves to: the stride-steady rate (``ceil(h_final/k)`` final
    rows, scaled upstream by consumer stride products) divided by
    ``rate_div`` (a slower pipeline: smaller chunks, smaller rings and
    working set, more iterations), floored at ``min_rows`` (deep
    low-resolution segments are cheap per row, so bigger chunks there cut
    halo recompute at almost no memory cost).  Single source of truth —
    ``cascade_slice_plans`` paces with these caps and the planner
    deduplicates estimate candidates on them (distinct triples often
    collapse to the same caps)."""
    heights = [_height(graph, seg[-1].output) for seg in segments]
    steady = [0] * len(segments)
    steady[-1] = -(-heights[-1] // k)       # type: ignore[operator]
    for i in range(len(segments) - 2, -1, -1):
        stride_prod = 1
        for op in segments[i + 1]:
            stride_prod *= spec_of(op).stride   # type: ignore[union-attr]
        steady[i] = max(1, steady[i + 1] * stride_prod)
    return tuple(min(h, max(-(-c // rate_div), min_rows))  # type: ignore
                 for c, h in zip(steady, heights))


def plan_cascade(graph: Graph, budget: Optional[int] = None,
                 max_k: int = 16, overhead_cap: float = 0.25,
                 k_choices: Sequence[int] = (2, 3, 4, 6, 8, 12, 16),
                 max_cuts: int = 8,
                 min_rows_choices: Sequence[int] = (1, 2, 4),
                 rate_div_choices: Sequence[int] = (1, 2, 4),
                 strips_choices: Sequence[int] = (1,)
                 ) -> List[Cascade]:
    """Choose, per sliceable run, the best (end, cut set, K, chunk floor,
    rate divisor, W-strip count) — ranked like ``_choose_in_run``: meeting
    the budget first, then estimated peak, halo overhead, K.

    ``strips_choices`` widens the search to 2-D tiled cascades: the whole
    cascade re-runs once per W-strip with rings and working sets narrowed
    to per-strip column windows (the MCUNetV2-style patch regime).  The
    default ``(1,)`` searches only row cascades and is byte-identical to
    the pre-2-D planner — the scheduler ladder escalates to strips > 1
    only when row rings alone miss the budget.

    The cascade may **end early** — at the boundary right after a stride
    level, where the feature map is small — leaving the run's tail to
    conventional scheduling: driving slices from the network's final
    low-resolution output would make slice 0's receptive field global and
    the rings as tall as the tensors they replace.  An early end's
    estimate is floored by the tail's fattest single step, so an end that
    merely shifts the peak into the tail cannot rank as a win.

    Cut sets searched: every subset of the stride-level candidates, plus
    suffixes of two structural families — a boundary right before every
    windowed op (each [windowed, 1x1...] block becomes a segment whose
    1x1 tail recomputes nothing) and all-singletons (every interior tensor
    retained in a kernel-sized ring: zero recompute, maximum rings).
    Suffixes merge the first ops into one head segment, which reads the
    (whole, already-charged) external input — recompute there trades
    against rings that would sit next to the fattest feature maps.  Only
    cascades with at least two segments qualify — a single segment is
    whole-externals Pex."""
    import itertools

    cascades: List[Cascade] = []
    for run in sliceable_runs(graph):
        if len(run) < 3:
            continue
        cuts_all = _cut_candidates(graph, run)[:max_cuts]
        if not cuts_all:
            continue
        windowed = tuple(
            p for p in range(1, len(run))
            if (spec_of(run[p]).kernel > 1          # type: ignore[union-attr]
                or spec_of(run[p]).stride > 1))     # type: ignore[union-attr]
        singles = tuple(range(1, len(run)))
        best: Optional[Tuple[Tuple, List[List[Operator]], int, int,
                             float, List[int], int, int]] = None
        for end in sorted(set(cuts_all) | {len(run)}):
            ops_e = run[:end]
            h_final = _height(graph, ops_e[-1].output)
            if h_final is None or h_final < 2 or len(ops_e) < 2:
                continue
            strips_e = [st for st in strips_choices
                        if st == 1 or _strips_eligible(graph, ops_e, st)]
            tail_floor = (_local_baseline(graph, run[end:])
                          if end < len(run) else 0)
            ends_cuts = [c for c in cuts_all if c < end]
            if not ends_cuts:
                continue
            cut_sets = [cuts for r in range(1, len(ends_cuts) + 1)
                        for cuts in itertools.combinations(ends_cuts, r)]
            for fam in (windowed, singles,
                        tuple(sorted(set(windowed) | set(ends_cuts)))):
                fam_e = tuple(c for c in fam if c < end)
                for j in range(min(4, len(fam_e))):
                    suffix = fam_e[j:]
                    if suffix and suffix not in cut_sets:
                        cut_sets.append(suffix)
            for cuts in cut_sets:
                segs = []
                lo = 0
                for c in list(cuts) + [end]:
                    segs.append(list(run[lo:c]))
                    lo = c
                seen_caps: set = set()
                for k in k_choices:
                    if k > min(max_k, h_final) or k < 2:
                        continue
                    for mr in min_rows_choices:
                        for rd in rate_div_choices:
                            caps = _cascade_caps(graph, segs, k, mr, rd)
                            for st in strips_e:
                                if (caps, st) in seen_caps:
                                    continue
                                seen_caps.add((caps, st))
                                est, frac, rings, extra = estimate_cascade(
                                    graph, segs, k, mr, rd, st)
                                if frac > overhead_cap:
                                    continue
                                est = max(est, tail_floor)
                                meets = budget is not None and est <= budget
                                key = (0 if meets else 1, est, frac, k,
                                       mr, rd, st)
                                if best is None or key < best[0]:
                                    best = (key, segs, k, est, frac, rings,
                                            mr, rd, extra, st)
        if best is not None:
            _, segs, k, est, frac, rings, mr, rd, extra, st = best
            cascades.append(Cascade(segs, k, rings, est, frac, mr, rd,
                                    extra, st))
    return cascades


# ----------------------------------------------------------- ring rewriting
def _ring_read_fn(lo: int, n: int, ring_rows: int) -> Callable[..., Any]:
    def fn(ring, lo=lo, n=n, ring_rows=ring_rows):
        ring = np.asarray(ring)
        return ring[(lo + np.arange(n)) % ring_rows]
    return fn


def _ring_push_fn(dst: int, ring_rows: int, first: bool) -> Callable[..., Any]:
    if first:
        def fn(part, dst=dst, ring_rows=ring_rows):
            part = np.asarray(part)
            ring = np.zeros((ring_rows,) + part.shape[1:], part.dtype)
            ring[(dst + np.arange(part.shape[0])) % ring_rows] = part
            return ring
    else:
        def fn(ring, part, dst=dst, ring_rows=ring_rows):
            out = np.array(np.asarray(ring))   # simulator copies; on-device
            part = np.asarray(part)            # this is a rolling in-place
            out[(dst + np.arange(part.shape[0])) % ring_rows] = part
            return out
    return fn


def _emit_cascade(old: Graph, new: Graph, casc: Cascade) -> None:
    segments, k, strips = casc.segments, casc.k, casc.strips
    m = len(segments)
    head = segments[0][0].name
    y = segments[-1][-1].output
    ty = old.tensors[y]
    members = casc.ops
    executable = all(op.fn is not None for op in members) and all(
        spec_of(op).make_fn is not None for op in members)  # type: ignore[union-attr]
    slices, _ = cascade_slice_plans(old, segments, k, casc.min_rows,
                                    casc.rate_div)

    # W-strips: one (cstart, column-window maps) triple per outer pass.
    # strips == 1 keeps cols == None everywhere, emitting byte-identical
    # names / attrs / sizes to the pre-2-D emitter (the degenerate path).
    if strips == 1:
        strip_iter: List[Tuple[Optional[int], Any, Any]] = [(None, None,
                                                             None)]
    else:
        w_final = _width(old, y)
        assert w_final is not None
        strip_iter = []
        for ca, cb in _strip_bounds(w_final, strips):
            cols_out, cols_ins = _backprop_cols(old, members, ca, cb)
            strip_iter.append((ca, cols_out, cols_ins))

    extracts: Dict[Tuple, str] = {}

    # Dedup is scoped PER STRIP (the key carries the strip tag): a window
    # shared across strips would stay live through the whole first strip —
    # co-resident with its rings and working set — which the cost model
    # never charges (``estimate_cascade`` prices windows only inside their
    # own strip).  Re-extracting per strip costs a copy and keeps the
    # estimate an upper bound.  strips == 1 has one tag, so the degenerate
    # path deduplicates (and names) exactly as the pre-2-D emitter.
    def extract(inp: str, lo: int, hi: int, phase: int,
                cols: Optional[Tuple[int, int]], seg_tag: str,
                sx: str) -> str:
        key = (inp, lo, hi, cols, sx)
        if key not in extracts:
            t_in = old.tensors[inp]
            attrs: Dict[str, Any] = {}
            if cols is None:
                tname = f"{inp}__cpex_{head}{sx}_{lo}_{hi}" if sx \
                    else f"{inp}__cpex_{head}_{lo}_{hi}"
                size = (hi - lo) * _row_bytes(old, inp)
                shape = ((hi - lo,) + tuple(t_in.shape[1:])
                         if t_in.shape else ())
                fn = _slice_fn(lo, hi) if executable else None
            else:
                clo, chi = cols
                tname = f"{inp}__cpex_{head}{sx}_{lo}_{hi}_{clo}_{chi}"
                size = (hi - lo) * _win_row_bytes(old, inp, cols)
                shape = (hi - lo, chi - clo) + tuple(t_in.shape[2:])
                fn = _slice_fn(lo, hi, clo, chi) if executable else None
                attrs["pex_cols"] = (clo, chi)
            new.add_tensor(tname, size, shape, t_in.dtype)
            new.add_operator(f"cpexsl__{head}_{len(extracts)}", [inp], tname,
                             kind="pex_slice", fn=fn,
                             pex_seg=seg_tag, pex_slice_idx=phase,
                             pex_rows=(lo, hi), **attrs)
            extracts[key] = tname
        return extracts[key]

    acc_prev: Optional[str] = None
    for j, (cstart, cols_out, cols_ins) in enumerate(strip_iter):
        sx = "" if strips == 1 else f"c{j}"
        seg_tag = head if strips == 1 else f"{head}@c{j}"
        ring_cur: List[Optional[str]] = [None] * (m - 1)
        for s, cs in enumerate(slices):
            # group index for the compiled executor's fori_loop rolling:
            # with a rate divisor the steady-state structure repeats every
            # rate_div iterations, so the super-period is one rollable group
            phase = s // casc.rate_div
            for i, seg in enumerate(segments):
                d_lo, d_hi = cs.deltas[i]
                if d_hi <= d_lo:
                    continue
                plan = cs.plans[i]
                assert plan is not None
                for d, op in enumerate(seg):
                    spec = spec_of(op)
                    assert spec is not None
                    oa, ob = plan.out[op.name]
                    oc = None if cols_out is None else cols_out[op.name]
                    pads = (0, 0)
                    wpads = (0, 0)
                    ins: List[str] = []
                    for idx, inp in enumerate(op.inputs):
                        rp = plan.ins[op.name][idx]
                        if rp is None:
                            ins.append(inp)            # consumed whole
                            continue
                        cc = (None if cols_ins is None
                              else cols_ins[op.name][idx])
                        lo, hi, top, bottom = rp
                        if top or bottom:
                            pads = (top, bottom)
                        if cc is not None and (cc[2] or cc[3]):
                            wpads = (cc[2], cc[3])
                        if d > 0 and inp == seg[d - 1].output:
                            ins.append(f"{inp}__cpex{s}{sx}")
                        elif (d == 0 and i > 0
                              and inp == segments[i - 1][-1].output):
                            # halo'd window out of the predecessor's ring
                            ring = ring_cur[i - 1]
                            assert ring is not None
                            ring_rows = casc.ring_rows[i - 1]
                            t_b = old.tensors[inp]
                            rname = f"{inp}__rw{s}{sx}"
                            if cc is None:
                                rbytes = (hi - lo) * _row_bytes(old, inp)
                                shape = ((hi - lo,) + tuple(t_b.shape[1:])
                                         if t_b.shape else ())
                            else:
                                rbytes = (hi - lo) * _win_row_bytes(
                                    old, inp, cc[:2])
                                shape = ((hi - lo, cc[1] - cc[0])
                                         + tuple(t_b.shape[2:]))
                            new.add_tensor(rname, rbytes, shape, t_b.dtype)
                            new.add_operator(
                                f"cpexrd__{head}_{i}_{s}{sx}", [ring], rname,
                                kind="pex_ring_read",
                                fn=(_ring_read_fn(lo, hi - lo, ring_rows)
                                    if executable else None),
                                pex_seg=seg_tag, pex_slice_idx=phase,
                                pex_ring_rows=ring_rows, pex_ring_src=lo)
                            ins.append(rname)
                        else:
                            ins.append(extract(inp, lo, hi, phase,
                                               None if cc is None
                                               else cc[:2], seg_tag, sx))
                    t_out = old.tensors[op.output]
                    oname = f"{op.output}__cpex{s}{sx}"
                    if oc is None:
                        obytes = (ob - oa) * _row_bytes(old, op.output)
                        shape = ((ob - oa,) + tuple(t_out.shape[1:])
                                 if t_out.shape else ())
                    else:
                        obytes = (ob - oa) * _win_row_bytes(old, op.output,
                                                            oc)
                        shape = ((ob - oa, oc[1] - oc[0])
                                 + tuple(t_out.shape[2:]))
                    new.add_tensor(oname, obytes, shape, t_out.dtype)
                    attrs = {a: v for a, v in op.attrs.items()
                             if a != PEX_ATTR}
                    attrs["pex_of"] = op.name
                    attrs["pex_seg"] = seg_tag
                    attrs["pex_slice_idx"] = phase
                    attrs["pex_pads"] = pads
                    if oc is None:
                        fn = (spec.make_fn(op, pads[0], pads[1])
                              if executable else None)  # type: ignore[misc]
                    else:
                        attrs["pex_wpads"] = wpads
                        fn = (spec.make_fn(op, pads[0], pads[1],  # type: ignore[call-arg]
                                           wpads[0], wpads[1])
                              if executable else None)  # type: ignore[misc]
                    new.add_operator(f"{op.name}__cpex{s}{sx}", ins, oname,
                                     kind=op.kind, fn=fn, **attrs)
                part = f"{seg[-1].output}__cpex{s}{sx}"
                if i < m - 1:
                    # rolling push of the delta rows into the boundary ring
                    boundary = seg[-1].output
                    bc = (None if cols_out is None
                          else cols_out[seg[-1].name])
                    ring_rows = casc.ring_rows[i]
                    t_b = old.tensors[boundary]
                    ring_name = f"{boundary}__ring{s}{sx}"
                    if bc is None:
                        rbytes = ring_rows * _row_bytes(old, boundary)
                        shape = ((ring_rows,) + tuple(t_b.shape[1:])
                                 if t_b.shape else ())
                    else:
                        rbytes = ring_rows * _win_row_bytes(old, boundary,
                                                            bc)
                        shape = ((ring_rows, bc[1] - bc[0])
                                 + tuple(t_b.shape[2:]))
                    new.add_tensor(ring_name, rbytes, shape, t_b.dtype)
                    first = ring_cur[i] is None
                    if first:
                        new.add_operator(
                            f"cpexpu__{head}_{i}_{s}{sx}", [part], ring_name,
                            kind="pex_ring_push",
                            fn=(_ring_push_fn(d_lo, ring_rows, True)
                                if executable else None),
                            pex_seg=seg_tag, pex_slice_idx=phase,
                            pex_ring_rows=ring_rows, pex_ring_dst=d_lo,
                            pex_first=True)
                    else:
                        new.add_operator(
                            f"cpexpu__{head}_{i}_{s}{sx}", [ring_cur[i],
                                                            part],
                            ring_name, kind="pex_ring_push",
                            fn=(_ring_push_fn(d_lo, ring_rows, False)
                                if executable else None),
                            inplace=True, inplace_input=ring_cur[i],
                            pex_seg=seg_tag, pex_slice_idx=phase,
                            pex_ring_rows=ring_rows, pex_ring_dst=d_lo,
                            pex_first=False)
                    ring_cur[i] = ring_name
                else:
                    start = d_lo
                    # the accumulator spans strips: only the very last
                    # delta of the very last strip completes the output
                    last = (s == len(slices) - 1
                            and j == len(strip_iter) - 1)
                    out_name = y if last else f"{y}__cpexacc{s}{sx}"
                    if not last:
                        new.add_tensor(out_name, ty.size, ty.shape,
                                       ty.dtype)
                    cat_attrs: Dict[str, Any] = {}
                    if cstart is not None:
                        cat_attrs["pex_cstart"] = cstart
                    if acc_prev is None:
                        new.add_operator(f"cpexcat__{head}_{s}{sx}", [part],
                                         out_name, kind="pex_concat",
                                         fn=(_concat_fn(start,
                                                        tuple(ty.shape),
                                                        True, cstart)
                                             if executable else None),
                                         pex_seg=seg_tag,
                                         pex_slice_idx=phase,
                                         pex_start=start, pex_first=True,
                                         **cat_attrs)
                    else:
                        new.add_operator(f"cpexcat__{head}_{s}{sx}",
                                         [acc_prev, part], out_name,
                                         kind="pex_concat",
                                         fn=(_concat_fn(start,
                                                        tuple(ty.shape),
                                                        False, cstart)
                                             if executable else None),
                                         inplace=True,
                                         inplace_input=acc_prev,
                                         pex_seg=seg_tag,
                                         pex_slice_idx=phase,
                                         pex_start=start, pex_first=False,
                                         **cat_attrs)
                    acc_prev = out_name


@dataclasses.dataclass
class CascadeResult:
    graph: Graph
    cascades: List[Cascade]
    total_macs: int = 0      # graph_macs of the ORIGINAL graph

    @property
    def extra_macs(self) -> int:
        """Absolute halo-recompute MACs over all cascades (disjoint ops)."""
        return sum(c.extra_macs for c in self.cascades)

    @property
    def extra_macs_frac(self) -> float:
        """Halo recompute overhead as a fraction of the whole graph's MACs
        (same whole-graph units as ``PartitionResult`` and the solver)."""
        return self.extra_macs / self.total_macs if self.total_macs else 0.0

    def __str__(self) -> str:
        return (f"cascade: {len(self.cascades)} cascades, "
                f"{sum(len(c.segments) for c in self.cascades)} segments, "
                f"halo overhead {self.extra_macs_frac:.1%} of graph MACs")


def apply_cascade(graph: Graph, cascades: Sequence[Cascade]) -> Graph:
    """Rewrite ``graph`` with every cascade streamed through ring buffers.
    Insertion order is the interleaved cascade execution order (slice 0
    through every segment, slice 1 through every segment, ...), so
    ``default_schedule`` of the result is already streaming-shaped."""
    heads = {c.segments[0][0].name: c for c in cascades}
    member = {op.name for c in cascades for op in c.ops}
    interior = {op.output for c in cascades for op in c.ops
                if op.output != c.segments[-1][-1].output}
    new = Graph()
    for name, t in graph.tensors.items():
        if name not in interior:
            new.add_tensor(name, t.size, t.shape, t.dtype)
    for op in graph.operators:
        if op.name in heads:
            _emit_cascade(graph, new, heads[op.name])
        elif op.name in member:
            continue
        else:
            new.add_operator(op.name, list(op.inputs), op.output,
                             kind=op.kind, fn=op.fn, **op.attrs)
    new.set_outputs(graph.outputs)
    return new


def cascade_graph(graph: Graph, budget: Optional[int] = None,
                  max_k: int = 16, overhead_cap: float = 0.25,
                  k_choices: Sequence[int] = (2, 3, 4, 6, 8, 12, 16),
                  strips_choices: Sequence[int] = (1,),
                  rf_redistribute: Optional[Tuple[str, str]] = None
                  ) -> CascadeResult:
    """One-stop cascaded-streaming transform: plan cut sets / K against
    ``budget`` and rewrite the graph.  Returns the input graph unchanged
    (``result.graph is graph``) when no run can cascade.

    ``rf_redistribute`` is the MCUNetV2-style planner option: an explicit
    ``(shrink_op, grow_op)`` pair handed to
    ``graphs.cnn_ops.redistribute_receptive_field`` before planning —
    kernel reach moves from the early (halo-expensive) op to a later one,
    so 2-D tiling's per-axis halo bill shrinks while total network reach
    is conserved.  The shrink leg is a flagged model edit (see the
    transform's docstring), which is why it is opt-in per op pair and
    never chosen silently by the planner."""
    if rf_redistribute is not None:
        from repro.graphs.cnn_ops import redistribute_receptive_field
        graph = redistribute_receptive_field(graph, *rf_redistribute)
    cascades = plan_cascade(graph, budget, max_k, overhead_cap, k_choices,
                            strips_choices=strips_choices)
    if not cascades:
        return CascadeResult(graph, [], graph_macs(graph))
    return CascadeResult(apply_cascade(graph, cascades), cascades,
                         graph_macs(graph))
