"""Partial execution (Pex-style) graph transform.

The paper (Liberis & Lane 2019) reorders whole operators; its sequel — *Pex:
Memory-efficient Microcontroller Deep Learning through Partial Execution*
(Liberis & Lane 2022) — goes further: an operator chain is split into K
spatial slices so that only a fraction of its interior tensors is ever live.
This module implements that transform over the reordering ``Graph`` IR:

* **Eligibility** is declared per-operator through a ``SliceSpec`` attached in
  ``Operator.attrs`` (see ``graphs/cnn_ops.py`` for the CNN classification:
  elementwise ops, depthwise/regular convolutions and spatial pooling are
  sliceable; global pooling, FC and concat are not).  A spec carries the
  row-map of the op — kernel/stride under TF-style SAME padding — which is
  everything needed to push an output row range back to the input rows it
  reads (including the halo that neighbouring slices recompute).

* **Segments** are contiguous runs of sliceable operators inside the maximal
  linear chains of the graph.  Splitting a single operator cannot save
  memory (its input and output buffers must coexist regardless); splitting a
  chain means the fat *interior* tensors only ever exist one slice at a time.

* **The rewrite** replaces a segment with, per slice ``s``:
  ``pex_slice`` extract operators (halo-aware row windows of the segment's
  external inputs), per-slice clones of the member operators (explicit
  padding replaces SAME so numerics are bit-identical), and an incremental
  ``pex_concat`` that writes the slice into the full output buffer.  The
  concat chain is marked ``inplace`` — each link dies as the next is written
  — so the memory model (``Graph.live_sets``), the arena planner and the
  micro-interpreter all charge the output buffer exactly once.  This mirrors
  Pex's "operators write into a shared buffer" execution.

* **The cost model** (``plan_partition``) picks per-segment boundaries and K
  to hit a target arena budget, subject to a cap on the extra MACs spent
  recomputing halo rows — the Pex latency/memory trade-off.

The transform never changes results: a partitioned graph evaluates
bit-identically to the original (property-tested through the
micro-interpreter).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import Graph, Operator, linear_chains

# Attribute key under which builders attach a SliceSpec to eligible ops.
PEX_ATTR = "pex_slice_spec"


@dataclasses.dataclass(frozen=True)
class SliceSpec:
    """Row-map of a spatially-sliceable operator (TF SAME padding semantics).

    ``kernel``/``stride`` describe how output rows map to input rows along
    the leading (height) axis.  ``sliced_inputs`` lists the input positions
    that follow the row map (``None`` = all of them — elementwise); inputs
    not listed are consumed whole by every slice.  ``make_fn(op, pad_top,
    pad_bottom)`` builds the executable for a clone whose input slice needs
    explicit edge padding; ``None`` leaves clones without semantics
    (scheduling-only graphs).  ``macs_per_row`` feeds the halo-recompute
    overhead model.
    """

    kernel: int = 1
    stride: int = 1
    sliced_inputs: Optional[Tuple[int, ...]] = None
    make_fn: Optional[Callable[[Operator, int, int], Callable[..., Any]]] = None
    macs_per_row: int = 0


def spec_of(op: Operator) -> Optional[SliceSpec]:
    return op.attrs.get(PEX_ATTR)


# ------------------------------------------------------------------ row maps
def same_pads(h_in: int, kernel: int, stride: int) -> Tuple[int, int, int]:
    """(out_rows, pad_begin, pad_end) of TF-style SAME padding."""
    out = -(-h_in // stride)
    total = max((out - 1) * stride + kernel - h_in, 0)
    return out, total // 2, total - total // 2


def in_rows(kernel: int, stride: int, h_in: int, oa: int, ob: int
            ) -> Tuple[int, int, int, int]:
    """Input rows [lo, hi) and explicit pads (top, bottom) needed to produce
    output rows [oa, ob) of a SAME-padded windowed op."""
    _, pad_beg, _ = same_pads(h_in, kernel, stride)
    lo = oa * stride - pad_beg
    hi = (ob - 1) * stride - pad_beg + kernel
    top, bottom = max(0, -lo), max(0, hi - h_in)
    return max(lo, 0), min(hi, h_in), top, bottom


def _height(graph: Graph, tensor: str) -> Optional[int]:
    t = graph.tensors[tensor]
    if not t.shape:
        return None
    h = int(t.shape[0])
    if h < 1 or t.size % h != 0:
        return None
    return h


def _chain_input_index(op: Operator, pred_output: str) -> int:
    return op.inputs.index(pred_output)


def _op_eligible(graph: Graph, op: Operator) -> bool:
    spec = spec_of(op)
    if spec is None:
        return False
    h_out = _height(graph, op.output)
    if h_out is None or h_out < 2:
        return False
    sliced = (spec.sliced_inputs if spec.sliced_inputs is not None
              else tuple(range(len(op.inputs))))
    if not sliced:
        return False
    if spec.kernel > 1 or spec.stride > 1:
        # windowed ops: exactly one halo'd input whose SAME output height
        # matches the recorded output height
        if len(sliced) != 1:
            return False
        h_in = _height(graph, op.inputs[sliced[0]])
        if h_in is None or same_pads(h_in, spec.kernel, spec.stride)[0] != h_out:
            return False
    else:
        # elementwise family: every sliced input must share the output height
        for idx in sliced:
            if idx >= len(op.inputs) or _height(graph, op.inputs[idx]) != h_out:
                return False
    return True


def _sliced_indices(op: Operator) -> Tuple[int, ...]:
    spec = spec_of(op)
    assert spec is not None
    return (spec.sliced_inputs if spec.sliced_inputs is not None
            else tuple(range(len(op.inputs))))


def sliceable_runs(graph: Graph) -> List[List[Operator]]:
    """Contiguous runs (length >= 2) of sliceable ops within the maximal
    linear chains of the graph, where every chain link enters its consumer
    through a sliced input position."""
    runs: List[List[Operator]] = []
    for chain in linear_chains(graph):
        cur: List[Operator] = []
        for op in chain:
            links = (not cur or
                     _chain_input_index(op, cur[-1].output) in
                     (_sliced_indices(op) if spec_of(op) else ()))
            if _op_eligible(graph, op) and links:
                cur.append(op)
            else:
                if len(cur) >= 2:
                    runs.append(cur)
                cur = [op] if _op_eligible(graph, op) else []
        if len(cur) >= 2:
            runs.append(cur)
    return runs


# --------------------------------------------------------------- slice plans
@dataclasses.dataclass
class _SlicePlan:
    # per op name: output row range (oa, ob)
    out: Dict[str, Tuple[int, int]]
    # per op name: per input index -> (lo, hi, pad_top, pad_bottom) for
    # sliced inputs, None for whole inputs
    ins: Dict[str, List[Optional[Tuple[int, int, int, int]]]]


def slice_plans(graph: Graph, ops: Sequence[Operator], k: int
                ) -> List[_SlicePlan]:
    """Back-propagate output row ranges of the K slices through the segment.
    Slice ``s`` of the final output covers rows [s*H//K, (s+1)*H//K)."""
    h_final = _height(graph, ops[-1].output)
    assert h_final is not None and 2 <= k <= h_final
    bounds = [(s * h_final) // k for s in range(k + 1)]
    plans: List[_SlicePlan] = []
    for s in range(k):
        out: Dict[str, Tuple[int, int]] = {}
        ins: Dict[str, List[Optional[Tuple[int, int, int, int]]]] = {}
        oa, ob = bounds[s], bounds[s + 1]
        for d in range(len(ops) - 1, -1, -1):
            op = ops[d]
            spec = spec_of(op)
            assert spec is not None
            out[op.name] = (oa, ob)
            sliced = _sliced_indices(op)
            row_plan: List[Optional[Tuple[int, int, int, int]]] = []
            for idx, inp in enumerate(op.inputs):
                if idx not in sliced:
                    row_plan.append(None)
                    continue
                h_in = _height(graph, inp)
                assert h_in is not None
                row_plan.append(in_rows(spec.kernel, spec.stride, h_in,
                                        oa, ob))
            ins[op.name] = row_plan
            if d > 0:
                ci = _chain_input_index(op, ops[d - 1].output)
                lo, hi, _, _ = row_plan[ci]  # type: ignore[misc]
                oa, ob = lo, hi
        plans.append(_SlicePlan(out, ins))
    return plans


# ----------------------------------------------------------------- cost model
@dataclasses.dataclass
class Segment:
    ops: List[Operator]
    k: int
    est_peak: int            # local estimate: externals + output + slice live
    extra_macs_frac: float   # halo recompute cost relative to segment MACs


def _row_bytes(graph: Graph, tensor: str) -> int:
    h = _height(graph, tensor)
    assert h is not None
    return graph.size(tensor) // h


def _macs_per_row(graph: Graph, op: Operator) -> int:
    spec = spec_of(op)
    if spec is not None and spec.macs_per_row > 0:
        return spec.macs_per_row
    return max(1, _row_bytes(graph, op.output))


def _external_inputs(ops: Sequence[Operator]) -> List[str]:
    internal = {op.output for op in ops}
    exts: List[str] = []
    for op in ops:
        for i in op.inputs:
            if i not in internal and i not in exts:
                exts.append(i)
    return exts


def estimate_segment(graph: Graph, ops: Sequence[Operator], k: int
                     ) -> Tuple[int, float]:
    """(estimated peak bytes while the partitioned segment runs, halo
    overhead as a fraction of the segment's MACs).

    The estimate charges: every external input whole (slices are extracted
    from it, so it lives until the last slice), the full output buffer (the
    inplace concat accumulator), and the fattest per-slice step
    (inputs + output of one clone).  Co-live tensors from elsewhere in the
    graph are not the segment's to know — callers verify the true peak by
    scheduling the rewritten graph.
    """
    plans = slice_plans(graph, ops, k)
    ext_bytes = sum(graph.size(e) for e in _external_inputs(ops))
    out_bytes = graph.size(ops[-1].output)
    slice_live = 0
    base_macs = extra_macs = 0
    rows_done: Dict[str, int] = {}
    for op in ops:
        base_macs += _height(graph, op.output) * _macs_per_row(graph, op)
    for plan in plans:
        for d, op in enumerate(ops):
            oa, ob = plan.out[op.name]
            step = (ob - oa) * _row_bytes(graph, op.output)
            for idx, rp in enumerate(plan.ins[op.name]):
                if rp is not None:
                    lo, hi, _, _ = rp
                    step += (hi - lo) * _row_bytes(graph, op.inputs[idx])
            slice_live = max(slice_live, step)
            rows_done[op.name] = rows_done.get(op.name, 0) + (ob - oa)
    for op in ops:
        extra = rows_done[op.name] - _height(graph, op.output)
        extra_macs += max(0, extra) * _macs_per_row(graph, op)
    frac = extra_macs / base_macs if base_macs else 0.0
    return ext_bytes + out_bytes + slice_live, frac


def _local_baseline(graph: Graph, ops: Sequence[Operator]) -> int:
    """Unpartitioned local peak proxy: fattest single step of the run."""
    return max(graph.size(op.output) + sum(graph.size(i) for i in op.inputs)
               for op in ops)


def _choose_in_run(graph: Graph, run: List[Operator],
                   budget: Optional[int], max_k: int, overhead_cap: float,
                   k_choices: Sequence[int]) -> List[Segment]:
    """Best (sub-segment, K) of a sliceable run, then recurse on what is left
    to the segment's sides (a long chain may need several segments)."""
    if len(run) < 2:
        return []
    best: Optional[Tuple[Tuple, int, int, int, float]] = None
    baseline = _local_baseline(graph, run)
    for i in range(len(run)):
        for j in range(i + 1, len(run)):
            ops = run[i:j + 1]
            h_final = _height(graph, ops[-1].output)
            floor = (sum(graph.size(e) for e in _external_inputs(ops))
                     + graph.size(ops[-1].output))
            if floor >= baseline and (budget is None or floor >= budget):
                continue            # cannot beat the unsplit run
            for k in k_choices:
                if k > min(max_k, h_final):
                    continue
                est, frac = estimate_segment(graph, ops, k)
                if frac > overhead_cap or est >= baseline:
                    continue
                meets = budget is not None and est <= budget
                # rank: meeting the budget first, then lowest estimated
                # peak, then cheapest halo recompute, then smallest K
                key = (0 if meets else 1, est, frac, k)
                if best is None or key < best[0]:
                    best = (key, i, j, k, frac)
    if best is None:
        return []
    _, i, j, k, frac = best
    ops = run[i:j + 1]
    est, frac = estimate_segment(graph, ops, k)
    segs = [Segment(list(ops), k, est, frac)]
    segs += _choose_in_run(graph, run[:i], budget, max_k, overhead_cap,
                           k_choices)
    segs += _choose_in_run(graph, run[j + 1:], budget, max_k, overhead_cap,
                           k_choices)
    return segs


def plan_partition(graph: Graph, budget: Optional[int] = None,
                   max_k: int = 16, overhead_cap: float = 0.5,
                   k_choices: Sequence[int] = (2, 3, 4, 6, 8, 12, 16)
                   ) -> List[Segment]:
    segs: List[Segment] = []
    for run in sliceable_runs(graph):
        segs.extend(_choose_in_run(graph, run, budget, max_k, overhead_cap,
                                   k_choices))
    return segs


# -------------------------------------------------------------------- rewrite
def _slice_fn(lo: int, hi: int) -> Callable[..., Any]:
    def fn(a, lo=lo, hi=hi):
        return np.asarray(a)[lo:hi]
    return fn


def _concat_fn(start: int, shape: Tuple[int, ...], first: bool
               ) -> Callable[..., Any]:
    if first:
        def fn(part, start=start, shape=shape):
            part = np.asarray(part)
            acc = np.zeros(shape, part.dtype)
            acc[start:start + part.shape[0]] = part
            return acc
    else:
        def fn(acc, part, start=start):
            part = np.asarray(part)
            out = np.array(acc)        # the simulator copies; on-device this
            out[start:start + part.shape[0]] = part   # writes in place
            return out
    return fn


def _emit_segment(old: Graph, new: Graph, seg: Segment) -> None:
    ops, k = seg.ops, seg.k
    head = ops[0].name
    y = ops[-1].output
    ty = old.tensors[y]
    executable = all(op.fn is not None for op in ops) and all(
        spec_of(op).make_fn is not None for op in ops)  # type: ignore[union-attr]
    plans = slice_plans(old, ops, k)
    bounds = [plan.out[ops[-1].name] for plan in plans]
    extracts: Dict[Tuple[str, int, int], str] = {}
    acc_prev: Optional[str] = None

    # Every emitted op carries structured metadata (segment head, slice
    # index, row windows) so backends that cannot call the numpy closures —
    # the compiled arena executor lowers pex_slice/pex_concat to
    # lax.dynamic_slice/dynamic_update_slice and rolls uniform slices into a
    # fori_loop — can reconstruct the computation from attrs alone.
    def extract(inp: str, lo: int, hi: int, s: int) -> str:
        key = (inp, lo, hi)
        if key not in extracts:
            t_in = old.tensors[inp]
            tname = f"{inp}__pex_{head}_{lo}_{hi}"
            shape = (hi - lo,) + tuple(t_in.shape[1:]) if t_in.shape else ()
            new.add_tensor(tname, (hi - lo) * _row_bytes(old, inp), shape,
                           t_in.dtype)
            new.add_operator(f"pexsl__{head}_{len(extracts)}", [inp], tname,
                             kind="pex_slice",
                             fn=_slice_fn(lo, hi) if executable else None,
                             pex_seg=head, pex_slice_idx=s, pex_rows=(lo, hi))
            extracts[key] = tname
        return extracts[key]

    for s in range(k):
        plan = plans[s]
        for d, op in enumerate(ops):
            spec = spec_of(op)
            assert spec is not None
            oa, ob = plan.out[op.name]
            pads = (0, 0)
            ins: List[str] = []
            for idx, inp in enumerate(op.inputs):
                rp = plan.ins[op.name][idx]
                if rp is None:
                    ins.append(inp)               # consumed whole
                    continue
                lo, hi, top, bottom = rp
                if top or bottom:
                    pads = (top, bottom)
                if d > 0 and inp == ops[d - 1].output:
                    ins.append(f"{inp}__pex{s}")
                else:
                    ins.append(extract(inp, lo, hi, s))
            t_out = old.tensors[op.output]
            oname = f"{op.output}__pex{s}"
            shape = ((ob - oa,) + tuple(t_out.shape[1:])
                     if t_out.shape else ())
            new.add_tensor(oname, (ob - oa) * _row_bytes(old, op.output),
                           shape, t_out.dtype)
            attrs = {a: v for a, v in op.attrs.items() if a != PEX_ATTR}
            attrs["pex_of"] = op.name
            attrs["pex_seg"] = head
            attrs["pex_slice_idx"] = s
            attrs["pex_pads"] = pads
            fn = (spec.make_fn(op, pads[0], pads[1])
                  if executable else None)   # type: ignore[misc]
            new.add_operator(f"{op.name}__pex{s}", ins, oname, kind=op.kind,
                             fn=fn, **attrs)
        # incremental concat: write this slice into the shared output buffer
        part = f"{y}__pex{s}"
        start = bounds[s][0]
        out_name = y if s == k - 1 else f"{y}__pexacc{s}"
        if s < k - 1:
            new.add_tensor(out_name, ty.size, ty.shape, ty.dtype)
        if s == 0:
            new.add_operator(f"pexcat__{head}_0", [part], out_name,
                             kind="pex_concat",
                             fn=(_concat_fn(start, tuple(ty.shape), True)
                                 if executable else None),
                             pex_seg=head, pex_slice_idx=s, pex_start=start,
                             pex_first=True)
        else:
            new.add_operator(f"pexcat__{head}_{s}", [acc_prev, part],
                             out_name, kind="pex_concat",
                             fn=(_concat_fn(start, tuple(ty.shape), False)
                                 if executable else None),
                             inplace=True, inplace_input=acc_prev,
                             pex_seg=head, pex_slice_idx=s, pex_start=start,
                             pex_first=False)
        acc_prev = out_name


@dataclasses.dataclass
class PartitionResult:
    graph: Graph
    segments: List[Segment]

    @property
    def n_slices(self) -> int:
        return sum(s.k for s in self.segments)

    @property
    def extra_macs_frac(self) -> float:
        """Halo recompute overhead, worst segment (the Pex latency cost)."""
        return max((s.extra_macs_frac for s in self.segments), default=0.0)

    def __str__(self) -> str:
        return (f"pex: {len(self.segments)} segments, "
                f"{self.n_slices} slices, halo overhead "
                f"<= {self.extra_macs_frac:.1%}")


def apply_partition(graph: Graph, segments: Sequence[Segment]) -> Graph:
    """Rewrite ``graph`` with every segment split into its K slices.  The
    rewritten graph's insertion order is the Pex execution order (slice 0's
    chain, its concat, slice 1's chain, ...), so ``default_schedule`` of the
    result is already partial-execution-shaped; schedulers may still improve
    on it."""
    heads = {seg.ops[0].name: seg for seg in segments}
    member = {op.name for seg in segments for op in seg.ops}
    interior = {op.output for seg in segments for op in seg.ops[:-1]}
    new = Graph()
    for name, t in graph.tensors.items():
        if name not in interior:
            new.add_tensor(name, t.size, t.shape, t.dtype)
    for op in graph.operators:
        if op.name in heads:
            _emit_segment(graph, new, heads[op.name])
        elif op.name in member:
            continue
        else:
            new.add_operator(op.name, list(op.inputs), op.output,
                             kind=op.kind, fn=op.fn, **op.attrs)
    new.set_outputs(graph.outputs)
    return new


def partition_graph(graph: Graph, budget: Optional[int] = None,
                    max_k: int = 16, overhead_cap: float = 0.5,
                    k_choices: Sequence[int] = (2, 3, 4, 6, 8, 12, 16)
                    ) -> PartitionResult:
    """One-stop transform: plan segments/K against ``budget`` (None = just
    minimise the estimated peak) and rewrite the graph.  Returns the input
    graph unchanged (``result.graph is graph``) when nothing is eligible."""
    segments = plan_partition(graph, budget, max_k, overhead_cap, k_choices)
    if not segments:
        return PartitionResult(graph, [])
    return PartitionResult(apply_partition(graph, segments), segments)
