# The paper's primary contribution: peak-memory-minimal operator scheduling
# (Liberis & Lane 2019) plus the runtime substrates built around it.
from .graph import Graph, Operator, Tensor, linear_chains
from .scheduler import ScheduleResult, minimise_peak_memory
from .heuristics import (beam_schedule, build_chains, greedy_schedule,
                         minimise_peak_memory_contracted, schedule)
from .allocator import (ArenaPlan, ArenaPlanner, DynamicAllocator, Placement,
                        inplace_alias_groups, static_plan_size,
                        tensor_lifetimes)
from .partition import (PEX_ATTR, Cascade, CascadeResult, PartitionResult,
                        Segment, SliceSpec, apply_cascade, apply_partition,
                        cascade_graph, partition_graph, plan_cascade,
                        plan_partition, sliceable_runs)
from .solver import (ParetoPoint, SolverResult, branch_and_bound_order,
                     enumerate_pex_configs, graph_macs, pareto_front,
                     segment_extra_macs, solve)
from . import profile

__all__ = [
    "Graph", "Operator", "Tensor", "linear_chains",
    "ScheduleResult", "minimise_peak_memory",
    "beam_schedule", "build_chains", "greedy_schedule",
    "minimise_peak_memory_contracted", "schedule",
    "ArenaPlan", "ArenaPlanner", "DynamicAllocator", "Placement",
    "inplace_alias_groups", "static_plan_size", "tensor_lifetimes",
    "PEX_ATTR", "Cascade", "CascadeResult", "PartitionResult", "Segment",
    "SliceSpec", "apply_cascade", "apply_partition", "cascade_graph",
    "partition_graph", "plan_cascade", "plan_partition",
    "sliceable_runs", "profile",
    "ParetoPoint", "SolverResult", "branch_and_bound_order",
    "enumerate_pex_configs", "graph_macs", "pareto_front",
    "segment_extra_macs", "solve",
]
