"""Computation-graph IR for operator-reordering memory optimisation.

This is the data model of Liberis & Lane (2019): a DAG of operators over
tensors.  A *working set* at a point in an execution schedule is the set of
tensors that must be resident simultaneously: the pending operator's inputs
and output, plus any already-produced tensors still needed by later operators.
Constants (tensors with no producer) are counted unconditionally, matching the
paper's Algorithm 1 (they "just contribute to memory usage").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

# Byte width per supported element type.  The planner, allocators and the
# compiled executor are byte-granular: every ``Tensor.size`` is
# ``elements * itemsize(dtype)`` bytes, and arena offsets are byte offsets
# aligned to at least the tensor's itemsize (an MCU cannot dereference a
# misaligned ``float*``).  ``int8`` is the historical default so
# scheduling-only graphs with abstract byte sizes stay coherent
# (1 byte == 1 element).
DTYPE_ITEMSIZE: Dict[str, int] = {
    # no "bool": XLA cannot bitcast bytes to i1, so the compiled arena
    # executor could never honour it — masks model as uint8
    "int8": 1, "uint8": 1,
    "int16": 2, "float16": 2, "bfloat16": 2,
    "int32": 4, "float32": 4,
}


def dtype_itemsize(dtype: str) -> int:
    try:
        return DTYPE_ITEMSIZE[dtype]
    except KeyError:
        raise ValueError(f"unsupported tensor dtype {dtype!r}; "
                         f"known: {sorted(DTYPE_ITEMSIZE)}") from None


@dataclasses.dataclass(frozen=True)
class Tensor:
    """A tensor in the graph. ``size`` is in **bytes**
    (= ``elements * itemsize(dtype)``)."""

    name: str
    size: int
    shape: Tuple[int, ...] = ()
    dtype: str = "int8"

    @property
    def itemsize(self) -> int:
        return dtype_itemsize(self.dtype)

    @property
    def elements(self) -> int:
        if self.size % self.itemsize:
            raise ValueError(
                f"tensor {self.name!r}: {self.size} bytes is not a multiple "
                f"of {self.dtype} itemsize {self.itemsize}")
        return self.size // self.itemsize

    def __repr__(self) -> str:  # keep trace output compact
        return f"T({self.name}:{self.size})"


@dataclasses.dataclass
class Operator:
    """An operator consuming ``inputs`` and producing a single ``output``.

    ``fn`` optionally carries executable semantics (used by the
    micro-interpreter simulator); scheduling never calls it.
    """

    name: str
    inputs: List[str]
    output: str
    kind: str = "op"
    fn: Optional[Callable[..., Any]] = None
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)


def inplace_candidates(op: "Operator") -> List[str]:
    """Inputs an ``inplace`` operator may overwrite: the one named by
    ``attrs["inplace_input"]`` when present (ops like dynamic_update_slice
    can only write into a specific operand), every input otherwise."""
    target = op.attrs.get("inplace_input")
    if target is not None:
        return [i for i in op.inputs if i == target]
    return op.inputs


class Graph:
    """A computation DAG. Tensors are identified by name; each non-constant
    tensor has exactly one producer (single-output operators, as in TFLite)."""

    def __init__(self) -> None:
        self.tensors: Dict[str, Tensor] = {}
        self.operators: List[Operator] = []
        self._producer: Dict[str, Operator] = {}
        self._consumers: Dict[str, List[Operator]] = {}
        self.outputs: List[str] = []

    # ------------------------------------------------------------------ build
    def add_tensor(self, name: str, size: int, shape: Tuple[int, ...] = (),
                   dtype: str = "int8") -> Tensor:
        if name in self.tensors:
            raise ValueError(f"duplicate tensor {name!r}")
        t = Tensor(name, int(size), tuple(shape), dtype)
        t.elements     # validates size % itemsize == 0 and a known dtype
        self.tensors[name] = t
        self._consumers.setdefault(name, [])
        return t

    def add_operator(self, name: str, inputs: Sequence[str], output: str,
                     kind: str = "op", fn: Optional[Callable[..., Any]] = None,
                     **attrs: Any) -> Operator:
        for i in inputs:
            if i not in self.tensors:
                raise ValueError(f"operator {name!r}: unknown input {i!r}")
        if output not in self.tensors:
            raise ValueError(f"operator {name!r}: unknown output {output!r}")
        if output in self._producer:
            raise ValueError(f"tensor {output!r} already has a producer")
        op = Operator(name, list(inputs), output, kind, fn, dict(attrs))
        self.operators.append(op)
        self._producer[output] = op
        for i in inputs:
            self._consumers[i].append(op)
        return op

    def set_outputs(self, names: Sequence[str]) -> None:
        for n in names:
            if n not in self.tensors:
                raise ValueError(f"unknown output tensor {n!r}")
        self.outputs = list(names)

    # ------------------------------------------------------------------ query
    def producer(self, tensor: str) -> Optional[Operator]:
        return self._producer.get(tensor)

    def consumers(self, tensor: str) -> List[Operator]:
        return self._consumers.get(tensor, [])

    def constants(self) -> List[str]:
        """Tensors with no producer: graph inputs and weights."""
        return [n for n in self.tensors if n not in self._producer]

    def size(self, tensor: str) -> int:
        return self.tensors[tensor].size

    def itemsize(self, tensor: str) -> int:
        return self.tensors[tensor].itemsize

    def elements(self, tensor: str) -> int:
        return self.tensors[tensor].elements

    def max_itemsize(self) -> int:
        """Widest element type in the graph — the natural arena alignment
        for mixed-dtype plans (see ``ArenaPlanner``)."""
        return max((t.itemsize for t in self.tensors.values()), default=1)

    def op_by_name(self, name: str) -> Operator:
        for op in self.operators:
            if op.name == name:
                return op
        raise KeyError(name)

    # Transitive predecessor relation over *operators*, via produced tensors.
    def predecessors_of_tensor(self, tensor: str) -> FrozenSet[str]:
        """All tensors that must be produced before ``tensor`` (transitively),
        excluding constants. Cached."""
        cache = getattr(self, "_pred_cache", None)
        if cache is None:
            cache = self._pred_cache = {}
        if tensor in cache:
            return cache[tensor]
        op = self._producer.get(tensor)
        if op is None:
            result: FrozenSet[str] = frozenset()
        else:
            acc: Set[str] = set()
            for i in op.inputs:
                if i in self._producer:
                    acc.add(i)
                    acc.update(self.predecessors_of_tensor(i))
            result = frozenset(acc)
        cache[tensor] = result
        return result

    # --------------------------------------------------------------- validity
    def is_valid_schedule(self, schedule: Sequence[Operator]) -> bool:
        """A valid schedule executes every operator exactly once, in an order
        where each operator's inputs are constants or already produced."""
        if len(schedule) != len(self.operators) or set(id(o) for o in schedule) != set(
            id(o) for o in self.operators
        ):
            return False
        produced: Set[str] = set()
        for op in schedule:
            for i in op.inputs:
                if i in self._producer and i not in produced:
                    return False
            produced.add(op.output)
        return True

    def default_schedule(self) -> List[Operator]:
        """The order operators were added in (must already be topological —
        mirrors the schedule embedded in a serialized model)."""
        if not self.is_valid_schedule(self.operators):
            raise ValueError("insertion order is not topological")
        return list(self.operators)

    # ----------------------------------------------------------- memory model
    def live_sets(self, schedule: Sequence[Operator],
                  include_constants: bool = True) -> List[FrozenSet[str]]:
        """Working set at each step of ``schedule``.

        At step t (executing op), live = op.inputs ∪ {op.output} ∪ tensors
        already produced that a *later* op (or a graph output) still needs.
        Constants are included when ``include_constants`` (the paper counts
        them; Figure-1 accounting includes the network input tensor while it
        has pending consumers).
        """
        n = len(schedule)
        # Last step at which each tensor is used as an input; graph outputs
        # are pinned to the end.
        last_use: Dict[str, int] = {}
        for t, op in enumerate(schedule):
            for i in op.inputs:
                last_use[i] = t
        for o in self.outputs:
            last_use[o] = n  # never freed
        sets: List[FrozenSet[str]] = []
        produced: Set[str] = set()
        for t, op in enumerate(schedule):
            live: Set[str] = set()
            for i in op.inputs:
                if include_constants or i in self._producer:
                    live.add(i)
            # paper §6 extension: an accumulating operator (attrs
            # inplace=True, e.g. elementwise add) whose input dies here and
            # matches the output size can write INTO that input — the output
            # needs no separate buffer at this step.  When only one input is
            # genuinely writable (e.g. dynamic_update_slice's operand), the
            # op names it via attrs["inplace_input"].
            inplace_ok = op.attrs.get("inplace") and any(
                last_use.get(i, -1) == t
                and self.size(i) == self.size(op.output)
                and i in self._producer
                for i in inplace_candidates(op))
            if not inplace_ok:
                live.add(op.output)
            for p in produced:
                if last_use.get(p, -1) > t:
                    live.add(p)
            if include_constants:
                # Constants with uses strictly after this step stay resident.
                for c in self.constants():
                    if last_use.get(c, -1) > t:
                        live.add(c)
            produced.add(op.output)
            sets.append(frozenset(live))
        return sets

    def usage_profile(self, schedule: Sequence[Operator],
                      include_constants: bool = True) -> List[int]:
        return [sum(self.size(t) for t in s)
                for s in self.live_sets(schedule, include_constants)]

    def peak_usage(self, schedule: Sequence[Operator],
                   include_constants: bool = True) -> int:
        prof = self.usage_profile(schedule, include_constants)
        return max(prof) if prof else 0

    # ------------------------------------------------------------------ misc
    def __repr__(self) -> str:
        return (f"Graph(tensors={len(self.tensors)}, ops={len(self.operators)}, "
                f"outputs={self.outputs})")


def linear_chains(graph: Graph) -> List[List[Operator]]:
    """Maximal chains of operators where each link is the sole consumer of its
    predecessor's output and has exactly one non-constant input.  Inside such a
    chain the execution order is forced, so schedulers may contract each chain
    into a single super-operator (see heuristics.contract_chains)."""
    chains: List[List[Operator]] = []
    visited: Set[str] = set()

    def sole_activation_input(op: Operator) -> Optional[str]:
        acts = [i for i in op.inputs if graph.producer(i) is not None]
        return acts[0] if len(acts) == 1 else None

    for op in graph.operators:
        if op.name in visited:
            continue
        # Is `op` a chain head?  Its activation input (if any) must not chain
        # into it (predecessor has >1 consumer, or op has !=1 activation input).
        a = sole_activation_input(op)
        prev = graph.producer(a) if a is not None else None
        if prev is not None and len(graph.consumers(prev.output)) == 1 \
                and prev.output not in graph.outputs:
            continue  # not a head; will be visited as part of prev's chain
        chain = [op]
        visited.add(op.name)
        cur = op
        while True:
            cons = graph.consumers(cur.output)
            if len(cons) != 1 or cur.output in graph.outputs:
                break
            nxt = cons[0]
            if sole_activation_input(nxt) != cur.output or nxt.name in visited:
                break
            chain.append(nxt)
            visited.add(nxt.name)
            cur = nxt
        chains.append(chain)
    return chains
