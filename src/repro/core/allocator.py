"""Tensor-buffer memory allocators.

``DynamicAllocator`` reproduces the paper's §4 runtime strategy: tensors live
in contiguous blocks of one arena; memory is reclaimed as soon as a tensor's
last consumer has run; after every operator, all live buffers are compacted to
the start of the region ("moving all tensor buffers to the start of the memory
region as much as possible after the execution of every operator").  It
reports the peak arena high-water mark and the total bytes memmoved — the
proxy for the paper's measured <1 % latency/energy overhead.

``ArenaPlanner`` is the §6 extension ("when the execution schedule is known in
advance, optimal tensor buffer placement in memory may be precomputed"): an
offline offset assignment over tensor lifetimes, greedy best-fit by decreasing
size — the strategy used by TFLite's arena planner.  Invariant (property
tested): tensors with overlapping lifetimes occupy disjoint address ranges.

Both allocators are **byte-granular**: sizes are bytes
(``elements * itemsize``, see ``graph.DTYPE_ITEMSIZE``) and offsets are byte
offsets.  Alignment policy: every offset is rounded up to ``alignment``
bytes; ``ArenaPlanner.plan(alignment=None)`` picks the graph's widest
element type (4 for any graph containing f32 tensors, 1 for pure int8), so
a bitcast view of the arena at any placement is always naturally aligned —
the precondition the compiled executor (and a real MCU pointer cast)
relies on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .graph import Graph, Operator, inplace_candidates


@dataclasses.dataclass
class Block:
    tensor: str
    offset: int
    size: int


@dataclasses.dataclass
class AllocatorStats:
    peak_bytes: int = 0
    bytes_moved: int = 0
    allocations: int = 0
    frees: int = 0
    defrag_passes: int = 0


class DynamicAllocator:
    """First-fit allocation + compact-to-front defragmentation (paper §4).

    ``alignment`` > 1 rounds every block offset up to that many bytes
    (mixed-dtype arenas need at least the widest itemsize so live buffers
    stay dereferenceable after compaction)."""

    def __init__(self, capacity: Optional[int] = None,
                 alignment: int = 1) -> None:
        if alignment < 1:
            raise ValueError(f"alignment must be >= 1, got {alignment}")
        self.capacity = capacity
        self.alignment = alignment
        self.blocks: List[Block] = []          # sorted by offset
        self.addresses: Dict[str, int] = {}    # tensor -> offset
        self.stats = AllocatorStats()

    def _align(self, x: int) -> int:
        a = self.alignment
        return (x + a - 1) // a * a

    # ------------------------------------------------------------------ api
    def alloc(self, tensor: str, size: int) -> int:
        if tensor in self.addresses:
            raise ValueError(f"{tensor!r} already allocated")
        offset = self._first_fit(size)
        if self.capacity is not None and offset + size > self.capacity:
            raise MemoryError(
                f"arena overflow allocating {tensor!r} ({size}B) at {offset}"
                f" with capacity {self.capacity}")
        blk = Block(tensor, offset, size)
        self._insert(blk)
        self.addresses[tensor] = offset
        self.stats.allocations += 1
        self.stats.peak_bytes = max(self.stats.peak_bytes, self.high_water())
        return offset

    def free(self, tensor: str) -> None:
        for k, b in enumerate(self.blocks):
            if b.tensor == tensor:
                del self.blocks[k]
                del self.addresses[tensor]
                self.stats.frees += 1
                return
        raise KeyError(tensor)

    def rename(self, old: str, new: str) -> int:
        """Hand ``old``'s block to ``new`` without moving memory — an
        operator that wrote its output in place over a dead input (partial
        execution's shared output buffer does this every slice)."""
        if new in self.addresses:
            raise ValueError(f"{new!r} already allocated")
        for b in self.blocks:
            if b.tensor == old:
                b.tensor = new
                self.addresses[new] = self.addresses.pop(old)
                return b.offset
        raise KeyError(old)

    def defragment(self) -> int:
        """Compact all live blocks to the start of the arena, preserving
        order (offsets stay aligned).  Returns bytes moved (cost proxy)."""
        moved = 0
        cursor = 0
        for b in self.blocks:
            if b.offset != cursor:
                moved += b.size
                b.offset = cursor
                self.addresses[b.tensor] = cursor
            cursor = self._align(cursor + b.size)
        self.stats.bytes_moved += moved
        self.stats.defrag_passes += 1
        return moved

    def high_water(self) -> int:
        return max((b.offset + b.size for b in self.blocks), default=0)

    def live_bytes(self) -> int:
        return sum(b.size for b in self.blocks)

    # ------------------------------------------------------------- internals
    def _first_fit(self, size: int) -> int:
        cursor = 0
        for b in self.blocks:
            if b.offset - cursor >= size:
                return cursor
            cursor = self._align(max(cursor, b.offset + b.size))
        return cursor

    def _insert(self, blk: Block) -> None:
        for k, b in enumerate(self.blocks):
            if b.offset > blk.offset:
                self.blocks.insert(k, blk)
                return
        self.blocks.append(blk)


# --------------------------------------------------------------------- plans
@dataclasses.dataclass
class Placement:
    tensor: str
    offset: int
    size: int
    start: int   # first step live (op index; -1 for graph inputs)
    end: int     # last step live (inclusive)
    alias: Optional[str] = None   # shared-buffer group (inplace chains)


@dataclasses.dataclass
class ArenaPlan:
    placements: List[Placement]
    arena_size: int
    guard_bytes: int = 0   # planned inter-placement guard width (0 = none)

    def offset_of(self, tensor: str) -> int:
        for p in self.placements:
            if p.tensor == tensor:
                return p.offset
        raise KeyError(tensor)

    def guard_regions(self) -> List[Tuple[int, int]]:
        """``(offset, size)`` byte ranges of the arena that **no** placement
        ever covers.  The compiled executor fills these with canary bytes
        and verifies them untouched after execution (guard-byte debug mode,
        DESIGN.md §12).

        Defined as the complement of the union of all placements — not "the
        ``guard_bytes`` after each placement" — because temporal reuse lets
        a time-disjoint tensor legitimately occupy another tensor's trailing
        pad.  The complement is provably never written by a correct program,
        so a stomped canary is always a genuine out-of-bounds write, never a
        false positive.  Empty when ``guard_bytes == 0`` (placements tile
        the arena up to alignment slack, which we deliberately do not treat
        as guarded in production plans — they must stay byte-identical)."""
        if self.guard_bytes <= 0:
            return []
        spans = sorted((p.offset, p.offset + p.size)
                       for p in self.placements if p.size > 0)
        merged: List[List[int]] = []
        for lo, hi in spans:
            if merged and lo <= merged[-1][1]:
                merged[-1][1] = max(merged[-1][1], hi)
            else:
                merged.append([lo, hi])
        out: List[Tuple[int, int]] = []
        cursor = 0
        for lo, hi in merged:
            if lo > cursor:
                out.append((cursor, lo - cursor))
            cursor = hi
        if cursor < self.arena_size:
            out.append((cursor, self.arena_size - cursor))
        return out


def tensor_lifetimes(graph: Graph, schedule: Sequence[Operator],
                     include_constants: bool = True
                     ) -> List[Tuple[str, int, int]]:
    """(tensor, first_step, last_step) for every SRAM-resident tensor under
    ``schedule``.  Graph outputs live to the end; constants (inputs) from -1
    until their last use."""
    n = len(schedule)
    first: Dict[str, int] = {}
    last: Dict[str, int] = {}
    for t, op in enumerate(schedule):
        first[op.output] = t
        for i in op.inputs:
            last[i] = t
    for o in graph.outputs:
        last[o] = n - 1
    out = []
    for name in graph.tensors:
        if name in first:
            out.append((name, first[name], last.get(name, first[name])))
        elif include_constants and name in last:
            out.append((name, -1, last[name]))
    return out


def inplace_alias_groups(graph: Graph, schedule: Sequence[Operator]
                         ) -> Dict[str, str]:
    """tensor -> representative for buffers shared through ``inplace``
    operators (partial execution's incremental concat writes slice ``s`` into
    the buffer that already holds slices ``0..s-1``).  Mirrors the condition
    ``Graph.live_sets`` uses to charge the output buffer zero bytes: the
    consumed input must die at that step and match the output size."""
    n = len(schedule)
    last_use: Dict[str, int] = {}
    for t, op in enumerate(schedule):
        for i in op.inputs:
            last_use[i] = t
    for o in graph.outputs:
        last_use[o] = n            # pinned, never overwritten in place
    parent: Dict[str, str] = {}

    def find(x: str) -> str:
        while parent.get(x, x) != x:
            parent[x] = parent.get(parent[x], parent[x])
            x = parent[x]
        return x

    for t, op in enumerate(schedule):
        if not op.attrs.get("inplace"):
            continue
        for i in inplace_candidates(op):
            if (graph.producer(i) is not None
                    and graph.size(i) == graph.size(op.output)
                    and last_use.get(i, -1) == t):
                parent[find(op.output)] = find(i)
                break
    members = set(parent) | set(parent.values())
    return {t: find(t) for t in members}


class ArenaPlanner:
    """Offline best-fit offset assignment (greedy over candidate orders).

    Tensors chained through ``inplace`` operators are planned as one
    shared buffer (same offset, union of lifetimes) — without this, a
    partial-execution concat chain would be charged K copies of the
    output tensor and the sliced schedule's savings would vanish.

    Greedy best-fit is order-sensitive: by-decreasing-size (TFLite's
    order) is optimal on conventional layer-by-layer lifetimes, but a
    cascade's ring buffers have long, irregular, *interleaved* lifetimes
    where placing a mid-sized long-lived ring after a large short-lived
    activation can strand an alignment-rounded gap that no later tensor
    fills.  ``plan`` therefore runs the same greedy under a small fixed
    set of orderings (decreasing size, increasing birth, decreasing
    lifetime length — each size-tie-broken) and keeps the smallest arena,
    preferring the earliest ordering on ties so conventional graphs keep
    their historical (by-size) placements.

    ``alignment=None`` (default) aligns offsets to the graph's widest
    element type, so every placement can be bitcast-viewed at its natural
    alignment (pure-int8 graphs plan at byte granularity, any graph with
    f32 tensors at 4 bytes)."""

    @staticmethod
    def plan(graph: Graph, schedule: Sequence[Operator],
             include_constants: bool = True,
             alignment: Optional[int] = None,
             guard_bytes: int = 0) -> ArenaPlan:
        """``guard_bytes > 0`` is the guarded-arena debug mode: every
        tensor's *footprint* is inflated by ``guard_bytes`` during greedy
        placement (and the arena gets a trailing band), so unplaced gaps —
        ``ArenaPlan.guard_regions()`` — exist next to every placement for
        the executor to fill with canaries.  Placements keep their true
        sizes; ``guard_bytes=0`` (production) is byte-identical to the
        historical planner."""
        if guard_bytes < 0:
            raise ValueError(f"guard_bytes must be >= 0, got {guard_bytes}")
        if alignment is None:
            alignment = graph.max_itemsize()
        lifetimes = tensor_lifetimes(graph, schedule, include_constants)
        alias = inplace_alias_groups(graph, schedule)
        # fold alias groups into one pseudo-tensor spanning all members
        by_rep: Dict[str, List[Tuple[str, int, int]]] = {}
        for name, s, e in lifetimes:
            by_rep.setdefault(alias.get(name, name), []).append((name, s, e))
        groups = [(rep, min(s for _, s, _ in members),
                   max(e for _, _, e in members), members)
                  for rep, members in by_rep.items()]

        def align(x: int) -> int:
            return (x + alignment - 1) // alignment * alignment

        def greedy(items: List[Tuple[str, int, int, list]]
                   ) -> Tuple[int, List[Placement]]:
            placed: List[Placement] = []
            for rep, s, e, _members in items:
                size = graph.size(rep)
                if size == 0:
                    placed.append(Placement(rep, 0, 0, s, e))
                    continue
                overlapping = [p for p in placed
                               if not (p.end < s or e < p.start)
                               and p.size > 0]
                overlapping.sort(key=lambda p: p.offset)
                # guard mode: fit against inflated footprints so every
                # placement keeps >= guard_bytes of never-placed slack
                # around it among its temporal neighbours
                foot = size + guard_bytes
                best_off, best_gap = None, None
                cursor = 0
                for p in overlapping:
                    gap = p.offset - cursor
                    if gap >= foot and (best_gap is None or gap < best_gap):
                        best_off, best_gap = cursor, gap
                    cursor = max(cursor,
                                 align(p.offset + p.size + guard_bytes))
                offset = best_off if best_off is not None else cursor
                placed.append(Placement(rep, offset, size, s, e))
            arena = max((p.offset + p.size for p in placed), default=0)
            return arena + guard_bytes if arena else arena, placed

        orders = (
            lambda it: (-graph.size(it[0]), it[1]),          # by size
            lambda it: (it[1], -graph.size(it[0])),          # by birth
            lambda it: (it[1] - it[2], -graph.size(it[0])),  # by lifetime
        )
        best_arena, best_placed = None, None
        for key in orders:
            arena, placed = greedy(sorted(groups, key=key))
            if best_arena is None or arena < best_arena:
                best_arena, best_placed = arena, placed

        offsets = {p.tensor: p.offset for p in best_placed}
        expanded: List[Placement] = []
        for rep, _s, _e, members in groups:
            shared = rep if len(members) > 1 else None
            for name, ms, me in members:
                expanded.append(Placement(name, offsets[rep],
                                          graph.size(name), ms, me,
                                          alias=shared))
        return ArenaPlan(expanded, best_arena, guard_bytes=guard_bytes)

    @staticmethod
    def validate(plan: ArenaPlan, graph: Optional[Graph] = None) -> None:
        """Overlapping lifetimes ⇒ disjoint address ranges (tensors sharing
        a buffer through an inplace chain are exempt by construction).
        With ``graph``, additionally checks every placement is aligned to
        its tensor's itemsize — the bitcast-view precondition."""
        if graph is not None:
            for p in plan.placements:
                isz = graph.itemsize(p.tensor)
                if p.offset % isz:
                    raise AssertionError(
                        f"misaligned placement: {p.tensor} "
                        f"({graph.tensors[p.tensor].dtype}, itemsize {isz}) "
                        f"at byte offset {p.offset}")
        ps = [p for p in plan.placements if p.size > 0]
        for i, a in enumerate(ps):
            for b in ps[i + 1:]:
                if a.alias is not None and a.alias == b.alias:
                    continue
                time_overlap = not (a.end < b.start or b.end < a.start)
                addr_overlap = not (a.offset + a.size <= b.offset
                                    or b.offset + b.size <= a.offset)
                if time_overlap and addr_overlap:
                    raise AssertionError(
                        f"overlap: {a.tensor} [{a.offset},{a.offset+a.size})"
                        f" steps [{a.start},{a.end}] vs {b.tensor}"
                        f" [{b.offset},{b.offset+b.size}) steps"
                        f" [{b.start},{b.end}]")


def static_plan_size(graph: Graph) -> int:
    """Footprint of the *static* strategy the paper compares against in
    Table 1 (MobileNet column): every activation tensor gets its own slot for
    the whole run — no reuse."""
    consts = set(graph.constants())
    return sum(t.size for n, t in graph.tensors.items() if n not in consts)
