"""Appendix-A style memory-usage reporting: per-operator working-set tables
and an ASCII usage plot, as produced by the paper's tflite-tools."""
from __future__ import annotations

from typing import Sequence

from .graph import Graph, Operator


def usage_table(graph: Graph, schedule: Sequence[Operator],
                include_constants: bool = True) -> str:
    sets = graph.live_sets(schedule, include_constants)
    rows = []
    width_op = max([len(op.name) for op in schedule] + [8])
    header = f"{'Operator':<{width_op}} | {'Tensors in RAM':<32} | Usage (B)"
    rows.append(header)
    rows.append("-" * len(header))
    peak = 0
    for op, live in zip(schedule, sets):
        usage = sum(graph.size(t) for t in live)
        peak = max(peak, usage)
        names = "{" + ", ".join(sorted(live)) + "}"
        rows.append(f"{op.name:<{width_op}} | {names:<32} | {usage:>9,}")
    rows.append("-" * len(header))
    rows.append(f"{'Peak:':<{width_op}} | {'':<32} | {peak:>9,}")
    return "\n".join(rows)


def usage_plot(graph: Graph, schedule: Sequence[Operator],
               include_constants: bool = True, width: int = 50) -> str:
    profile = graph.usage_profile(schedule, include_constants)
    peak = max(profile) if profile else 1
    lines = []
    for op, u in zip(schedule, profile):
        bar = "#" * max(1, round(u / peak * width))
        lines.append(f"{op.name:>12} |{bar:<{width}}| {u:,}")
    return "\n".join(lines)


def compare(graph: Graph, default: Sequence[Operator],
            optimised: Sequence[Operator],
            include_constants: bool = True) -> str:
    pd = graph.peak_usage(default, include_constants)
    po = graph.peak_usage(optimised, include_constants)
    saving = pd - po
    return (f"default-order peak : {pd:,} B\n"
            f"optimised peak     : {po:,} B\n"
            f"saving             : {saving:,} B ({saving / pd * 100:.1f}%)")
