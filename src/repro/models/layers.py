"""Shared building blocks: norms, RoPE, chunked (online-softmax) attention.

The chunked attention is the single attention code path for training and
prefill — a pure-JAX flash-attention formulation (lax.scan over KV chunks
with running max/denominator) whose peak memory is O(S·chunk) instead of
O(S²), which is what lets the 32k-prefill and whisper-encoder shapes lower
within HBM.  The Pallas kernel in ``repro.kernels.flash_attention`` is the
TPU-optimised version of the same computation (used on real hardware; the
jnp path is the oracle and the dry-run path).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import runtime


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dtype)


# ------------------------------------------------------------------- RoPE
def rope_angles(positions: jax.Array, head_dim: int,
                theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions [...]; returns cos/sin of shape [..., head_dim//2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., S, H, D]; cos/sin [..., S, D//2] broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


# ------------------------------------------------- chunked flash attention
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, chunk: int = 512,
                      q_positions: Optional[jax.Array] = None,
                      kv_positions: Optional[jax.Array] = None,
                      sliding_window: int = 0,
                      softmax_scale: Optional[float] = None) -> jax.Array:
    """Online-softmax attention.

    q: [B, Sq, H, D]; k, v: [B, Skv, K, D] with H % K == 0 (GQA).
    Scans over KV chunks carrying (acc, running_max, denom): peak memory is
    O(B·H·Sq·chunk) rather than O(B·H·Sq·Skv).
    """
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    assert H % K == 0, (H, K)
    groups = H // K
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if kv_positions is None:
        kv_positions = jnp.arange(Skv)

    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad),
                               constant_values=jnp.iinfo(jnp.int32).max)
    kc = k.reshape(B, n_chunks, chunk, K, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, K, D).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(n_chunks, chunk)

    qf = q.astype(jnp.float32) * scale
    # [B, K, groups, Sq, D] so GQA is an einsum over the shared K axis
    qg = qf.reshape(B, Sq, K, groups, D).transpose(0, 2, 3, 1, 4)

    NEG = jnp.float32(-1e30)

    def step(carry, inp):
        acc, m, denom = carry
        kch, vch, pch = inp          # [B, chunk, K, D], [chunk]
        s = jnp.einsum("bkgsd,bckd->bkgsc", qg, kch.astype(jnp.float32))
        # mask: causal and/or sliding window on absolute positions
        qpos = q_positions[:, None]          # [Sq, 1]
        kpos = pch[None, :]                  # [1, chunk]
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask &= kpos <= qpos
        if sliding_window:
            mask &= kpos > qpos - sliding_window
        s = jnp.where(mask[None, None, None], s, NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        denom = denom * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgsc,bckd->bkgsd", p, vch.astype(jnp.float32))
        return (acc, m_new, denom), None

    acc0 = jnp.zeros((B, K, groups, Sq, D), jnp.float32)
    m0 = jnp.full((B, K, groups, Sq), NEG)
    d0 = jnp.zeros((B, K, groups, Sq), jnp.float32)
    (acc, m, denom), _ = lax.scan(step, (acc0, m0, d0), (kc, vc, pc),
                                  unroll=runtime.scan_unroll())
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     length_mask: jax.Array,
                     softmax_scale: Optional[float] = None) -> jax.Array:
    """Single-position attention against a cache.

    q: [B, 1, H, D]; caches: [B, S, K, D]; length_mask: [B, S] bool (True =
    attend).  The Pallas ``decode_attention`` kernel implements this same
    contract with blocked KV streaming.
    """
    B, _, H, D = q.shape
    _, S, K, _ = k_cache.shape
    groups = H // K
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    qg = (q.astype(jnp.float32) * scale).reshape(B, K, groups, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    s = jnp.where(length_mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x: jax.Array, w_in: jax.Array, b_in: jax.Array,
             w_out: jax.Array, b_out: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ w_in + b_in) @ w_out + b_out
