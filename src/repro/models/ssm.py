"""Recurrent mixers: Mamba2 (SSD), mLSTM, sLSTM.

Both Mamba2 and mLSTM are instances of one primitive — *chunked linear
attention with per-step decay*:

    state_t = exp(log_decay_t) · state_{t-1} + in_scale_t · k_t ⊗ v_t
    y_t     = q_t · state_t

Mamba2 maps (q,k,v,log_decay,in_scale) = (C, B, x, Δt·A, Δt) with B/C shared
across heads; mLSTM maps them to (q, k, v, log σ(f), σ(i)) with an extra
normaliser row (implemented by appending a ones-column to v).  The chunked
evaluation (intra-chunk quadratic + inter-chunk state scan) keeps peak memory
at O(S·chunk·H) — the same working-set-vs-schedule trade the paper makes,
applied to recurrence.  All decays are ≤ 0 in log space so every exp() here
is bounded by 1 (numerically safe in bf16).

sLSTM is a genuinely sequential scan (exponential gating with running max
stabiliser), evaluated with lax.scan over time.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import runtime


# ------------------------------------------------ chunked linear attention
def chunked_linear_attention(
        q: jax.Array, k: jax.Array, v: jax.Array,
        log_decay: jax.Array, in_scale: jax.Array, *,
        chunk: int = 128, normalize: bool = False,
        state_in: Optional[jax.Array] = None,
        ) -> Tuple[jax.Array, jax.Array]:
    """q,k: [B,S,H,N]; v: [B,S,H,P]; log_decay, in_scale: [B,S,H].

    Returns (y [B,S,H,P], final state [B,H,N,P(+1)]).
    """
    B, S, H, N = q.shape
    P = v.shape[-1]
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    log_decay = log_decay.astype(f32)
    in_scale = in_scale.astype(f32)
    if normalize:
        v = jnp.concatenate([v, jnp.ones((B, S, H, 1), f32)], axis=-1)
    Pv = v.shape[-1]

    nz = -(-S // chunk)
    pad = nz * chunk - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_decay = jnp.pad(log_decay, ((0, 0), (0, pad), (0, 0)))
        in_scale = jnp.pad(in_scale, ((0, 0), (0, pad), (0, 0)))

    def chunkify(a):
        return a.reshape((B, nz, chunk) + a.shape[2:]).swapaxes(0, 1)

    qz, kz, vz = chunkify(q), chunkify(k), chunkify(v)
    ldz, isz = chunkify(log_decay), chunkify(in_scale)

    state0 = state_in if state_in is not None \
        else jnp.zeros((B, H, N, Pv), f32)
    if normalize and state_in is not None and state_in.shape[-1] == P:
        raise ValueError("state_in must include the normaliser column")

    idx = jnp.arange(chunk)
    causal = (idx[:, None] >= idx[None, :])          # j <= i

    def step(state, inp):
        qc, kc, vc, ldc, sc = inp                    # [B,c,H,*]
        cum = jnp.cumsum(ldc, axis=1)                # [B,c,H]
        # ---- intra-chunk: scores (q_i·k_j)·exp(cum_i-cum_j)·s_j, j<=i
        att = jnp.einsum("bihn,bjhn->bhij", qc, kc)
        dec = jnp.exp(jnp.clip(
            cum.transpose(0, 2, 1)[:, :, :, None]
            - cum.transpose(0, 2, 1)[:, :, None, :], -60.0, 0.0))
        w = att * dec * sc.transpose(0, 2, 1)[:, :, None, :]
        w = jnp.where(causal[None, None], w, 0.0)
        y_intra = jnp.einsum("bhij,bjhp->bihp", w, vc)
        # ---- inter-chunk: carry-in state decayed to each position
        y_inter = jnp.einsum("bihn,bhnp->bihp",
                             qc * jnp.exp(cum)[..., None], state)
        # ---- state update
        tail = cum[:, -1:, :]                        # [B,1,H]
        wj = jnp.exp(jnp.clip(tail - cum, -60.0, 0.0)) * sc   # [B,c,H]
        state = state * jnp.exp(tail[:, 0, :])[..., None, None] \
            + jnp.einsum("bjhn,bjhp,bjh->bhnp", kc, vc, wj)
        return state, y_intra + y_inter

    state, ys = lax.scan(step, state0, (qz, kz, vz, ldz, isz),
                         unroll=runtime.scan_unroll())
    y = ys.swapaxes(0, 1).reshape(B, nz * chunk, H, Pv)[:, :S]
    if normalize:
        y, denom = y[..., :P], y[..., P:]
        y = y / jnp.maximum(jnp.abs(denom), 1.0)
    return y, state


def linear_attention_step(
        state: jax.Array, q: jax.Array, k: jax.Array, v: jax.Array,
        log_decay: jax.Array, in_scale: jax.Array, *,
        normalize: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. q,k: [B,H,N]; v: [B,H,P]; gates: [B,H];
    state: [B,H,N,P(+1)].  Returns (y [B,H,P], new state)."""
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    if normalize:
        v = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), f32)], -1)
    decay = jnp.exp(jnp.clip(log_decay.astype(f32), -60.0, 0.0))
    state = state * decay[..., None, None] \
        + in_scale.astype(f32)[..., None, None] \
        * (k[..., :, None] * v[..., None, :])
    y = jnp.einsum("bhn,bhnp->bhp", q, state)
    if normalize:
        P = y.shape[-1] - 1
        y = y[..., :P] / jnp.maximum(jnp.abs(y[..., P:]), 1.0)
    return y, state


# ------------------------------------------------------------ causal conv1d
def causal_conv1d(x: jax.Array, w: jax.Array,
                  cache: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x [B,S,C]; w [W,C]; cache [B,W-1,C].
    Returns (y [B,S,C], new cache [B,W-1,C])."""
    W = w.shape[0]
    B, S, C = x.shape
    if cache is None:
        cache = jnp.zeros((B, W - 1, C), x.dtype)
    xc = jnp.concatenate([cache, x], axis=1)
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for t in range(W):   # W is 4 — unrolled taps, no conv primitive needed
        y = y + xc[:, t:t + S].astype(jnp.float32) * w[t].astype(jnp.float32)
    new_cache = xc[:, -(W - 1):] if W > 1 else cache
    return jax.nn.silu(y).astype(x.dtype), new_cache


# --------------------------------------------------------------------- sLSTM
def slstm_scan(x_gates: jax.Array, r: jax.Array,
               state: Optional[Tuple[jax.Array, ...]] = None,
               ) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    """sLSTM with exponential gating and max-stabiliser.

    x_gates: [B,S,4,H,P] pre-activations (i, f, z, o) from the input path;
    r: [4,H,P,P] per-head recurrent kernels.
    Returns (h [B,S,H,P], final (c,n,h,m) state).
    """
    B, S, _, H, P = x_gates.shape
    f32 = jnp.float32
    if state is None:
        zeros = jnp.zeros((B, H, P), f32)
        state = (zeros, zeros + 1.0, zeros, zeros - 10.0)   # c, n, h, m

    rr = r.astype(f32)

    def step(carry, xt):
        c, n, h, m = carry
        rec = jnp.einsum("bhp,ghpq->bghq", h, rr)           # [B,4,H,P]
        pre = xt.astype(f32) + rec
        i_p, f_p, z_p, o_p = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
        log_i = i_p
        log_f = -jax.nn.softplus(-f_p)                       # log sigmoid(f)
        m_new = jnp.maximum(log_f + m, log_i)
        i_g = jnp.exp(log_i - m_new)
        f_g = jnp.exp(log_f + m - m_new)
        z = jnp.tanh(z_p)
        o = jax.nn.sigmoid(o_p)
        c = f_g * c + i_g * z
        n = f_g * n + i_g
        h = o * c / jnp.maximum(jnp.abs(n), 1.0)
        return (c, n, h, m_new), h

    xs = x_gates.swapaxes(0, 1)                              # [S,B,4,H,P]
    state, hs = lax.scan(step, state, xs)
    return hs.swapaxes(0, 1).astype(x_gates.dtype), state
