"""Runtime analysis flags.

XLA's HloCostAnalysis counts a while-loop (lax.scan) body ONCE, not
multiplied by the trip count, so FLOPs/bytes/collectives inside the layer
and chunk scans are undercounted by the trip count.  For the roofline
analysis pass the dry-run re-lowers the model with these scans UNROLLED
(`UNROLL_SCANS = True`), which makes cost_analysis and the HLO collective
census exact.  The deployable artifact (and memory_analysis) always uses the
rolled scans.  The sLSTM time scan is never unrolled (S can be 500k); its
FLOPs are a negligible slice of xLSTM and the residual undercount is noted
in EXPERIMENTS.md.
"""

UNROLL_SCANS = False


def scan_unroll():
    """Value for lax.scan(unroll=...): 1 (rolled) or True (fully unrolled)."""
    return True if UNROLL_SCANS else 1
