from .model import (Model, init_params, param_specs)

__all__ = ["Model", "init_params", "param_specs"]
