"""Expert-parallel Mixture-of-Experts FFN.

Sharding scheme (see DESIGN.md §7): experts are partitioned over the `model`
mesh axis; tokens are sharded over the data axes and *replicated* across
`model`.  Each model shard gathers the tokens routed to its local experts
into capacity-bounded buffers (GShard-style scatter with an overflow row —
tokens beyond capacity are dropped, standard capacity-factor semantics),
runs the expert FFNs, scatters results back weighted by the router
probabilities, and a psum over `model` combines the partial outputs.
Expert weights are additionally sharded over `data` for storage (FSDP) and
all-gathered just-in-time inside the shard_map.

On a single device (CPU smoke tests) the same routing code runs with all
experts local and no collectives.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


def router_topk(x: jax.Array, w_router: jax.Array, k: int,
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [B,S,d] -> (probs [B,S,k], idx [B,S,k] int32, load-balance aux loss).

    Softmax over experts then top-k renormalised — the Switch/Mixtral recipe.
    """
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # [B,S,E]
    top_p, top_i = lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # aux loss: E * sum_e f_e * p_e  (fraction routed * mean prob)
    E = w_router.shape[-1]
    one_hot = jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32)
    f = one_hot.reshape(-1, E).mean(0)
    p = probs.reshape(-1, E).mean(0)
    aux = E * jnp.sum(f * p)
    return top_p, top_i.astype(jnp.int32), aux


def _expert_pass(x_flat: jax.Array, top_p: jax.Array, top_i: jax.Array,
                 w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
                 first_expert: jax.Array, capacity: int) -> jax.Array:
    """Tokens -> local experts -> tokens, capacity-bounded.

    x_flat [T,d]; top_p/top_i [T,k]; w_* [E_loc, d, ff]/[E_loc, ff, d];
    first_expert: global id of local expert 0.  Returns partial y [T,d].
    """
    T, d = x_flat.shape
    E_loc = w_gate.shape[0]
    wt = w_gate.dtype       # compute in the weights' dtype (bf16), f32
    f32 = jnp.float32       # accumulation via preferred_element_type: this
    # keeps the FSDP all_gather operands in bf16 — with .astype(f32) on the
    # weights XLA hoists the convert BEFORE the gather and doubles the
    # collective traffic (§Perf pair-3 iteration 2).

    def one_expert(wg, wu, wd, j):
        e = first_expert + j
        match = (top_i == e)                                  # [T,k]
        gate = jnp.sum(jnp.where(match, top_p, 0.0), axis=-1)  # [T]
        hit = match.any(-1)
        pos = jnp.cumsum(hit.astype(jnp.int32)) - 1
        valid = hit & (pos < capacity)
        slot = jnp.where(valid, pos, capacity)                # overflow row
        buf = jnp.zeros((capacity + 1, d), wt)
        buf = buf.at[slot].add(
            jnp.where(valid[:, None], x_flat.astype(wt), 0))
        g_ = jnp.einsum("cd,df->cf", buf[:capacity], wg,
                        preferred_element_type=f32)
        u_ = jnp.einsum("cd,df->cf", buf[:capacity], wu,
                        preferred_element_type=f32)
        h = (jax.nn.silu(g_) * u_).astype(wt)
        out = jnp.einsum("cf,fd->cd", h, wd,
                         preferred_element_type=f32)          # [C, d]
        out = jnp.concatenate([out, jnp.zeros((1, d), f32)], 0)
        return out[slot] * (valid & (gate > 0))[:, None] * gate[:, None]

    y = jnp.zeros((T, d), f32)
    for j in range(E_loc):    # E_loc is 1-2 in practice; unrolled
        y = y + one_expert(w_gate[j], w_up[j], w_down[j], j)
    return y


def moe_ffn(x: jax.Array, w_router: jax.Array, w_gate: jax.Array,
            w_up: jax.Array, w_down: jax.Array, *, k: int,
            capacity_factor: float = 1.25,
            mesh: Optional[jax.sharding.Mesh] = None,
            dp_axes: Tuple[str, ...] = (), tp_axis: str = "model",
            fsdp_axis: Optional[str] = "data",
            ) -> Tuple[jax.Array, jax.Array]:
    """x [B,S,d] -> (y [B,S,d], aux loss).  w_gate/up [E,d,ff], w_down
    [E,ff,d].  With a mesh: shard_map over (dp_axes..., tp_axis)."""
    B, S, d = x.shape
    E = w_router.shape[-1]
    top_p, top_i, aux = router_topk(x, w_router, k)

    if mesh is None:
        cap = max(1, int(B * S * k / E * capacity_factor))
        y = _expert_pass(x.reshape(-1, d), top_p.reshape(-1, k),
                         top_i.reshape(-1, k), w_gate, w_up, w_down,
                         jnp.int32(0), cap)
        return y.reshape(B, S, d).astype(x.dtype), aux

    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    tp = mesh.shape[tp_axis]
    assert E % tp == 0, (E, tp)
    E_loc = E // tp
    T_loc = (B // dp) * S
    cap = max(1, int(T_loc * k / E * capacity_factor))

    tok = P(dp_axes, None, None)
    w_spec = P(tp_axis, fsdp_axis, None)

    def shard_fn(xs, tps, tis, wg, wu, wd):
        if fsdp_axis is not None:
            wg = lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
            wu = lax.all_gather(wu, fsdp_axis, axis=1, tiled=True)
            wd = lax.all_gather(wd, fsdp_axis, axis=2, tiled=True)
        first = lax.axis_index(tp_axis).astype(jnp.int32) * E_loc
        Bl = xs.shape[0]
        y = _expert_pass(xs.reshape(-1, d), tps.reshape(-1, k),
                         tis.reshape(-1, k), wg, wu, wd, first, cap)
        # psum in the activation dtype, not f32 — halves the AR traffic
        y = lax.psum(y.astype(xs.dtype), tp_axis)
        return y.reshape(Bl, S, d)

    wd_spec = P(tp_axis, None, fsdp_axis)   # w_down [E, ff, d]: FSDP on d
    y = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(tok, tok, tok, w_spec, w_spec, wd_spec),
        out_specs=tok, check_vma=False,
    )(x, top_p, top_i, w_gate, w_up, w_down)
    return y.astype(x.dtype), aux
