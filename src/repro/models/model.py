"""Unified multi-architecture transformer stack.

One ``Model`` class covers all six assigned families:

* dense / MoE / VLM decoders  — uniform stack of attention blocks, scanned
  over stacked per-layer parameters (compile time independent of depth);
* Zamba2 hybrid               — groups of Mamba2 layers with a weight-SHARED
  attention block applied after each group (nested scan);
* xLSTM                       — groups of mLSTM layers with an sLSTM closing
  each group;
* Whisper                     — encoder stack (non-causal) + decoder stack
  with cross-attention to cached encoder K/V.

Everything is pure-functional: ``init_params`` builds the pytree (and
``jax.eval_shape`` of it gives the dry-run specs), ``param_specs`` the
matching PartitionSpec pytree.  Modality frontends (audio conv codec, ViT)
are stubs per the assignment carve-out: inputs arrive as precomputed
frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from . import runtime, ssm
from .layers import (apply_rope, chunked_attention, decode_attention,
                     rms_norm, rope_angles, swiglu)
from .moe import moe_ffn

Params = Dict[str, Any]


def _lscan(body, init, xs, **kw):
    """Layer/chunk scan: unrollable for the roofline analysis pass."""
    return lax.scan(body, init, xs, unroll=runtime.scan_unroll(), **kw)


# --------------------------------------------------------------- utilities
def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _shard(x, mesh: Optional[Mesh], *spec):
    if mesh is None:
        return x
    return lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def _tp(cfg: ModelConfig, mesh: Optional[Mesh], n: int) -> Optional[str]:
    """'model' when n divides evenly over the tensor-parallel axis."""
    if mesh is None or "model" not in mesh.shape:
        return None
    return "model" if n % mesh.shape["model"] == 0 else None


DP = ("data",)   # batch axes; the launcher extends this with "pod"


def resolve_kv_mode(cfg: ModelConfig, mesh: Optional[Mesh]) -> str:
    """Decode-cache sharding mode (see ModelConfig.kv_mode)."""
    if mesh is None or "model" not in mesh.shape:
        return "heads"
    if cfg.kv_mode != "auto":
        return cfg.kv_mode
    return "heads" if cfg.num_kv_heads % mesh.shape["model"] == 0 \
        else "sequence"


# ------------------------------------------------------------------- init
def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _attn_block_params(cfg: ModelConfig, key, n_layers: int,
                       cross: bool = False) -> Params:
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    dt = _dtype(cfg)
    ks = jax.random.split(key, 16)
    L = (n_layers,) if n_layers else ()
    s_in = 1.0 / math.sqrt(d)
    p = {
        "ln1": jnp.ones(L + (d,), jnp.float32),
        "wq": _init(ks[0], L + (d, H, hd), s_in, dt),
        "wk": _init(ks[1], L + (d, K, hd), s_in, dt),
        "wv": _init(ks[2], L + (d, K, hd), s_in, dt),
        "wo": _init(ks[3], L + (H, hd, d), 1.0 / math.sqrt(H * hd), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros(L + (H, hd), dt)
        p["bk"] = jnp.zeros(L + (K, hd), dt)
        p["bv"] = jnp.zeros(L + (K, hd), dt)
    if cross:
        p.update({
            "ln_x": jnp.ones(L + (d,), jnp.float32),
            "wq_x": _init(ks[4], L + (d, H, hd), s_in, dt),
            "wk_x": _init(ks[5], L + (d, H, hd), s_in, dt),
            "wv_x": _init(ks[6], L + (d, H, hd), s_in, dt),
            "wo_x": _init(ks[7], L + (H, hd, d), 1.0 / math.sqrt(H * hd), dt),
        })
    # FFN
    ff = cfg.d_ff
    if cfg.is_moe:
        E = cfg.num_experts
        p.update({
            "ln2": jnp.ones(L + (d,), jnp.float32),
            "router": _init(ks[8], L + (d, E), s_in, jnp.float32),
            "we_g": _init(ks[9], L + (E, d, ff), s_in, dt),
            "we_u": _init(ks[10], L + (E, d, ff), s_in, dt),
            "we_d": _init(ks[11], L + (E, ff, d), 1.0 / math.sqrt(ff), dt),
        })
    elif ff:
        p.update({
            "ln2": jnp.ones(L + (d,), jnp.float32),
            "wg": _init(ks[8], L + (d, ff), s_in, dt),
            "wu": _init(ks[9], L + (d, ff), s_in, dt),
            "wdn": _init(ks[10], L + (ff, d), 1.0 / math.sqrt(ff), dt),
        })
    return p


def _attn_block_specs(cfg: ModelConfig, mesh, n_layers: int,
                      cross: bool = False) -> Params:
    tpH = _tp(cfg, mesh, cfg.num_heads)
    tpK = _tp(cfg, mesh, cfg.num_kv_heads)
    L = (None,) if n_layers else ()
    p = {
        "ln1": P(*L, None),
        "wq": P(*L, "data", tpH, None),
        "wk": P(*L, "data", tpK, None),
        "wv": P(*L, "data", tpK, None),
        "wo": P(*L, tpH, None, "data"),
    }
    if cfg.qkv_bias:
        p["bq"] = P(*L, tpH, None)
        p["bk"] = P(*L, tpK, None)
        p["bv"] = P(*L, tpK, None)
    if cross:
        p.update({"ln_x": P(*L, None),
                  "wq_x": P(*L, "data", tpH, None),
                  "wk_x": P(*L, "data", tpH, None),
                  "wv_x": P(*L, "data", tpH, None),
                  "wo_x": P(*L, tpH, None, "data")})
    if cfg.is_moe:
        p.update({"ln2": P(*L, None),
                  "router": P(*L, None, None),
                  "we_g": P(*L, "model", "data", None),
                  "we_u": P(*L, "model", "data", None),
                  "we_d": P(*L, "model", None, "data")})
    elif cfg.d_ff:
        tpF = _tp(cfg, mesh, cfg.d_ff)
        p.update({"ln2": P(*L, None),
                  "wg": P(*L, "data", tpF),
                  "wu": P(*L, "data", tpF),
                  "wdn": P(*L, tpF, "data")})
    return p


def _mamba_block_params(cfg: ModelConfig, key, n_layers: int) -> Params:
    d, N = cfg.d_model, cfg.ssm_state
    H, Ph = cfg.ssm_heads, cfg.ssm_head_dim
    W = cfg.conv_width
    dt = _dtype(cfg)
    ks = jax.random.split(key, 10)
    L = (n_layers,)
    s = 1.0 / math.sqrt(d)
    return {
        "ln": jnp.ones(L + (d,), jnp.float32),
        "w_x": _init(ks[0], L + (d, H, Ph), s, dt),
        "w_z": _init(ks[1], L + (d, H, Ph), s, dt),
        "w_B": _init(ks[2], L + (d, N), s, dt),
        "w_C": _init(ks[3], L + (d, N), s, dt),
        "w_dt": _init(ks[4], L + (d, H), s, dt),
        "conv_x": _init(ks[5], L + (W, H, Ph), 0.5, jnp.float32),
        "conv_B": _init(ks[6], L + (W, N), 0.5, jnp.float32),
        "conv_C": _init(ks[7], L + (W, N), 0.5, jnp.float32),
        "A_log": jnp.zeros(L + (H,), jnp.float32),
        "D": jnp.ones(L + (H,), jnp.float32),
        "dt_bias": jnp.zeros(L + (H,), jnp.float32),
        "out_norm": jnp.ones(L + (H, Ph), jnp.float32),
        "w_out": _init(ks[8], L + (H, Ph, d), 1.0 / math.sqrt(H * Ph), dt),
    }


def _mamba_block_specs(cfg: ModelConfig, mesh, n_layers: int) -> Params:
    tpH = _tp(cfg, mesh, cfg.ssm_heads)
    L = (None,)
    return {
        "ln": P(*L, None),
        "w_x": P(*L, "data", tpH, None),
        "w_z": P(*L, "data", tpH, None),
        "w_B": P(*L, "data", None),
        "w_C": P(*L, "data", None),
        "w_dt": P(*L, "data", tpH),
        "conv_x": P(*L, None, tpH, None),
        "conv_B": P(*L, None, None),
        "conv_C": P(*L, None, None),
        "A_log": P(*L, tpH),
        "D": P(*L, tpH),
        "dt_bias": P(*L, tpH),
        "out_norm": P(*L, tpH, None),
        "w_out": P(*L, tpH, None, "data"),
    }


def _xlstm_block_params(cfg: ModelConfig, key, n_layers: int,
                        kind: str) -> Params:
    d, H = cfg.d_model, cfg.num_heads
    Ph = d // H
    dt = _dtype(cfg)
    ks = jax.random.split(key, 8)
    L = (n_layers,)
    s = 1.0 / math.sqrt(d)
    if kind == "mlstm":
        return {
            "ln": jnp.ones(L + (d,), jnp.float32),
            "w_q": _init(ks[0], L + (d, H, Ph), s, dt),
            "w_k": _init(ks[1], L + (d, H, Ph), s, dt),
            "w_v": _init(ks[2], L + (d, H, Ph), s, dt),
            "w_ig": _init(ks[3], L + (d, H), s, jnp.float32),
            "w_fg": _init(ks[4], L + (d, H), s, jnp.float32),
            "fg_bias": jnp.full(L + (H,), 3.0, jnp.float32),
            "out_norm": jnp.ones(L + (H, Ph), jnp.float32),
            "w_o": _init(ks[5], L + (H, Ph, d), 1.0 / math.sqrt(d), dt),
        }
    return {   # slstm
        "ln": jnp.ones(L + (d,), jnp.float32),
        "w_in": _init(ks[0], L + (d, 4, H, Ph), s, dt),
        "r": _init(ks[1], L + (4, H, Ph, Ph), 1.0 / math.sqrt(Ph),
                   jnp.float32),
        "b": jnp.zeros(L + (4, H, Ph), jnp.float32),
        "w_o": _init(ks[2], L + (d, d), s, dt),
    }


def _xlstm_block_specs(cfg: ModelConfig, mesh, n_layers: int,
                       kind: str) -> Params:
    tpH = _tp(cfg, mesh, cfg.num_heads)
    L = (None,)
    if kind == "mlstm":
        return {"ln": P(*L, None),
                "w_q": P(*L, "data", tpH, None),
                "w_k": P(*L, "data", tpH, None),
                "w_v": P(*L, "data", tpH, None),
                "w_ig": P(*L, "data", tpH),
                "w_fg": P(*L, "data", tpH),
                "fg_bias": P(*L, tpH),
                "out_norm": P(*L, tpH, None),
                "w_o": P(*L, tpH, None, "data")}
    return {"ln": P(*L, None),
            "w_in": P(*L, "data", None, tpH, None),
            "r": P(*L, None, tpH, None, None),
            "b": P(*L, None, tpH, None),
            "w_o": P(*L, "data", None)}


# ----------------------------------------------------------------- layout
@dataclasses.dataclass(frozen=True)
class Layout:
    """How the stacked parameter groups tile the depth of the network."""
    kind: str                 # uniform | zamba | xlstm | encdec
    groups: int = 0           # hybrid groups
    per_group: int = 0        # inner layers per group


def model_layout(cfg: ModelConfig) -> Layout:
    if cfg.arch_type == "hybrid":
        g = cfg.num_layers // 6
        return Layout("zamba", groups=g, per_group=6)
    if cfg.arch_type == "ssm":
        g = cfg.num_layers // 6
        return Layout("xlstm", groups=g, per_group=6)
    if cfg.arch_type == "audio":
        return Layout("encdec")
    return Layout("uniform")


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    d, V = cfg.d_model, cfg.padded_vocab
    dt = _dtype(cfg)
    keys = jax.random.split(key, 12)
    lay = model_layout(cfg)
    p: Params = {
        "embed": _init(keys[0], (V, d), 1.0, dt),
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["head"] = _init(keys[1], (d, V), 1.0 / math.sqrt(d), dt)
    if lay.kind == "uniform":
        p["blocks"] = _attn_block_params(cfg, keys[2], cfg.num_layers)
    elif lay.kind == "zamba":
        n_mamba = lay.groups * lay.per_group
        p["mamba"] = _mamba_block_params(cfg, keys[2], n_mamba)
        p["shared_attn"] = _attn_block_params(cfg, keys[3], 0)
    elif lay.kind == "xlstm":
        n_m = lay.groups * (lay.per_group - 1)
        p["mlstm"] = _xlstm_block_params(cfg, keys[2], n_m, "mlstm")
        p["slstm"] = _xlstm_block_params(cfg, keys[3], lay.groups, "slstm")
    elif lay.kind == "encdec":
        p["encoder"] = _attn_block_params(cfg, keys[2], cfg.encoder_layers)
        p["blocks"] = _attn_block_params(cfg, keys[3], cfg.num_layers,
                                         cross=True)
    if cfg.num_patch_tokens:
        p["vis_proj"] = _init(keys[4], (cfg.frontend_dim, d),
                              1.0 / math.sqrt(cfg.frontend_dim), dt)
    if cfg.arch_type == "audio":
        p["frame_proj"] = _init(keys[5], (cfg.frontend_dim, d),
                                1.0 / math.sqrt(cfg.frontend_dim), dt)
    return p


def param_specs(cfg: ModelConfig, mesh: Optional[Mesh]) -> Params:
    lay = model_layout(cfg)
    tpV = _tp(cfg, mesh, cfg.padded_vocab)
    p: Params = {
        "embed": P(tpV, "data"),
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        p["head"] = P("data", tpV)
    if lay.kind == "uniform":
        p["blocks"] = _attn_block_specs(cfg, mesh, cfg.num_layers)
    elif lay.kind == "zamba":
        p["mamba"] = _mamba_block_specs(cfg, mesh, lay.groups * lay.per_group)
        p["shared_attn"] = _attn_block_specs(cfg, mesh, 0)
    elif lay.kind == "xlstm":
        p["mlstm"] = _xlstm_block_specs(cfg, mesh,
                                        lay.groups * (lay.per_group - 1),
                                        "mlstm")
        p["slstm"] = _xlstm_block_specs(cfg, mesh, lay.groups, "slstm")
    elif lay.kind == "encdec":
        p["encoder"] = _attn_block_specs(cfg, mesh, cfg.encoder_layers)
        p["blocks"] = _attn_block_specs(cfg, mesh, cfg.num_layers, cross=True)
    if cfg.num_patch_tokens:
        p["vis_proj"] = P(None, "data")
    if cfg.arch_type == "audio":
        p["frame_proj"] = P(None, "data")
    if cfg.act_shard == "cp":
        # context parallelism: the model axis carries the sequence, so
        # weights must not claim it — they stay FSDP-sharded over 'data'
        # and are gathered per layer (except MoE experts, which keep their
        # expert-parallel 'model' sharding).
        def _strip_model(spec):
            return P(*(None if ax == "model" else ax for ax in spec))
        for blk in ("blocks", "encoder", "mamba", "shared_attn", "mlstm",
                    "slstm"):
            if blk in p:
                p[blk] = {k_: (v if k_.startswith("we_") or k_ == "router"
                               else _strip_model(v))
                          for k_, v in p[blk].items()}
        p["embed"] = _strip_model(p["embed"])
        if "head" in p:
            p["head"] = _strip_model(p["head"])
    if not cfg.moe_fsdp:
        for blk in ("blocks",):
            if blk in p:
                for k_ in ("we_g", "we_u", "we_d"):
                    if k_ in p[blk]:
                        p[blk][k_] = P(*("model" if ax == "model" else None
                                         for ax in p[blk][k_]))
    if mesh is None:
        p = jax.tree_util.tree_map(lambda _: P(), p,
                                   is_leaf=lambda x: isinstance(x, P))
    return p


# ------------------------------------------------------------------ mixers
def _dp_axes(mesh: Optional[Mesh]) -> Tuple[str, ...]:
    if mesh is None:
        return ()
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _dp_for(mesh: Optional[Mesh], batch: int) -> Tuple[str, ...]:
    """Batch axes, but only when the batch divides them (long_500k has
    global_batch=1 -> replicate instead of sharding over data)."""
    dp = _dp_axes(mesh)
    if not dp:
        return ()
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    return dp if batch % size == 0 else ()


def _proj_qkv(cfg, p, h):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def attn_mixer_seq(cfg: ModelConfig, mesh, p, x, positions, *,
                   causal=True, window=0, cross_kv=None, want_kv=False):
    """Full-sequence attention block (train / prefill).  Returns
    (x, (k, v) or None, aux)."""
    dp = _dp_for(mesh, x.shape[0])
    cp = cfg.act_shard == "cp" and mesh is not None and x.shape[1] > 1
    tpH = _tp(cfg, mesh, cfg.num_heads)
    tpK = _tp(cfg, mesh, cfg.num_kv_heads)
    h = rms_norm(x, p["ln1"])
    q, k, v = _proj_qkv(cfg, p, h)
    cos, sin = rope_angles(positions, cfg.head_dim_, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if cp:
        # context parallelism: queries sequence-sharded over 'model';
        # K/V replicated (all-gathered by GSPMD — cheap at small GQA kv)
        q = _shard(q, mesh, dp, "model", None, None)
        k = _shard(k, mesh, dp, None, None, None)
        v = _shard(v, mesh, dp, None, None, None)
    else:
        q = _shard(q, mesh, dp, None, tpH, None)
        k = _shard(k, mesh, dp, None, tpK, None)
        v = _shard(v, mesh, dp, None, tpK, None)
    out = chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk,
                            q_positions=positions, kv_positions=positions,
                            sliding_window=window)
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if cross_kv is not None:
        hx = rms_norm(x, p["ln_x"])
        qx = jnp.einsum("bsd,dhk->bshk", hx, p["wq_x"])
        ck, cv = cross_kv            # [B, Senc, H, hd]
        out = chunked_attention(qx, ck, cv, causal=False,
                                chunk=cfg.attn_chunk)
        x = x + jnp.einsum("bshk,hkd->bsd", out, p["wo_x"])
    x, aux = _ffn(cfg, mesh, p, x)
    x = _shard(x, mesh, dp, "model" if cp else None, None)
    return x, ((k, v) if want_kv else None), aux


def _ffn(cfg, mesh, p, x):
    aux = jnp.float32(0.0)
    if cfg.is_moe:
        h = rms_norm(x, p["ln2"])
        y, aux = moe_ffn(h, p["router"], p["we_g"], p["we_u"], p["we_d"],
                         k=cfg.experts_per_token,
                         capacity_factor=cfg.capacity_factor,
                         mesh=mesh, dp_axes=_dp_for(mesh, x.shape[0]),
                         fsdp_axis="data" if cfg.moe_fsdp else None)
        x = x + y
    elif cfg.d_ff:
        h = rms_norm(x, p["ln2"])
        x = x + swiglu(h, p["wg"], p["wu"], p["wdn"])
    return x, aux


def _seqshard_decode_attn(cfg: ModelConfig, mesh, q, k_cache, v_cache,
                          length_mask, k_new, v_new, slot):
    """Flash-decoding over a sequence-sharded KV cache: each model shard
    owns Sc/tp cache rows, updates them if the write slot falls in its
    range, computes a partial softmax over its rows, and the partials merge
    with a max/sum reduction over the 'model' axis.  This removes the
    KV-head replication that blows past HBM when kv_heads < TP degree."""
    dp = _dp_for(mesh, q.shape[0])
    tp = mesh.shape["model"]
    Sc = k_cache.shape[1]
    Sc_loc = Sc // tp
    scale = cfg.head_dim_ ** -0.5

    def body(qb, kc, vc, mk, kn, vn, slot_):
        i = lax.axis_index("model")
        ls = slot_ - i * Sc_loc
        ok = (ls >= 0) & (ls < Sc_loc)
        lsc = jnp.clip(ls, 0, Sc_loc - 1)
        B, _, K, hd = kc.shape
        H = qb.shape[2]
        groups = H // K
        import os as _os
        if _os.environ.get("REPRO_DECODE_BASELINE"):
            # paper-faithful-naive cache update kept for §Perf A/B: whole-
            # cache where + f32 cache materialisation
            upd_k = lax.dynamic_update_slice(kc, kn.astype(kc.dtype),
                                             (0, lsc, 0, 0))
            upd_v = lax.dynamic_update_slice(vc, vn.astype(vc.dtype),
                                             (0, lsc, 0, 0))
            kc = jnp.where(ok, upd_k, kc)
            vc = jnp.where(ok, upd_v, vc)
            qg = (qb.astype(jnp.float32) * scale).reshape(B, K, groups, hd)
            sc = jnp.einsum("bkgd,bskd->bkgs", qg, kc.astype(jnp.float32))
        else:
            # §Perf decode iteration 2: select at ROW granularity (read one
            # row, blend, write one row) instead of jnp.where over the whole
            # cache, which materialised two full-cache copies per layer.
            row_k = lax.dynamic_slice(kc, (0, lsc, 0, 0), (B, 1, K, hd))
            row_v = lax.dynamic_slice(vc, (0, lsc, 0, 0), (B, 1, K, hd))
            kc = lax.dynamic_update_slice(
                kc, jnp.where(ok, kn.astype(kc.dtype), row_k),
                (0, lsc, 0, 0))
            vc = lax.dynamic_update_slice(
                vc, jnp.where(ok, vn.astype(vc.dtype), row_v),
                (0, lsc, 0, 0))
            qg = (qb.astype(jnp.float32) * scale).reshape(B, K, groups, hd)
            # bf16 operands, f32 accumulation — no f32 cache copy
            sc = jnp.einsum("bkgd,bskd->bkgs", qg.astype(kc.dtype), kc,
                            preferred_element_type=jnp.float32)
        sc = jnp.where(mk[:, None, None, :], sc, -1e30)
        m = sc.max(-1)
        pr = jnp.exp(sc - m[..., None])
        pr = jnp.where(mk[:, None, None, :], pr, 0.0)
        den = pr.sum(-1)
        if _os.environ.get("REPRO_DECODE_BASELINE"):
            acc = jnp.einsum("bkgs,bskd->bkgd", pr,
                             vc.astype(jnp.float32))
        else:
            acc = jnp.einsum("bkgs,bskd->bkgd", pr.astype(kc.dtype), vc,
                             preferred_element_type=jnp.float32)
        m_g = lax.pmax(m, "model")
        corr = jnp.exp(m - m_g)
        acc = lax.psum(acc * corr[..., None], "model")
        den = lax.psum(den * corr, "model")
        out = (acc / jnp.maximum(den[..., None], 1e-30)).reshape(B, 1, H, hd)
        return out.astype(qb.dtype), kc, vc

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None, None, None), P(dp, "model", None, None),
                  P(dp, "model", None, None), P(dp, "model"),
                  P(dp, None, None, None), P(dp, None, None, None), P()),
        out_specs=(P(dp, None, None, None), P(dp, "model", None, None),
                   P(dp, "model", None, None)),
        check_vma=False,
    )(q, k_cache, v_cache, length_mask, k_new, v_new, slot)


def attn_mixer_step(cfg: ModelConfig, mesh, p, x, k_cache, v_cache,
                    length_mask, slot, pos, cross_kv=None,
                    kv_mode: str = "heads"):
    """Single-token decode block.  x [B,1,d]; caches [B,Sc,K,hd];
    length_mask [B,Sc] (True = attend, already includes this token's slot).
    Returns (x, new k_cache, new v_cache, aux)."""
    h = rms_norm(x, p["ln1"])
    q, k, v = _proj_qkv(cfg, p, h)
    posv = jnp.full((1,), pos, jnp.int32)
    cos, sin = rope_angles(posv, cfg.head_dim_, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if kv_mode == "sequence" and mesh is not None:
        out, k_cache, v_cache = _seqshard_decode_attn(
            cfg, mesh, q, k_cache, v_cache, length_mask, k, v, slot)
    else:
        k_cache = lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                           (0, slot, 0, 0))
        v_cache = lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                           (0, slot, 0, 0))
        out = decode_attention(q, k_cache, v_cache, length_mask=length_mask)
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if cross_kv is not None:
        hx = rms_norm(x, p["ln_x"])
        qx = jnp.einsum("bsd,dhk->bshk", hx, p["wq_x"])
        ck, cv = cross_kv
        full = jnp.ones(ck.shape[:2], bool)
        out = decode_attention(qx, ck, cv, length_mask=full)
        x = x + jnp.einsum("bshk,hkd->bsd", out, p["wo_x"])
    x, aux = _ffn(cfg, mesh, p, x)
    return x, k_cache, v_cache, aux


def _head_rms(y, scale):
    """Per-head RMS norm: y [B,S,H,P] (or [B,H,P]), scale [H,P]."""
    dt = y.dtype
    y = y.astype(jnp.float32)
    y = y * lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    return (y * scale).astype(dt)


def _mamba_pre(cfg, p, h, conv_caches):
    """Shared projection + conv path. h [B,S,d] -> (xh, z, Bv, Cv, ld, dt,
    new conv caches)."""
    B, S, _ = h.shape
    H, Ph, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xh = jnp.einsum("bsd,dhp->bshp", h, p["w_x"]).reshape(B, S, H * Ph)
    z = jnp.einsum("bsd,dhp->bshp", h, p["w_z"])
    Bv = h @ p["w_B"]
    Cv = h @ p["w_C"]
    dt_pre = jnp.einsum("bsd,dh->bsh", h, p["w_dt"])
    cx, cb, cc = conv_caches
    xh, cx = ssm.causal_conv1d(xh, p["conv_x"].reshape(-1, H * Ph), cx)
    Bv, cb = ssm.causal_conv1d(Bv, p["conv_B"], cb)
    Cv, cc = ssm.causal_conv1d(Cv, p["conv_C"], cc)
    xh = xh.reshape(B, S, H, Ph)
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    ld = dt * A              # [B,S,H], <= 0
    return xh, z, Bv, Cv, ld, dt, (cx, cb, cc)


def mamba_mixer_seq(cfg: ModelConfig, mesh, p, x, *, state_in=None,
                    conv_in=None):
    B, S, _ = x.shape
    H, Ph, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    dp = _dp_for(mesh, x.shape[0])
    tpH = _tp(cfg, mesh, H)
    h = rms_norm(x, p["ln"])
    conv0 = conv_in if conv_in is not None else (None, None, None)
    xh, z, Bv, Cv, ld, dt, convs = _mamba_pre(cfg, p, h, conv0)
    xh = _shard(xh, mesh, dp, None, tpH, None)
    qh = jnp.broadcast_to(Cv[:, :, None, :], (B, S, H, N))
    kh = jnp.broadcast_to(Bv[:, :, None, :], (B, S, H, N))
    y, state = ssm.chunked_linear_attention(
        qh, kh, xh, ld, dt, chunk=cfg.ssm_chunk, state_in=state_in)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = _head_rms(y, p["out_norm"])
    out = jnp.einsum("bshp,hpd->bsd", y.astype(x.dtype), p["w_out"])
    return x + out, (convs, state)


def _conv_step(x_t, w, cache):
    """x_t [B,1,C]; w [W,C]; cache [B,W-1,C]."""
    xc = jnp.concatenate([cache, x_t], axis=1)          # [B,W,C]
    y = jnp.einsum("bwc,wc->bc", xc.astype(jnp.float32),
                   w.astype(jnp.float32))[:, None]
    return jax.nn.silu(y).astype(x_t.dtype), xc[:, 1:]


def mamba_mixer_step(cfg: ModelConfig, mesh, p, x, state, convs):
    B = x.shape[0]
    H, Ph, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    h = rms_norm(x, p["ln"])                             # [B,1,d]
    xh = jnp.einsum("bsd,dhp->bshp", h, p["w_x"]).reshape(B, 1, H * Ph)
    z = jnp.einsum("bsd,dhp->bshp", h, p["w_z"])[:, 0]
    Bv = (h @ p["w_B"])
    Cv = (h @ p["w_C"])
    dt_pre = jnp.einsum("bsd,dh->bh", h[:, 0:1], p["w_dt"][None][0])
    cx, cb, cc = convs
    xh, cx = _conv_step(xh, p["conv_x"].reshape(-1, H * Ph), cx)
    Bv, cb = _conv_step(Bv, p["conv_B"], cb)
    Cv, cc = _conv_step(Cv, p["conv_C"], cc)
    xh = xh.reshape(B, H, Ph)
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["dt_bias"])
    ld = dt * (-jnp.exp(p["A_log"]))
    qh = jnp.broadcast_to(Cv[:, 0, None, :], (B, H, N))
    kh = jnp.broadcast_to(Bv[:, 0, None, :], (B, H, N))
    y, state = ssm.linear_attention_step(state, qh, kh, xh, ld, dt)
    y = y + p["D"][None, :, None] * xh.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = _head_rms(y, p["out_norm"])
    out = jnp.einsum("bhp,hpd->bd", y.astype(x.dtype), p["w_out"])
    return x + out[:, None], (state, (cx, cb, cc))


def mlstm_mixer_seq(cfg: ModelConfig, mesh, p, x, *, state_in=None):
    B, S, d = x.shape
    H = cfg.num_heads
    Ph = d // H
    h = rms_norm(x, p["ln"])
    q = jnp.einsum("bsd,dhp->bshp", h, p["w_q"]) * (Ph ** -0.5)
    k = jnp.einsum("bsd,dhp->bshp", h, p["w_k"]) * (Ph ** -0.5)
    v = jnp.einsum("bsd,dhp->bshp", h, p["w_v"])
    ig = jax.nn.sigmoid(jnp.einsum("bsd,dh->bsh", h, p["w_ig"])
                        .astype(jnp.float32))
    fg = -jax.nn.softplus(-(jnp.einsum("bsd,dh->bsh", h, p["w_fg"])
                            .astype(jnp.float32) + p["fg_bias"]))
    y, state = ssm.chunked_linear_attention(
        q, k, v, fg, ig, chunk=cfg.ssm_chunk, normalize=True,
        state_in=state_in)
    y = _head_rms(y, p["out_norm"])
    out = jnp.einsum("bshp,hpd->bsd", y.astype(x.dtype), p["w_o"])
    return x + out, state


def mlstm_mixer_step(cfg: ModelConfig, mesh, p, x, state):
    B, _, d = x.shape
    H = cfg.num_heads
    Ph = d // H
    h = rms_norm(x, p["ln"])[:, 0]
    q = jnp.einsum("bd,dhp->bhp", h, p["w_q"]) * (Ph ** -0.5)
    k = jnp.einsum("bd,dhp->bhp", h, p["w_k"]) * (Ph ** -0.5)
    v = jnp.einsum("bd,dhp->bhp", h, p["w_v"])
    ig = jax.nn.sigmoid(jnp.einsum("bd,dh->bh", h, p["w_ig"])
                        .astype(jnp.float32))
    fg = -jax.nn.softplus(-(jnp.einsum("bd,dh->bh", h, p["w_fg"])
                            .astype(jnp.float32) + p["fg_bias"]))
    y, state = ssm.linear_attention_step(state, q, k, v, fg, ig,
                                         normalize=True)
    y = _head_rms(y, p["out_norm"])
    out = jnp.einsum("bhp,hpd->bd", y.astype(x.dtype), p["w_o"])
    return x + out[:, None], state


def slstm_mixer_seq(cfg: ModelConfig, mesh, p, x, *, state_in=None):
    B, S, d = x.shape
    H = cfg.num_heads
    h = rms_norm(x, p["ln"])
    gates = (jnp.einsum("bsd,dghp->bsghp", h, p["w_in"])
             + p["b"]).astype(jnp.float32)
    hs, state = ssm.slstm_scan(gates, p["r"], state_in)
    out = hs.reshape(B, S, d).astype(x.dtype) @ p["w_o"]
    return x + out, state


def slstm_mixer_step(cfg: ModelConfig, mesh, p, x, state):
    y, state = slstm_mixer_seq(cfg, mesh, p, x, state_in=state)
    return y, state


# ------------------------------------------------------------------ caches
def _kv_cache_shape(cfg, B, Sc, n_layers):
    K, hd = cfg.num_kv_heads, cfg.head_dim_
    return (n_layers, B, Sc, K, hd)


def init_cache(cfg: ModelConfig, batch_size: int, cache_len: int) -> Params:
    """Zeroed decode cache.  ``cache_len`` is the KV capacity (== sliding
    window when cfg.sliding_window > 0); recurrent archs carry O(1) state."""
    lay = model_layout(cfg)
    B = batch_size
    dt = _dtype(cfg)
    Sc = min(cache_len, cfg.sliding_window) if cfg.sliding_window \
        else cache_len
    c: Params = {"pos": jnp.zeros((), jnp.int32)}
    if lay.kind in ("uniform", "encdec"):
        c["k"] = jnp.zeros(_kv_cache_shape(cfg, B, Sc, cfg.num_layers), dt)
        c["v"] = jnp.zeros_like(c["k"])
        c["kv_pos"] = jnp.full((Sc,), -1, jnp.int32)
    if lay.kind == "encdec":
        H, hd = cfg.num_heads, cfg.head_dim_
        c["ck"] = jnp.zeros((cfg.num_layers, B, cfg.encoder_seq, H, hd), dt)
        c["cv"] = jnp.zeros_like(c["ck"])
    if lay.kind == "zamba":
        g, per = model_layout(cfg).groups, model_layout(cfg).per_group
        n = g * per
        H, Ph, N, W = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, \
            cfg.conv_width
        c["conv_x"] = jnp.zeros((n, B, W - 1, H * Ph), dt)
        c["conv_B"] = jnp.zeros((n, B, W - 1, N), dt)
        c["conv_C"] = jnp.zeros((n, B, W - 1, N), dt)
        c["state"] = jnp.zeros((n, B, H, N, Ph), jnp.float32)
        c["k"] = jnp.zeros(_kv_cache_shape(cfg, B, Sc, g), dt)
        c["v"] = jnp.zeros_like(c["k"])
        c["kv_pos"] = jnp.full((Sc,), -1, jnp.int32)
    if lay.kind == "xlstm":
        g, per = model_layout(cfg).groups, model_layout(cfg).per_group
        H = cfg.num_heads
        Ph = cfg.d_model // H
        c["mstate"] = jnp.zeros((g * (per - 1), B, H, Ph, Ph + 1),
                                jnp.float32)
        for k_ in ("sc", "sn", "sh", "sm"):
            c[k_] = jnp.zeros((g, B, H, Ph), jnp.float32)
    return c


def cache_specs(cfg: ModelConfig, mesh: Optional[Mesh],
                batch_size: int = 0) -> Params:
    if mesh is None:
        dummy = init_cache(cfg, 1, 8)
        return jax.tree_util.tree_map(lambda _: P(), dummy)
    dp = _dp_for(mesh, batch_size) if batch_size else _dp_axes(mesh)
    tpK = _tp(cfg, mesh, cfg.num_kv_heads)
    tpH = _tp(cfg, mesh, cfg.num_heads)
    tpHs = _tp(cfg, mesh, cfg.ssm_heads)
    lay = model_layout(cfg)
    c: Params = {"pos": P()}
    if lay.kind in ("uniform", "encdec", "zamba"):
        if resolve_kv_mode(cfg, mesh) == "sequence":
            c["k"] = P(None, dp, "model", None, None)
            c["v"] = P(None, dp, "model", None, None)
        else:
            c["k"] = P(None, dp, None, tpK, None)
            c["v"] = P(None, dp, None, tpK, None)
        c["kv_pos"] = P(None)
    if lay.kind == "encdec":
        c["ck"] = P(None, dp, None, tpH, None)
        c["cv"] = P(None, dp, None, tpH, None)
    if lay.kind == "zamba":
        c["conv_x"] = P(None, dp, None, tpHs)
        c["conv_B"] = P(None, dp, None, None)
        c["conv_C"] = P(None, dp, None, None)
        c["state"] = P(None, dp, tpHs, None, None)
    if lay.kind == "xlstm":
        c["mstate"] = P(None, dp, tpH, None, None)
        for k_ in ("sc", "sn", "sh", "sm"):
            c[k_] = P(None, dp, tpH, None)
    return c


# ------------------------------------------------------------------- model
class Model:
    """Pure-functional multi-architecture LM.  All public entry points take
    the params pytree explicitly and are jit/lower-able."""

    def __init__(self, cfg: ModelConfig, mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.layout = model_layout(cfg)

    # ------------------------------------------------------------ embedding
    def _embed(self, params: Params, batch: Dict[str, jax.Array]):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.num_patch_tokens:
            vis = batch["patches"].astype(x.dtype) @ params["vis_proj"]
            x = jnp.concatenate([vis, x], axis=1)
        x = _shard(x, self.mesh, _dp_for(self.mesh, x.shape[0]), None, None)
        return x

    # --------------------------------------------------------------- stacks
    def _encoder(self, params: Params, frames: jax.Array) -> jax.Array:
        """Whisper encoder on stub frame embeddings [B, Senc, d]."""
        cfg = self.cfg
        pos = jnp.arange(cfg.encoder_seq)
        x = frames.astype(_dtype(cfg)) @ params["frame_proj"]

        def body(carry, pl):
            x, aux = carry
            x, _, a = attn_mixer_seq(cfg, self.mesh, pl, x, pos,
                                     causal=False)
            return (x, aux + a), 0.0

        (x, _), _ = _lscan(body, (x, jnp.float32(0.0)),
                             params["encoder"])
        return x

    def _cross_kv_all(self, params: Params, enc_out: jax.Array):
        """Per-decoder-layer cross K/V, stacked [L,B,Senc,H,hd]."""
        bl = params["blocks"]
        ck = jnp.einsum("bsd,ldhk->lbshk", enc_out, bl["wk_x"])
        cv = jnp.einsum("bsd,ldhk->lbshk", enc_out, bl["wv_x"])
        return ck, cv

    def _seq_stack(self, params: Params, x: jax.Array, positions, *,
                   want_cache: bool, window: int, cross_kv=None,
                   remat: bool = False):
        """Run the full depth on a full sequence.  Returns
        (x, aux, cache_pieces dict of stacked ys)."""
        cfg, mesh, lay = self.cfg, self.mesh, self.layout

        if lay.kind in ("uniform", "encdec"):
            def body(carry, xs):
                x, aux = carry
                if cross_kv is not None:
                    pl, ckv = xs
                else:
                    pl, ckv = xs, None
                x, kv, a = attn_mixer_seq(
                    cfg, mesh, pl, x, positions, causal=True, window=window,
                    cross_kv=ckv, want_kv=want_cache)
                ys = {"k": kv[0], "v": kv[1]} if want_cache else {}
                return (x, aux + a), ys

            if remat:
                body = jax.checkpoint(body)
            xs = (params["blocks"], cross_kv) if cross_kv is not None \
                else params["blocks"]
            (x, aux), ys = _lscan(body, (x, jnp.float32(0.0)), xs)
            return x, aux, ys

        if lay.kind == "zamba":
            g, per = lay.groups, lay.per_group
            mam = jax.tree_util.tree_map(
                lambda a: a.reshape((g, per) + a.shape[1:]), params["mamba"])
            shared = params["shared_attn"]

            def inner(carry, pl):
                x = carry
                x, (convs, st) = mamba_mixer_seq(cfg, mesh, pl, x)
                ys = {"conv_x": convs[0], "conv_B": convs[1],
                      "conv_C": convs[2], "state": st} if want_cache else {}
                return x, ys

            def outer(carry, mg):
                x, aux = carry
                x, m_ys = _lscan(inner, x, mg)
                x, kv, a = attn_mixer_seq(
                    cfg, mesh, shared, x, positions, causal=True,
                    window=window, want_kv=want_cache)
                ys = dict(m_ys)
                if want_cache:
                    ys["k"], ys["v"] = kv
                return (x, aux + a), ys

            if remat:
                outer = jax.checkpoint(outer)
            (x, aux), ys = _lscan(outer, (x, jnp.float32(0.0)), mam)
            if want_cache:   # flatten [g, per, ...] -> [g*per, ...]
                for k_ in ("conv_x", "conv_B", "conv_C", "state"):
                    ys[k_] = ys[k_].reshape((-1,) + ys[k_].shape[2:])
            return x, aux, ys

        if lay.kind == "xlstm":
            g, per = lay.groups, lay.per_group
            ml = jax.tree_util.tree_map(
                lambda a: a.reshape((g, per - 1) + a.shape[1:]),
                params["mlstm"])

            def inner(carry, pl):
                x = carry
                x, st = mlstm_mixer_seq(cfg, mesh, pl, x)
                return x, ({"mstate": st} if want_cache else {})

            def outer(carry, xs):
                x, aux = carry
                mg, sl = xs
                x, m_ys = _lscan(inner, x, mg)
                x, sstate = slstm_mixer_seq(cfg, mesh, sl, x)
                ys = dict(m_ys)
                if want_cache:
                    ys["sc"], ys["sn"], ys["sh"], ys["sm"] = sstate
                return (x, aux), ys

            if remat:
                outer = jax.checkpoint(outer)
            (x, aux), ys = _lscan(outer, (x, jnp.float32(0.0)),
                                    (ml, params["slstm"]))
            if want_cache:
                ys["mstate"] = ys["mstate"].reshape(
                    (-1,) + ys["mstate"].shape[2:])
            return x, aux, ys

        raise ValueError(lay.kind)

    # ---------------------------------------------------------------- loss
    def _chunked_ce(self, params: Params, x: jax.Array, labels: jax.Array,
                    mask: jax.Array, chunk: int = 1024):
        """Cross-entropy without materialising [B,S,V]: scan over sequence
        chunks, projecting to the (model-sharded) vocab per chunk."""
        cfg = self.cfg
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        B, S, d = x.shape
        n = -(-S // chunk)
        pad = n * chunk - S
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)))
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        xc = x.reshape(B, n, chunk, d).swapaxes(0, 1)
        lc = labels.reshape(B, n, chunk).swapaxes(0, 1)
        mc = mask.reshape(B, n, chunk).swapaxes(0, 1)

        vmask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size

        def step(carry, inp):
            tot, cnt = carry
            xi, li, mi = inp
            logits = (xi @ head).astype(jnp.float32)
            logits = jnp.where(vmask, -1e30, logits)
            logits = _shard(logits, self.mesh,
                            _dp_for(self.mesh, logits.shape[0]), None,
                            _tp(cfg, self.mesh, cfg.padded_vocab))
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, li[..., None],
                                       axis=-1)[..., 0]
            nll = (lse - gold) * mi
            return (tot + nll.sum(), cnt + mi.sum()), None

        (tot, cnt), _ = _lscan(step, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (xc, lc, mc))
        return tot / jnp.maximum(cnt, 1.0)

    # ------------------------------------------------------------ training
    def loss_fn(self, params: Params, batch: Dict[str, jax.Array],
                remat: bool = True):
        """Next-token LM loss (+ MoE aux).  Returns (loss, metrics)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        B, S, _ = x.shape
        positions = jnp.arange(S)
        cross_kv = None
        if self.layout.kind == "encdec":
            enc = self._encoder(params, batch["frames"])
            ck, cv = self._cross_kv_all(params, enc)
            cross_kv = (ck, cv)
        x, aux, _ = self._seq_stack(params, x, positions, want_cache=False,
                                    window=cfg.sliding_window,
                                    cross_kv=cross_kv, remat=remat)
        x = rms_norm(x, params["final_norm"])
        tokens = batch["tokens"]
        n_text = tokens.shape[1]
        x_text = x[:, -n_text:]                      # skip patch positions
        labels = tokens[:, 1:]
        mask = jnp.ones_like(labels, jnp.float32)
        ce = self._chunked_ce(params, x_text[:, :-1], labels, mask)
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------- serving
    def prefill(self, params: Params, batch: Dict[str, jax.Array],
                cache_len: Optional[int] = None):
        """Run the prompt, build the decode cache, return last-token logits."""
        cfg = self.cfg
        x = self._embed(params, batch)
        B, S, _ = x.shape
        Sc = min(cache_len or S, cfg.sliding_window) if cfg.sliding_window \
            else (cache_len or S)
        positions = jnp.arange(S)
        cross_kv = None
        cache: Params = {"pos": jnp.int32(S)}
        if self.layout.kind == "encdec":
            enc = self._encoder(params, batch["frames"])
            ck, cv = self._cross_kv_all(params, enc)
            cross_kv = (ck, cv)
            cache["ck"], cache["cv"] = ck, cv
        x, aux, ys = self._seq_stack(params, x, positions, want_cache=True,
                                     window=cfg.sliding_window,
                                     cross_kv=cross_kv, remat=False)
        # ---- assemble the fixed-capacity cache from the per-layer ys
        if "k" in ys:
            k_full, v_full = ys["k"], ys["v"]        # [L,B,S,K,hd]
            if S >= Sc:     # keep the last Sc positions (sliding window)
                cache["k"] = k_full[:, :, S - Sc:]
                cache["v"] = v_full[:, :, S - Sc:]
                cache["kv_pos"] = positions[S - Sc:].astype(jnp.int32)
            else:
                pad = Sc - S
                cache["k"] = jnp.pad(k_full, ((0, 0), (0, 0), (0, pad),
                                              (0, 0), (0, 0)))
                cache["v"] = jnp.pad(v_full, ((0, 0), (0, 0), (0, pad),
                                              (0, 0), (0, 0)))
                cache["kv_pos"] = jnp.pad(positions.astype(jnp.int32),
                                          (0, pad), constant_values=-1)
        for k_ in ("conv_x", "conv_B", "conv_C", "state", "mstate",
                   "sc", "sn", "sh", "sm"):
            if k_ in ys:
                cache[k_] = ys[k_]
        x = rms_norm(x, params["final_norm"])
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        logits = (x[:, -1:] @ head).astype(jnp.float32)
        logits = jnp.where(jnp.arange(cfg.padded_vocab) >= cfg.vocab_size,
                           -1e30, logits)
        return logits[:, 0, :cfg.vocab_size], cache

    def decode_step(self, params: Params, cache: Params,
                    tokens: jax.Array):
        """One token for every sequence in the batch.  tokens [B] int32."""
        cfg, mesh, lay = self.cfg, self.mesh, self.layout
        pos = cache["pos"]
        x = jnp.take(params["embed"], tokens[:, None], axis=0)
        new_cache = dict(cache)
        aux_total = jnp.float32(0.0)

        if lay.kind in ("uniform", "encdec", "zamba"):
            Sc = cache["k"].shape[2]
            slot = (pos % Sc) if cfg.sliding_window else jnp.minimum(
                pos, Sc - 1)
            kv_pos = cache["kv_pos"].at[slot].set(pos)
            mask1 = (kv_pos >= 0) & (kv_pos <= pos)
            if cfg.sliding_window:
                mask1 &= kv_pos > pos - cfg.sliding_window
            B = x.shape[0]
            mask = jnp.broadcast_to(mask1[None], (B, Sc))
            new_cache["kv_pos"] = kv_pos

        if lay.kind in ("uniform", "encdec"):
            cross = None
            if lay.kind == "encdec":
                cross = (cache["ck"], cache["cv"])

            kv_mode = resolve_kv_mode(cfg, mesh)

            def body(carry, xs):
                x, aux = carry
                if cross is not None:
                    pl, kc, vc, ckl, cvl = xs
                    ckv = (ckl, cvl)
                else:
                    pl, kc, vc = xs
                    ckv = None
                x, kc, vc, a = attn_mixer_step(cfg, mesh, pl, x, kc, vc,
                                               mask, slot, pos, cross_kv=ckv,
                                               kv_mode=kv_mode)
                return (x, aux + a), {"k": kc, "v": vc}

            xs = (params["blocks"], cache["k"], cache["v"]) if cross is None \
                else (params["blocks"], cache["k"], cache["v"],
                      cache["ck"], cache["cv"])
            (x, aux_total), ys = _lscan(body, (x, aux_total), xs)
            new_cache["k"], new_cache["v"] = ys["k"], ys["v"]

        elif lay.kind == "zamba":
            g, per = lay.groups, lay.per_group
            mam = jax.tree_util.tree_map(
                lambda a: a.reshape((g, per) + a.shape[1:]), params["mamba"])
            st = {k_: cache[k_].reshape((g, per) + cache[k_].shape[1:])
                  for k_ in ("conv_x", "conv_B", "conv_C", "state")}
            shared = params["shared_attn"]

            def inner(carry, xs):
                x = carry
                pl, cx, cb, cc, s0 = xs
                x, (s1, convs) = mamba_mixer_step(cfg, mesh, pl, x, s0,
                                                  (cx, cb, cc))
                return x, {"conv_x": convs[0], "conv_B": convs[1],
                           "conv_C": convs[2], "state": s1}

            def outer(carry, xs):
                x, aux = carry
                mg, stg, kc, vc = xs
                x, m_ys = _lscan(
                    inner, x, (mg, stg["conv_x"], stg["conv_B"],
                               stg["conv_C"], stg["state"]))
                x, kc, vc, a = attn_mixer_step(
                    cfg, mesh, shared, x, kc, vc, mask, slot, pos,
                    kv_mode=resolve_kv_mode(cfg, mesh))
                m_ys["k"], m_ys["v"] = kc, vc
                return (x, aux + a), m_ys

            (x, aux_total), ys = _lscan(
                outer, (x, aux_total), (mam, st, cache["k"], cache["v"]))
            for k_ in ("conv_x", "conv_B", "conv_C", "state"):
                new_cache[k_] = ys[k_].reshape((-1,) + ys[k_].shape[2:])
            new_cache["k"], new_cache["v"] = ys["k"], ys["v"]

        elif lay.kind == "xlstm":
            g, per = lay.groups, lay.per_group
            ml = jax.tree_util.tree_map(
                lambda a: a.reshape((g, per - 1) + a.shape[1:]),
                params["mlstm"])
            mstate = cache["mstate"].reshape(
                (g, per - 1) + cache["mstate"].shape[1:])

            def inner(carry, xs):
                x = carry
                pl, s0 = xs
                x, s1 = mlstm_mixer_step(cfg, mesh, pl, x, s0)
                return x, {"mstate": s1}

            def outer(carry, xs):
                x, aux = carry
                mg, ms, sl, sst = xs
                x, m_ys = _lscan(inner, x, (mg, ms))
                x, s_new = slstm_mixer_step(cfg, mesh, sl, x, sst)
                m_ys.update({"sc": s_new[0], "sn": s_new[1],
                             "sh": s_new[2], "sm": s_new[3]})
                return (x, aux), m_ys

            sstates = (cache["sc"], cache["sn"], cache["sh"], cache["sm"])
            (x, aux_total), ys = _lscan(
                outer, (x, aux_total),
                (ml, mstate, params["slstm"], sstates))
            new_cache["mstate"] = ys["mstate"].reshape(
                (-1,) + ys["mstate"].shape[2:])
            for k_ in ("sc", "sn", "sh", "sm"):
                new_cache[k_] = ys[k_]

        x = rms_norm(x, params["final_norm"])
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        logits = (x @ head).astype(jnp.float32)[:, 0]
        logits = jnp.where(jnp.arange(cfg.padded_vocab) >= cfg.vocab_size,
                           -1e30, logits)
        new_cache["pos"] = pos + 1
        return logits[:, :cfg.vocab_size], new_cache
