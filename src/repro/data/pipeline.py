"""Deterministic synthetic LM data pipeline.

Generates a learnable Markov-chain token stream (so the e2e training example
actually shows loss going down, not just noise) with per-step deterministic
seeding — every data-parallel host can regenerate its shard independently,
which is how the pipeline scales to the multi-pod mesh without a central
loader.  Also provides the modality-stub inputs (patch/frame embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SyntheticLMDataset:
    cfg: ModelConfig
    batch_size: int
    seq_len: int
    seed: int = 0
    order: int = 2        # markov order

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = self.cfg.vocab_size
        # sparse-ish transition table over a reduced state space
        self.n_states = min(V, 997)
        self.trans = rng.integers(0, V, size=(self.n_states, 8))

    def _tokens(self, rng, B, S):
        V = self.cfg.vocab_size
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = rng.integers(0, V, B)
        choice = rng.integers(0, 8, size=(B, S))
        noise = rng.random((B, S))
        rand_tok = rng.integers(0, V, size=(B, S))
        for t in range(1, S):
            nxt = self.trans[toks[:, t - 1] % self.n_states, choice[:, t]]
            toks[:, t] = np.where(noise[:, t] < 0.1, rand_tok[:, t], nxt)
        return toks.astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        n_text = self.seq_len - (cfg.num_patch_tokens or 0)
        out: Dict[str, np.ndarray] = {
            "tokens": self._tokens(rng, self.batch_size, n_text)}
        if cfg.num_patch_tokens:
            out["patches"] = rng.standard_normal(
                (self.batch_size, cfg.num_patch_tokens, cfg.frontend_dim)
            ).astype(np.float32)
        if cfg.arch_type == "audio":
            out["frames"] = rng.standard_normal(
                (self.batch_size, cfg.encoder_seq, cfg.frontend_dim)
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_batch_specs(cfg: ModelConfig, batch_size: int, seq_len: int,
                     dtype="float32") -> Dict[str, "jax.ShapeDtypeStruct"]:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    import jax
    import jax.numpy as jnp
    n_text = seq_len - (cfg.num_patch_tokens or 0)
    specs = {"tokens": jax.ShapeDtypeStruct((batch_size, n_text), jnp.int32)}
    if cfg.num_patch_tokens:
        specs["patches"] = jax.ShapeDtypeStruct(
            (batch_size, cfg.num_patch_tokens, cfg.frontend_dim),
            jnp.float32)
    if cfg.arch_type == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch_size, cfg.encoder_seq, cfg.frontend_dim), jnp.float32)
    return specs
