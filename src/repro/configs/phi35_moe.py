"""Phi-3.5-MoE-instruct: 42B total / 6.6B active, 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct]"""
from .base import ModelConfig, register, register_smoke

CFG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b", arch_type="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=6400, vocab_size=32064,
    num_experts=16, experts_per_token=2,
    rope_theta=10_000.0,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
))
register_smoke(CFG)
