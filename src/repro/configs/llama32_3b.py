"""Llama-3.2-3B: small llama3. [hf:meta-llama/Llama-3.2-1B]"""
from .base import ModelConfig, register, register_smoke

CFG = register(ModelConfig(
    name="llama3.2-3b", arch_type="dense",
    num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab_size=128256,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-1B",
))
register_smoke(CFG)
