"""xLSTM-350M: mLSTM + sLSTM blocks, no separate FFN (d_ff=0).
[arXiv:2405.04517]"""
from .base import ModelConfig, register, register_smoke

CFG = register(ModelConfig(
    name="xlstm-350m", arch_type="ssm",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    source="arXiv:2405.04517",
))
register_smoke(CFG, num_layers=6, d_ff=0)
