"""Model configuration system.  One file per assigned architecture registers
its exact full-size config plus a ``smoke`` reduced variant (≤2 layers,
d_model ≤ 512, ≤4 experts) used by CPU tests.  ``--arch <id>`` in the
launchers resolves through ``get_config``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Tuple

_REGISTRY: Dict[str, "ModelConfig"] = {}

ARCH_IDS = [
    "phi3.5-moe-42b-a6.6b", "llama3.2-3b", "internvl2-1b", "qwen2-7b",
    "granite-moe-1b-a400m", "zamba2-2.7b", "phi3-medium-14b",
    "whisper-large-v3", "glm4-9b", "xlstm-350m",
]
_MODULES = {
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "llama3.2-3b": "llama32_3b",
    "internvl2-1b": "internvl2_1b",
    "qwen2-7b": "qwen2_7b",
    "granite-moe-1b-a400m": "granite_moe",
    "zamba2-2.7b": "zamba2_27b",
    "phi3-medium-14b": "phi3_medium",
    "whisper-large-v3": "whisper_large_v3",
    "glm4-9b": "glm4_9b",
    "xlstm-350m": "xlstm_350m",
    # the paper's own CNN models live in repro.graphs (graph IR, not the
    # transformer ModelConfig system)
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # block layout: mixer kind per layer; built by helpers below
    block_pattern: Tuple[str, ...] = ()   # 'attn'|'mamba'|'mlstm'|'slstm'|'shared_attn'

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # SSM / recurrent
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    conv_width: int = 4
    mlstm_proj_factor: int = 2

    # attention details
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    sliding_window: int = 0        # 0 = full attention
    attn_chunk: int = 512
    # decode KV-cache sharding over the model axis:
    #   "auto"     -> "heads" when kv_heads divide the TP size, else "sequence"
    #   "heads"    -> shard KV heads (replicate when not divisible — the
    #                 naive baseline; can exceed HBM at 32k×128)
    #   "sequence" -> shard the cache sequence axis; decode attention runs as
    #                 flash-decoding partial-softmax + merge over 'model'
    kv_mode: str = "auto"
    # full-sequence (train/prefill) activation sharding over the model axis:
    #   "tp" -> Megatron tensor parallelism (heads/ffn sharded, per-layer
    #           activation all-reduce)
    #   "cp" -> context parallelism: sequence sharded over 'model', weights
    #           FSDP-gathered per layer, K/V all-gathered (cheap for small
    #           GQA kv) — §Perf iteration for collective-bound prefill
    act_shard: str = "tp"
    # MoE expert-weight FSDP over 'data': True gathers experts per layer
    # (fwd + remat'd bwd); False stores experts model-sharded only and lets
    # the OPTIMIZER states stay fsdp-sharded (ZeRO-1) — §Perf iteration
    moe_fsdp: bool = True

    # encoder-decoder (audio) / vlm frontend
    encoder_layers: int = 0
    encoder_seq: int = 0           # precomputed frame embeddings (stub)
    num_patch_tokens: int = 0
    frontend_dim: int = 0          # embedding dim delivered by the stub

    dtype: str = "bfloat16"
    norm: str = "rms"              # rms | layer
    tie_embeddings: bool = False
    source: str = ""               # citation (paper / model card)

    # ------------------------------------------------------------ derived
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/logits tables are padded to a multiple of 128 for TP
        divisibility and lane alignment; padded logits are masked to -inf.
        The LOGICAL vocab (tokens, labels, losses) stays exact."""
        return -(-self.vocab_size // 128) * 128

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.block_pattern:
            return self.block_pattern
        if self.arch_type == "hybrid":     # zamba: mamba backbone + shared
            g = self.num_layers // 6       # attn applied after each group
            return ("mamba",) * self.num_layers + ("shared_attn",) * 0 \
                if g == 0 else ("mamba",) * self.num_layers
        if self.arch_type == "ssm":        # xlstm: groups of 5 mLSTM+1 sLSTM
            g = self.num_layers // 6
            return (("mlstm",) * 5 + ("slstm",)) * g
        return ("attn",) * self.num_layers

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_sliding_window(self, window: int = 8192) -> "ModelConfig":
        return self.replace(sliding_window=window)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim_
        n = V * d                      # embedding
        if not self.tie_embeddings:
            n += d * V                 # lm head
        for kind in self.pattern:
            if kind in ("attn", "shared_attn"):
                attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                    + self.num_heads * hd * d
                if kind == "attn":
                    n += attn
                # shared_attn params counted once (outside the loop)
                if self.is_moe:
                    n += d * self.num_experts \
                        + self.num_experts * 3 * d * ff
                elif ff:
                    n += 3 * d * ff
            elif kind == "mamba":
                di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
                n += d * (2 * di + 2 * N + H) + di * d + 3 * H
            elif kind in ("mlstm", "slstm"):
                n += 4 * d * d + 2 * d * self.num_heads
        if self.arch_type == "hybrid":     # shared attn block params, once
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                + self.num_heads * hd * d
            n += attn + 3 * d * ff
        if self.encoder_layers:
            n += self.encoder_layers * (4 * d * d + 3 * d * ff)
            n += self.num_layers * (4 * d * d)   # cross attention
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_every = self.param_count() - len(self.pattern) \
            * self.num_experts * 3 * d * ff
        return dense_every + len(self.pattern) \
            * self.experts_per_token * 3 * d * ff


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    base, _, variant = name.partition("@")
    if base not in _REGISTRY:
        mod = _MODULES.get(base)
        if mod is None:
            raise KeyError(f"unknown architecture {base!r};"
                           f" known: {sorted(_MODULES)}")
        importlib.import_module(f"repro.configs.{mod}")
    cfg = _REGISTRY[base]
    if variant == "smoke":
        cfg = _REGISTRY[f"{base}@smoke"]
    elif variant:
        raise KeyError(f"unknown variant {variant!r}")
    return cfg


def register_smoke(base: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family variant: ≤2 layers, d_model ≤ 512, ≤4 experts."""
    kw = dict(
        name=f"{base.name}@smoke",
        num_layers=2,
        d_model=min(base.d_model, 256),
        num_heads=4,
        num_kv_heads=min(base.num_kv_heads, 2),
        d_ff=min(base.d_ff, 512) if base.d_ff else 0,
        vocab_size=512,
        head_dim=0,
        num_experts=min(base.num_experts, 4),
        experts_per_token=min(base.experts_per_token, 2),
        ssm_state=min(base.ssm_state, 16) if base.ssm_state else 0,
        ssm_head_dim=16 if base.ssm_state else 64,
        encoder_layers=2 if base.encoder_layers else 0,
        encoder_seq=16 if base.encoder_seq else 0,
        num_patch_tokens=8 if base.num_patch_tokens else 0,
        frontend_dim=64 if base.frontend_dim else 0,
        attn_chunk=16,
        dtype="float32",
    )
    kw.update(overrides)
    if base.block_pattern and "block_pattern" not in overrides:
        kw["block_pattern"] = base.block_pattern[:kw["num_layers"]]
    return register(base.replace(**kw))
