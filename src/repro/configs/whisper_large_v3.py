"""Whisper-large-v3: encoder-decoder; mel+conv frontend is a STUB delivering
frame embeddings [B, 1500, d_model]. [arXiv:2212.04356]"""
from .base import ModelConfig, register, register_smoke

CFG = register(ModelConfig(
    name="whisper-large-v3", arch_type="audio",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866,
    encoder_layers=32, encoder_seq=1500, frontend_dim=1280,
    rope_theta=10_000.0,
    source="arXiv:2212.04356",
))
register_smoke(CFG, num_kv_heads=4)
