"""Qwen2-7B: GQA with QKV bias. [arXiv:2407.10671]"""
from .base import ModelConfig, register, register_smoke

CFG = register(ModelConfig(
    name="qwen2-7b", arch_type="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, rope_theta=1_000_000.0,
    source="arXiv:2407.10671",
))
register_smoke(CFG)
