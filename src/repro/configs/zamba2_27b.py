"""Zamba2-2.7B: Mamba2 backbone + shared attention block every 6 layers.
[arXiv:2411.15242]"""
from .base import ModelConfig, register, register_smoke

CFG = register(ModelConfig(
    name="zamba2-2.7b", arch_type="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    rope_theta=10_000.0,
    source="arXiv:2411.15242",
))
register_smoke(CFG, num_layers=6, d_model=128, num_heads=4, num_kv_heads=4,
               ssm_head_dim=16)
