from .base import ARCH_IDS, ModelConfig, get_config, register

__all__ = ["ARCH_IDS", "ModelConfig", "get_config", "register"]
