"""Granite-3.0-1B-A400M: 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from .base import ModelConfig, register, register_smoke

CFG = register(ModelConfig(
    name="granite-moe-1b-a400m", arch_type="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    num_experts=32, experts_per_token=8,
    rope_theta=10_000.0,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
register_smoke(CFG)
