"""InternVL2-1B: InternViT vision encoder (STUB -> patch embeddings) +
Qwen2-0.5B-class language decoder. [arXiv:2404.16821]"""
from .base import ModelConfig, register, register_smoke

CFG = register(ModelConfig(
    name="internvl2-1b", arch_type="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655,
    qkv_bias=True, rope_theta=1_000_000.0,
    num_patch_tokens=256, frontend_dim=1024,
    source="arXiv:2404.16821",
))
register_smoke(CFG)
