"""GLM-4-9B: RoPE, aggressive GQA (kv=2). [hf:THUDM/glm-4-9b]"""
from .base import ModelConfig, register, register_smoke

CFG = register(ModelConfig(
    name="glm4-9b", arch_type="dense",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=151552,
    rope_theta=10_000.0,
    source="hf:THUDM/glm-4-9b",
))
register_smoke(CFG)
