"""Phi-3-medium-14B: RoPE SwiGLU GQA. [arXiv:2404.14219]"""
from .base import ModelConfig, register, register_smoke

CFG = register(ModelConfig(
    name="phi3-medium-14b", arch_type="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
    d_ff=17920, vocab_size=100352,
    rope_theta=10_000.0,
    source="arXiv:2404.14219",
))
register_smoke(CFG)
