"""AdamW with decoupled weight decay, cosine schedule and global-norm
clipping — pure JAX (no optax dependency), pytree-generic.

Moments are kept in f32 regardless of the parameter dtype (bf16 training):
the update is computed in f32 and cast back, the standard mixed-precision
recipe.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree_util.tree_map(zeros, params),
                      nu=jax.tree_util.tree_map(zeros, params))


def cosine_lr(step: jax.Array, *, peak: float, warmup: int,
              total: int, floor: float = 0.1) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = peak * step / max(warmup, 1)
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup, warm, peak * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(params, grads, state: AdamWState, *, lr: jax.Array,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + eps)
        # decoupled decay only on >=2D tensors (skip norms/biases)
        if p.ndim >= 2:
            delta = delta + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
