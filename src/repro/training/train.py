"""Training step: loss + grad + AdamW, with optional gradient-accumulation
microbatching and remat (activation checkpointing) inside the model stack.
``make_train_step`` returns a function suitable for jax.jit with sharded
in/out specs (built by the launcher).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model, init_params
from .optimizer import AdamWState, adamw_init, adamw_update, cosine_lr


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def train_state_init(cfg: ModelConfig, key: jax.Array) -> TrainState:
    params = init_params(cfg, key)
    return TrainState(params=params, opt=adamw_init(params))


def make_train_step(model: Model, *, peak_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000,
                    microbatches: int = 1, remat: bool = True
                    ) -> Callable[[TrainState, Dict[str, jax.Array]], Any]:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return model.loss_fn(params, batch, remat=remat)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            def micro(b):
                return jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, b)

            split = jax.tree_util.tree_map(
                lambda a: a.reshape((microbatches,
                                     a.shape[0] // microbatches)
                                    + a.shape[1:]), batch)

            def acc_step(carry, mb):
                (l_sum, g_sum) = carry
                (loss, m), g = micro(mb)
                g_sum = jax.tree_util.tree_map(jnp.add, g_sum, g)
                return (l_sum + loss, g_sum), m

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), ms = jax.lax.scan(
                acc_step, (jnp.float32(0.0), zeros), split)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            metrics = jax.tree_util.tree_map(lambda a: a.mean(), ms)

        lr = cosine_lr(state.opt.step, peak=peak_lr, warmup=warmup,
                       total=total_steps)
        params, opt, om = adamw_update(state.params, grads, state.opt, lr=lr)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        metrics["lr"] = lr
        return TrainState(params, opt), metrics

    return train_step
