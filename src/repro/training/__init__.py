from .optimizer import AdamWState, adamw_init, adamw_update, cosine_lr
from .train import TrainState, make_train_step, train_state_init

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_lr",
           "TrainState", "make_train_step", "train_state_init"]
