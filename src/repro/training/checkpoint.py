"""Flat-npz checkpointing for params/optimizer pytrees.

Tree leaves are flattened to ``path/to/leaf`` keys; restore rebuilds into a
template pytree (shape/dtype checked).  Device-local: on a real multi-host
pod each host saves its addressable shards (we save the fully-addressable
arrays here, which is exact on single-host and the CPU test rig).
"""
from __future__ import annotations

import os
from typing import Dict

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, tree, step: int) -> str:
    os.makedirs(path, exist_ok=True)
    fname = os.path.join(path, f"ckpt_{step:08d}.npz")
    tmp = fname + ".tmp"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, fname)
    return fname


def latest_checkpoint(path: str):
    if not os.path.isdir(path):
        return None
    files = sorted(f for f in os.listdir(path)
                   if f.startswith("ckpt_") and f.endswith(".npz"))
    return os.path.join(path, files[-1]) if files else None


def restore_checkpoint(fname: str, template):
    data = np.load(fname)
    flat, tdef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in data:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
