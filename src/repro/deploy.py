"""``repro.deploy`` — the one-call deployment facade.

Every consumer of the paper pipeline used to hand-roll the same four-step
chain::

    res  = schedule(graph, arena_budget=..., partition=...)
    g    = res.graph if res.graph is not None else graph
    plan = ArenaPlanner.plan(g, res.schedule)
    ArenaPlanner.validate(plan, g)
    ex   = compile_schedule(g, res.schedule, plan, use_pallas=...)

duplicated (with drift) across the serving engines, the benchmarks, the
examples and the tests.  ``build()`` is that chain as one call returning a
``Deployment`` — the documented way to go from a graph to something that
runs::

    import repro.deploy as deploy

    d = deploy.build(graph, arena_budget=256 * 1024)
    out = d.run({"input": x})            # one request
    outs = d.serve(requests)             # micro-batched engine
    d.stats.arena_bytes                  # typed, not stringly-keyed

The raw ``schedule()``/``ArenaPlanner``/``compile_schedule`` chain stays
importable and supported — ``build`` adds no semantics on top of it, so
anything the facade can express the chain can too (and vice versa; the
facade is for the 95% path).

``quantize=True`` accepts a *float* graph and post-training-quantizes it
first (``graphs/quantize.py``); the returned deployment carries the
``QuantizedModel`` so callers can ``d.quantize_inputs(...)`` /
``d.dequantize_outputs(...)`` at the edges while ``run``/``serve`` keep
the honest int8 dtype contract inside.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

from repro.core import ArenaPlanner, schedule as _schedule
from repro.core.allocator import ArenaPlan
from repro.core.graph import Graph, Operator
from repro.core.scheduler import ScheduleResult
from repro.mcu.compile import CompiledExecutor, compile_schedule


@dataclasses.dataclass
class Deployment:
    """A graph scheduled, planned, validated and compiled — ready to run.

    ``graph`` is the graph the caller handed in; ``exec_graph`` is the one
    the schedule's operators belong to (a Pex/cascade rewrite, or the int8
    rewrite under ``quantize=True`` — the same graph when no rewrite
    fired).  ``schedule`` is the operator order, ``plan`` the validated
    arena plan the executor runs against.
    """

    graph: Graph                          # as passed to build()
    exec_graph: Graph                     # what the schedule executes
    schedule_result: ScheduleResult
    plan: ArenaPlan
    executor: CompiledExecutor
    qmodel: Optional[object] = None       # QuantizedModel when quantize=True

    @property
    def schedule(self) -> List[Operator]:
        return self.schedule_result.schedule

    @property
    def arena_bytes(self) -> int:
        return int(self.plan.arena_size)

    # ------------------------------------------------------------- running
    def run(self, inputs: Dict[str, Any], as_numpy: bool = True
            ) -> Dict[str, Any]:
        """One request through the compiled arena program."""
        return self.executor.run(inputs, as_numpy=as_numpy)

    def serve(self, requests: Sequence[Dict[str, Any]], *,
              micro_batch: int = 8) -> List[Dict[str, Any]]:
        """Micro-batched one-shot serve (single device).  For sharded
        continuous batching build an engine with ``engine(...)``."""
        return self.engine(micro_batch=micro_batch).serve(requests)

    def engine(self, *, micro_batch: int = 8, replicas: Optional[int] = None,
               **kw):
        """A serving engine over this deployment.  ``replicas=None`` gives
        the single-device micro-batching ``GraphServingEngine``; any other
        value the sharded continuous-batching ``ShardedServingEngine``
        (``replicas=0`` = one replica per visible device)."""
        if replicas is None:
            from repro.serving.engine import GraphServingEngine
            return GraphServingEngine(deployment=self,
                                      micro_batch=micro_batch, **kw)
        from repro.serving.sharded import ShardedServingEngine
        return ShardedServingEngine(self, replicas=replicas or None,
                                    lanes=micro_batch, **kw)

    # ------------------------------------------------------------- stats
    @property
    def stats(self):
        """Deployment-level ``EngineStats`` (schedule/arena fields; the
        serve-level fields belong to an engine's ``.stats``)."""
        from repro.serving.stats import EngineStats
        return EngineStats(
            arena_bytes=self.arena_bytes,
            schedule_peak_bytes=int(self.schedule_result.peak),
            schedule_method=self.schedule_result.method)

    # --------------------------------------------------- quantized edges
    def quantize_inputs(self, inputs: Dict[str, Any]) -> Dict[str, Any]:
        if self.qmodel is None:
            return inputs
        return self.qmodel.quantize_inputs(inputs)

    def dequantize_outputs(self, outputs: Dict[str, Any]) -> Dict[str, Any]:
        if self.qmodel is None:
            return outputs
        return self.qmodel.dequantize_outputs(outputs)


def build(graph: Graph, *, arena_budget: Optional[int] = None,
          quantize: bool = False, calibration=None,
          use_pallas: bool = False, objective: str = "memory",
          partition: bool = False, macs_cap: Optional[float] = None,
          fuse: bool = False, **schedule_opts) -> Deployment:
    """schedule → plan → validate → compile, one call.

    * ``arena_budget`` — target arena bytes; the scheduler escalates
      reorder → Pex → cascaded streaming until it fits (or returns its
      best effort — check ``d.arena_bytes``).
    * ``quantize`` — post-training-quantize a float graph to int8 first
      (``calibration``: input dict(s); default = deterministic synthetic).
    * ``use_pallas`` — route int8 convs through the fused Pallas kernels
      (bit-identical; DESIGN.md §9).
    * ``objective`` — ``"memory"`` (lowest peak) or ``"latency"``
      (cheapest in-budget schedule; needs ``arena_budget``).
    * ``macs_cap`` — max halo-recompute extra-MACs fraction.
    * extra keyword arguments are forwarded to ``core.schedule()``.
    """
    qmodel = None
    if quantize:
        from repro.graphs import quantize_graph
        qmodel = quantize_graph(graph, calibration)
        graph = qmodel.graph
    res = _schedule(graph, arena_budget=arena_budget, partition=partition,
                    objective=objective, macs_cap=macs_cap,
                    **schedule_opts)
    exec_graph = res.graph if res.graph is not None else graph
    plan = ArenaPlanner.plan(exec_graph, res.schedule)
    ArenaPlanner.validate(plan, exec_graph)
    executor = compile_schedule(exec_graph, res.schedule, plan,
                                use_pallas=use_pallas, fuse=fuse)
    return Deployment(graph=graph, exec_graph=exec_graph,
                      schedule_result=res, plan=plan, executor=executor,
                      qmodel=qmodel)


__all__ = ["Deployment", "build"]
