"""``repro.deploy`` — the one-call deployment facade.

Every consumer of the paper pipeline used to hand-roll the same four-step
chain::

    res  = schedule(graph, arena_budget=..., partition=...)
    g    = res.graph if res.graph is not None else graph
    plan = ArenaPlanner.plan(g, res.schedule)
    ArenaPlanner.validate(plan, g)
    ex   = compile_schedule(g, res.schedule, plan, use_pallas=...)

duplicated (with drift) across the serving engines, the benchmarks, the
examples and the tests.  ``build()`` is that chain as one call returning a
``Deployment`` — the documented way to go from a graph to something that
runs::

    import repro.deploy as deploy

    d = deploy.build(graph, arena_budget=256 * 1024)
    out = d.run({"input": x})            # one request
    outs = d.serve(requests)             # micro-batched engine
    d.stats.arena_bytes                  # typed, not stringly-keyed

The raw ``schedule()``/``ArenaPlanner``/``compile_schedule`` chain stays
importable and supported — ``build`` adds no semantics on top of it, so
anything the facade can express the chain can too (and vice versa; the
facade is for the 95% path).

``quantize=True`` accepts a *float* graph and post-training-quantizes it
first (``graphs/quantize.py``); the returned deployment carries the
``QuantizedModel`` so callers can ``d.quantize_inputs(...)`` /
``d.dequantize_outputs(...)`` at the edges while ``run``/``serve`` keep
the honest int8 dtype contract inside.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import ArenaPlanner, schedule as _schedule
from repro.core.allocator import ArenaPlan
from repro.core.graph import Graph, Operator
from repro.core.scheduler import ScheduleResult
from repro.errors import (BudgetUnreachableError, DeploymentError,
                          InputValidationError, NaNActivationError)
from repro.mcu.compile import (_JNP_DTYPES, CompiledExecutor,
                               compile_schedule)

# Graceful-degradation ladder (strict=False): each entry is the rung set
# handed to ``core.schedule(rungs=...)``; ``None`` = the full ladder.  When
# a rung set fails (a rewrite crashes, a plan fails validation, a lowering
# refuses to compile), build() drops to the next entry — progressively
# disabling the most intricate rewrites first (2-D tiles, then ring
# cascades, then whole-externals Pex) until only plain reordering is left.
# Reordering cannot be disabled: it is the identity fallback.
_FALLBACK_RUNGS: Tuple[Optional[Tuple[str, ...]], ...] = (
    None,
    ("reorder", "pex", "cascade", "solver"),
    ("reorder", "pex", "solver"),
    ("reorder",),
)


@dataclasses.dataclass
class Deployment:
    """A graph scheduled, planned, validated and compiled — ready to run.

    ``graph`` is the graph the caller handed in; ``exec_graph`` is the one
    the schedule's operators belong to (a Pex/cascade rewrite, or the int8
    rewrite under ``quantize=True`` — the same graph when no rewrite
    fired).  ``schedule`` is the operator order, ``plan`` the validated
    arena plan the executor runs against.
    """

    graph: Graph                          # as passed to build()
    exec_graph: Graph                     # what the schedule executes
    schedule_result: ScheduleResult
    plan: ArenaPlan
    executor: CompiledExecutor
    qmodel: Optional[object] = None       # QuantizedModel when quantize=True
    # failure layer (DESIGN.md §12): what build(strict=False) gave up on —
    # [] means nothing degraded; each note names the rung/budget and why
    degraded: List[str] = dataclasses.field(default_factory=list)
    guard_bytes: int = 0                  # canary width planned (0 = off)

    @property
    def schedule(self) -> List[Operator]:
        return self.schedule_result.schedule

    @property
    def arena_bytes(self) -> int:
        return int(self.plan.arena_size)

    # ------------------------------------------------------------ validation
    def validate_inputs(self, inputs: Dict[str, Any]) -> None:
        """Reject malformed request inputs with a typed
        ``InputValidationError`` *before* they reach the arena.

        The executor's own ``make_arena`` checks are narrower than they
        look: ``jnp.asarray`` silently downcasts float64 → float32 before
        the dtype check fires, and the element-count check silently accepts
        any wrong *shape* with the right flat size.  On an MCU deployment
        both are wrong-answer factories, so the facade checks name, shape,
        dtype, finiteness, and the int8 quantization domain up front."""
        g = self.executor.graph
        if not isinstance(inputs, dict):
            raise InputValidationError(
                f"inputs must be a dict of tensor name -> array, got "
                f"{type(inputs).__name__}")
        needed = {c for c in g.constants() if g.consumers(c)}
        missing = needed - set(inputs)
        if missing:
            raise InputValidationError(
                f"missing graph inputs: {sorted(missing)}")
        for name, value in inputs.items():
            if name not in g.tensors:
                raise InputValidationError(
                    f"unknown input tensor {name!r}; graph inputs are "
                    f"{sorted(needed)}")
            if g.producer(name) is not None:
                raise InputValidationError(
                    f"{name!r} is produced by operator "
                    f"{g.producer(name).name!r}, not a graph input")
            t = g.tensors[name]
            val = np.asarray(value)
            want = np.dtype(_JNP_DTYPES[t.dtype]) if t.dtype != "bfloat16" \
                else None
            if want is not None and val.dtype != want:
                hint = ""
                if t.dtype == "int8":
                    hint = (" — int8 graphs take quantized inputs in "
                            "[-128, 127]; use d.quantize_inputs(...) at "
                            "the float edge")
                raise InputValidationError(
                    f"input {name!r} is {val.dtype}, graph declares "
                    f"{t.dtype}{hint}")
            shape = tuple(t.shape) if t.shape else (t.elements,)
            if tuple(val.shape) != shape and val.size == t.elements:
                raise InputValidationError(
                    f"input {name!r} has shape {tuple(val.shape)}, graph "
                    f"declares {shape} (same element count — refusing the "
                    f"silent flatten)")
            if val.size != t.elements:
                raise InputValidationError(
                    f"input {name!r} has {val.size} elements, graph "
                    f"declares {t.elements} (shape {shape})")
            if val.dtype.kind == "f" and not np.isfinite(val).all():
                raise InputValidationError(
                    f"input {name!r} contains non-finite values (NaN/Inf "
                    f"poison every downstream activation)")

    # ------------------------------------------------------------- running
    def run(self, inputs: Dict[str, Any], as_numpy: bool = True, *,
            validate: bool = True, faults=None) -> Dict[str, Any]:
        """One request through the compiled arena program.

        ``validate=True`` (default) runs ``validate_inputs`` first —
        malformed requests raise ``InputValidationError`` instead of being
        silently cast/flattened.  ``faults`` (a ``serving.FaultPlan`` or
        ``FaultInjector``; test-only) exercises the one-shot path under the
        same fault taxonomy as the engines: transient device errors are
        retried, corruption is surfaced by the guard canaries
        (``GuardViolation``) and NaN poison by a genuine output scan
        (``NaNActivationError``) — never returned as an answer."""
        if validate:
            self.validate_inputs(inputs)
        ex = self.executor
        if faults is None:
            return ex.run(inputs, as_numpy=as_numpy)
        from repro.serving.faults import (FaultInjector, FaultPlan,
                                          dispatch_with_retry)
        inj = FaultInjector(faults) if isinstance(faults, FaultPlan) \
            else faults
        arena, _retried, _trips = dispatch_with_retry(
            lambda: ex.fn(ex.make_arena(inputs)), faults=inj)
        a = np.array(arena)   # writable host copy: never mutate jax buffers
        if inj.corrupt_lanes(1):
            inj.corrupt_arena(a, ex.guard_regions)
        ex.verify_guards(a)                    # raises GuardViolation
        if inj.nan_lanes(1):
            inj.inject_nan(a, ex)
        out = ex.outputs_from(a, as_numpy=True)
        for name, val in out.items():
            arr = np.asarray(val)
            if arr.dtype.kind == "f" and np.isnan(arr).any():
                raise NaNActivationError(
                    f"output {name!r} contains NaN activations")
        return out

    def serve(self, requests: Sequence[Dict[str, Any]], *,
              micro_batch: int = 8) -> List[Dict[str, Any]]:
        """Micro-batched one-shot serve (single device).  For sharded
        continuous batching build an engine with ``engine(...)``."""
        return self.engine(micro_batch=micro_batch).serve(requests)

    def engine(self, *, micro_batch: int = 8, replicas: Optional[int] = None,
               **kw):
        """A serving engine over this deployment.  ``replicas=None`` gives
        the single-device micro-batching ``GraphServingEngine``; any other
        value the sharded continuous-batching ``ShardedServingEngine``
        (``replicas=0`` = one replica per visible device)."""
        if replicas is None:
            from repro.serving.engine import GraphServingEngine
            return GraphServingEngine(deployment=self,
                                      micro_batch=micro_batch, **kw)
        from repro.serving.sharded import ShardedServingEngine
        return ShardedServingEngine(self, replicas=replicas or None,
                                    lanes=micro_batch, **kw)

    # ------------------------------------------------------------- stats
    @property
    def stats(self):
        """Deployment-level ``EngineStats`` (schedule/arena fields; the
        serve-level fields belong to an engine's ``.stats``)."""
        from repro.serving.stats import EngineStats
        return EngineStats(
            arena_bytes=self.arena_bytes,
            schedule_peak_bytes=int(self.schedule_result.peak),
            schedule_method=self.schedule_result.method)

    # --------------------------------------------------- quantized edges
    def quantize_inputs(self, inputs: Dict[str, Any]) -> Dict[str, Any]:
        if self.qmodel is None:
            return inputs
        return self.qmodel.quantize_inputs(inputs)

    def dequantize_outputs(self, outputs: Dict[str, Any]) -> Dict[str, Any]:
        if self.qmodel is None:
            return outputs
        return self.qmodel.dequantize_outputs(outputs)


def build(graph: Graph, *, arena_budget: Optional[int] = None,
          quantize: bool = False, calibration=None,
          use_pallas: bool = False, objective: str = "memory",
          partition: bool = False, macs_cap: Optional[float] = None,
          fuse: bool = False, strict: bool = True, guard_bytes: int = 0,
          **schedule_opts) -> Deployment:
    """schedule → plan → validate → compile, one call.

    * ``arena_budget`` — target arena bytes; the scheduler escalates
      reorder → Pex → cascaded streaming until it fits.  ``strict=True``
      (default) raises ``BudgetUnreachableError`` on a miss;
      ``strict=False`` deploys best-effort with the miss recorded in
      ``Deployment.degraded``.
    * ``quantize`` — post-training-quantize a float graph to int8 first
      (``calibration``: input dict(s); default = deterministic synthetic).
    * ``use_pallas`` — route int8 convs through the fused Pallas kernels
      (bit-identical; DESIGN.md §9).
    * ``objective`` — ``"memory"`` (lowest peak) or ``"latency"``
      (cheapest in-budget schedule; needs ``arena_budget``).
    * ``macs_cap`` — max halo-recompute extra-MACs fraction.
    * ``strict=False`` — graceful degradation: when a scheduler rung fails
      (a rewrite crashes, a plan fails validation, a lowering refuses to
      compile), fall back through progressively simpler rung sets
      (cascade2d → cascade → pex → reorder) instead of raising; every
      fallback and budget miss is a note in ``Deployment.degraded``.
      Only when *every* rung set fails does ``DeploymentError`` escape.
    * ``guard_bytes`` — debug mode: plan ``guard_bytes`` of never-placed
      slack around every placement and fill/verify canary bytes there at
      run time (``GuardViolation`` on a stomp).  0 (default) is
      byte-identical to the historical planner/executor.
    * extra keyword arguments are forwarded to ``core.schedule()``.
    """
    qmodel = None
    if quantize:
        from repro.graphs import quantize_graph
        qmodel = quantize_graph(graph, calibration)
        graph = qmodel.graph

    # one attempt = the full schedule → plan → validate → compile chain for
    # one rung set; any failure inside is that rung set's failure
    def attempt(rungs):
        res = _schedule(graph, arena_budget=arena_budget,
                        partition=partition, objective=objective,
                        macs_cap=macs_cap,
                        **(schedule_opts if rungs is None
                           else {**schedule_opts, "rungs": rungs}))
        eg = res.graph if res.graph is not None else graph
        plan = ArenaPlanner.plan(eg, res.schedule, guard_bytes=guard_bytes)
        ArenaPlanner.validate(plan, eg)
        ex = compile_schedule(eg, res.schedule, plan,
                              use_pallas=use_pallas, fuse=fuse)
        return res, eg, plan, ex

    ladder = (_FALLBACK_RUNGS if "rungs" not in schedule_opts
              else (schedule_opts.pop("rungs"),))
    degraded: List[str] = []
    res = None
    if strict:
        res, exec_graph, plan, executor = attempt(ladder[0])
    else:
        for rungs in ladder:
            try:
                res, exec_graph, plan, executor = attempt(rungs)
                break
            except Exception as e:       # noqa: BLE001 — each rung may fail
                tag = "full ladder" if rungs is None else "+".join(rungs)
                degraded.append(f"rung set [{tag}] failed: "
                                f"{type(e).__name__}: {e}")
        if res is None:
            raise DeploymentError(
                "every scheduler rung set failed — nothing left to degrade "
                "to:\n  " + "\n  ".join(degraded))
    if arena_budget is not None and plan.arena_size > arena_budget:
        miss = (f"arena budget missed: need {int(plan.arena_size)} B > "
                f"budget {int(arena_budget)} B (best rung: {res.method})")
        if strict:
            raise BudgetUnreachableError(
                miss + " — pass strict=False to deploy best-effort")
        degraded.append(miss)
    return Deployment(graph=graph, exec_graph=exec_graph,
                      schedule_result=res, plan=plan, executor=executor,
                      qmodel=qmodel, degraded=degraded,
                      guard_bytes=guard_bytes)


__all__ = ["Deployment", "build"]
