from .interpreter import InterpreterReport, MicroInterpreter
from .compile import (CompiledExecutor, LoweringCtx, compile_schedule,
                      lower_op, register_lowering)

__all__ = ["MicroInterpreter", "InterpreterReport",
           "CompiledExecutor", "LoweringCtx", "compile_schedule",
           "lower_op", "register_lowering"]
