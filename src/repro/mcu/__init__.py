from .interpreter import InterpreterReport, MicroInterpreter

__all__ = ["MicroInterpreter", "InterpreterReport"]
