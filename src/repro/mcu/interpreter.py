"""Micro-interpreter simulator (paper §4).

Executes a scheduled computation graph the way the paper's modified
TensorFlow-Lite-Micro interpreter does:

* tensors live in one contiguous SRAM arena managed by the paper's
  ``DynamicAllocator`` (first-fit + compact-to-front defrag after every op);
* a tensor's buffer is reclaimed as soon as its last consumer has executed;
* C/C++-style "no stale pointers" is modelled by resolving every tensor's
  arena offset immediately before each operator runs;
* numerics are the operator ``fn``s (jnp), so we can assert bit-identical
  outputs across schedules — the paper's property that reordering "does not
  change the architecture or the output of a neural network".

The report carries the paper's measurables: peak SRAM usage (arena
high-water), defrag traffic (latency/energy-overhead proxy), and whether the
model fits a given SRAM capacity.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.allocator import DynamicAllocator
from repro.core.graph import Graph, Operator


@dataclasses.dataclass
class InterpreterReport:
    peak_sram: int
    bytes_moved: int
    defrag_passes: int
    steps: int
    wall_time_s: float
    fits: Optional[bool] = None
    outputs: Optional[Dict[str, Any]] = None


class MicroInterpreter:
    def __init__(self, graph: Graph, capacity: Optional[int] = None,
                 defragment: bool = True):
        self.graph = graph
        self.capacity = capacity
        self.defragment = defragment

    def run(self, inputs: Dict[str, Any],
            schedule: Optional[Sequence[Operator]] = None,
            keep_outputs: bool = True) -> InterpreterReport:
        g = self.graph
        sched = list(schedule) if schedule is not None else g.default_schedule()
        if not g.is_valid_schedule(sched):
            raise ValueError("invalid schedule")
        alloc = DynamicAllocator(self.capacity)
        buffers: Dict[str, Any] = {}

        # reference counts: uses of each tensor by the remaining schedule,
        # graph outputs pinned
        uses: Dict[str, int] = {}
        for op in sched:
            for i in op.inputs:
                uses[i] = uses.get(i, 0) + 1
        for o in g.outputs:
            uses[o] = uses.get(o, 0) + 1

        # network inputs occupy SRAM from the start (paper Fig. 2: tensor 0)
        for name, value in inputs.items():
            if g.producer(name) is not None:
                raise ValueError(f"{name!r} is not a graph input")
            alloc.alloc(name, g.size(name))
            buffers[name] = value

        t0 = time.perf_counter()
        for op in sched:
            # resolve current addresses (no stale pointers across defrags)
            args = [buffers[i] for i in op.inputs]
            alloc.alloc(op.output, g.size(op.output))
            if op.fn is None:
                raise ValueError(f"operator {op.name!r} has no semantics")
            out = op.fn(*args)
            buffers[op.output] = out
            # reclaim inputs whose last consumer just ran
            for i in set(op.inputs):
                uses[i] -= op.inputs.count(i)
                if uses[i] <= 0:
                    alloc.free(i)
                    del buffers[i]
            if uses.get(op.output, 0) <= 0:   # dead output (shouldn't happen)
                alloc.free(op.output)
                del buffers[op.output]
            if self.defragment:
                alloc.defragment()
        wall = time.perf_counter() - t0

        outs = {o: np.asarray(buffers[o]) for o in g.outputs} \
            if keep_outputs else None
        fits = (alloc.stats.peak_bytes <= self.capacity
                if self.capacity is not None else None)
        return InterpreterReport(
            peak_sram=alloc.stats.peak_bytes,
            bytes_moved=alloc.stats.bytes_moved,
            defrag_passes=alloc.stats.defrag_passes,
            steps=len(sched),
            wall_time_s=wall,
            fits=fits,
            outputs=outs,
        )
