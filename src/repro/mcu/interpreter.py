"""Micro-interpreter simulator (paper §4).

Executes a scheduled computation graph the way the paper's modified
TensorFlow-Lite-Micro interpreter does:

* tensors live in one contiguous SRAM arena managed by the paper's
  ``DynamicAllocator`` (first-fit + compact-to-front defrag after every op);
* a tensor's buffer is reclaimed as soon as its last consumer has executed;
* C/C++-style "no stale pointers" is modelled by resolving every tensor's
  arena offset immediately before each operator runs;
* numerics are the operator ``fn``s (jnp), so we can assert bit-identical
  outputs across schedules — the paper's property that reordering "does not
  change the architecture or the output of a neural network".

Two extensions support partial-execution (Pex-style) sliced schedules:

* operators marked ``inplace`` (the incremental ``pex_concat`` that writes a
  slice into the shared output buffer) reuse the dying input's block via
  ``DynamicAllocator.rename`` instead of allocating a second copy of the
  output — matching ``Graph.live_sets``'s accounting;
* ``run(..., plan=ArenaPlan)`` executes against precomputed offsets (the §6
  offline planner) instead of the dynamic allocator, reporting the plan's
  high-water mark so callers can cross-check it against ``plan.arena_size``.

The report carries the paper's measurables: peak SRAM usage (arena
high-water), defrag traffic (latency/energy-overhead proxy), and whether the
model fits a given SRAM capacity.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Sequence

import numpy as np

from repro.core.allocator import ArenaPlan, DynamicAllocator
from repro.core.graph import Graph, Operator, inplace_candidates


@dataclasses.dataclass
class InterpreterReport:
    peak_sram: int
    bytes_moved: int
    defrag_passes: int
    steps: int
    wall_time_s: float
    fits: Optional[bool] = None
    outputs: Optional[Dict[str, Any]] = None


class MicroInterpreter:
    def __init__(self, graph: Graph, capacity: Optional[int] = None,
                 defragment: bool = True):
        self.graph = graph
        self.capacity = capacity
        self.defragment = defragment

    def run(self, inputs: Dict[str, Any],
            schedule: Optional[Sequence[Operator]] = None,
            keep_outputs: bool = True,
            plan: Optional[ArenaPlan] = None) -> InterpreterReport:
        g = self.graph
        sched = list(schedule) if schedule is not None else g.default_schedule()
        if not g.is_valid_schedule(sched):
            raise ValueError("invalid schedule")
        # the dynamic allocator compacts buffers, so mixed-dtype graphs
        # need offsets aligned to the widest itemsize to stay
        # dereferenceable (pure-int8/f32 graphs are unaffected: every
        # size is already a multiple of the single itemsize)
        alloc = (DynamicAllocator(self.capacity,
                                  alignment=g.max_itemsize())
                 if plan is None else None)
        offsets: Dict[str, tuple] = {}
        if plan is not None:
            offsets = {p.tensor: (p.offset, p.size) for p in plan.placements}
        live_planned: Dict[str, int] = {}   # tensor -> offset+size
        planned_peak = 0
        buffers: Dict[str, Any] = {}

        # reference counts: uses of each tensor by the remaining schedule,
        # graph outputs pinned
        uses: Dict[str, int] = {}
        for op in sched:
            for i in op.inputs:
                uses[i] = uses.get(i, 0) + 1
        for o in g.outputs:
            uses[o] = uses.get(o, 0) + 1

        def planned_alloc(name: str) -> None:
            nonlocal planned_peak
            if name not in offsets:
                raise KeyError(f"{name!r} missing from the arena plan")
            off, size = offsets[name]
            live_planned[name] = off + size
            planned_peak = max(planned_peak, max(live_planned.values()))
            if self.capacity is not None and planned_peak > self.capacity:
                raise MemoryError(
                    f"arena overflow at {name!r}: planned high water "
                    f"{planned_peak} exceeds capacity {self.capacity}")

        # network inputs occupy SRAM from the start (paper Fig. 2: tensor 0)
        for name, value in inputs.items():
            if g.producer(name) is not None:
                raise ValueError(f"{name!r} is not a graph input")
            declared = g.tensors[name].dtype
            if declared != "bfloat16":     # numpy has no bfloat16
                got = np.asarray(value).dtype
                if got != np.dtype(declared):
                    raise ValueError(
                        f"input {name!r} is {got}, graph declares "
                        f"{declared} (quantize inputs for int8 graphs)")
            if alloc is not None:
                alloc.alloc(name, g.size(name))
            else:
                planned_alloc(name)
            buffers[name] = value

        t0 = time.perf_counter()
        for op in sched:
            # resolve current addresses (no stale pointers across defrags)
            args = [buffers[i] for i in op.inputs]
            # an inplace op whose dying, size-matched input can donate its
            # buffer (partial execution's shared output buffer)
            donor: Optional[str] = None
            if op.attrs.get("inplace"):
                for i in inplace_candidates(op):
                    if (g.producer(i) is not None
                            and g.size(i) == g.size(op.output)
                            and uses[i] - op.inputs.count(i) <= 0):
                        donor = i
                        break
            if alloc is not None:
                if donor is None:
                    alloc.alloc(op.output, g.size(op.output))
            else:
                planned_alloc(op.output)
            if op.fn is None:
                raise ValueError(f"operator {op.name!r} has no semantics")
            out = op.fn(*args)
            buffers[op.output] = out
            # reclaim inputs whose last consumer just ran
            for i in set(op.inputs):
                uses[i] -= op.inputs.count(i)
                if uses[i] <= 0:
                    if alloc is not None:
                        if i == donor:
                            alloc.rename(i, op.output)
                        else:
                            alloc.free(i)
                    else:
                        live_planned.pop(i, None)
                    del buffers[i]
            if uses.get(op.output, 0) <= 0:   # dead output (shouldn't happen)
                if alloc is not None:
                    alloc.free(op.output)
                else:
                    live_planned.pop(op.output, None)
                del buffers[op.output]
            if alloc is not None and self.defragment:
                alloc.defragment()
        wall = time.perf_counter() - t0

        outs = {o: np.asarray(buffers[o]) for o in g.outputs} \
            if keep_outputs else None
        peak = alloc.stats.peak_bytes if alloc is not None else planned_peak
        fits = (peak <= self.capacity
                if self.capacity is not None else None)
        return InterpreterReport(
            peak_sram=peak,
            bytes_moved=alloc.stats.bytes_moved if alloc is not None else 0,
            defrag_passes=(alloc.stats.defrag_passes
                           if alloc is not None else 0),
            steps=len(sched),
            wall_time_s=wall,
            fits=fits,
            outputs=outs,
        )
