"""Compiled arena executor: lower a scheduled graph + arena plan to ONE
jitted JAX program.

The paper's offline artefacts — a schedule (the operator order) and an
``ArenaPlan`` (a byte offset per tensor) — fully determine the runtime: what
ships to the device is a straight-line program over a single SRAM arena.
``MicroInterpreter`` executes that program as a Python loop with per-op
dispatch, which validates the memory model but is orders of magnitude slower
than the hardware.  ``compile_schedule`` closes the gap the way Pex and
MCUNet pair their planners with a compiled runtime:

* the whole arena is **one uint8 buffer** of ``plan.arena_size`` bytes —
  exactly the byte-addressed SRAM arena of TFLite-Micro.  The jitted
  program takes the arena and returns the arena, and is jitted with
  ``donate_argnums=0`` so XLA updates it in place — the jit-level
  equivalent of a Pallas kernel's ``input_output_aliases``;
* each operator becomes a static byte-slice read of its inputs at their
  ``Placement`` offsets **bitcast to the tensor's dtype** (f32 tensors view
  4 bytes per element, int8 tensors 1 — mixed f32/int8 graphs coexist in
  the one arena), a lowering rule (see the registry below), and a bitcast
  back to bytes + ``dynamic_update_slice`` at the output's offset.  The
  plan's disjointness invariant (overlapping lifetimes ⇒ disjoint ranges)
  plus its alignment policy (offsets aligned to the itemsize, so every
  bitcast view is naturally aligned — enforced here at compile time) are
  what make this sound;
* inplace chains (partial execution's incremental ``pex_concat``) alias to
  one offset in the plan, so the read-modify-write at that offset **is** the
  shared accumulator buffer — no copies materialise after XLA's donation;
* runs of uniform Pex slices are rolled into a ``lax.fori_loop`` whose body
  indexes per-iteration offsets/row-starts from closed-over arrays — the
  compiled program stays O(segment) in code size instead of O(K · segment);
* ``pex_ring_read`` windows with a single integer-exact consumer are
  **zero-copy**: the modular gather fuses into the consumer's computation
  as an SSA value and the window is never re-materialised in the arena
  (``zero_copy_rings=True``, see ``_zero_copy_reads`` for the eligibility
  proof obligations) — in both the straight-line and rolled-loop paths.

Lowering rules are registered per operator ``kind`` next to the semantics
(``graphs/cnn_ops.py`` registers conv/dwconv/maxpool/add, optionally routing
the MCU-shaped NHWC pointwise conv through the Pallas fused kernel under
``kernels/``); ``pex_slice``/``pex_concat`` are lowered here from the
structured attrs the partition rewrite records, because their simulator
closures are numpy and cannot be traced.  Any kind without a rule falls back
to tracing ``op.fn`` — every jnp-based simulator semantic is jit-compatible.

Numerics contract: with ``use_pallas=False`` (default) the lowering traces
the same jnp/lax computations the interpreter runs eagerly, so outputs are
bit-identical (property-tested in ``tests/test_executor_diff.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.allocator import ArenaPlan, ArenaPlanner
from repro.core.graph import Graph, Operator

# ``optimization_barrier`` (the fence strict mode places between operators,
# see ``compile_schedule``) has no vmap batching rule in this jax version,
# which would break micro-batched serving (vmap over stacked arenas).  The
# barrier is semantically the identity, so batching is a pass-through.
try:  # pragma: no cover - exercised via serving vmap tests
    from jax._src.lax.lax import optimization_barrier_p
    from jax.interpreters import batching

    if optimization_barrier_p not in batching.primitive_batchers:
        def _optimization_barrier_batcher(args, dims, **params):
            return optimization_barrier_p.bind(*args, **params), dims

        batching.primitive_batchers[optimization_barrier_p] = \
            _optimization_barrier_batcher
except Exception:            # private path moved: only fuse=True vmaps
    pass


# ------------------------------------------------------------- dtype bitcasts
# The arena is bytes; tensors are typed views of byte ranges.  These two
# helpers are the only place the executor crosses that boundary, and both
# are exact bit-level reinterpretations (no rounding, no canonicalisation),
# so they cannot perturb the bit-identity contract.
_JNP_DTYPES = {
    "int8": jnp.int8, "uint8": jnp.uint8,
    "int16": jnp.int16, "float16": jnp.float16, "bfloat16": jnp.bfloat16,
    "int32": jnp.int32, "float32": jnp.float32,
}

# Guard-byte debug mode (DESIGN.md §12): never-placed arena gaps (see
# ``ArenaPlan.guard_regions``) are filled with this canary at arena build
# and verified untouched after execution.  0xA5 = 1010_0101 — asymmetric
# under bit rotation and distinct from 0x00/0xFF, so zero-fills, one-fills
# and shifted writes all trip it.
CANARY_BYTE = 0xA5


def _view_bytes(raw, dtype: str, shape: Tuple[int, ...]):
    """uint8 [nbytes] -> ``dtype`` array of ``shape``."""
    dt = jnp.dtype(_JNP_DTYPES[dtype])
    if dt.itemsize == 1:
        v = raw if dt == jnp.uint8 else lax.bitcast_convert_type(raw, dt)
    else:
        v = lax.bitcast_convert_type(raw.reshape(-1, dt.itemsize), dt)
    return v.reshape(shape)


def _as_bytes(val):
    """Any array -> flat uint8 [nbytes]."""
    flat = jnp.ravel(val)
    if flat.dtype == jnp.uint8:
        return flat
    return jnp.ravel(lax.bitcast_convert_type(flat, jnp.uint8))


# ----------------------------------------------------------- lowering registry
@dataclasses.dataclass
class LoweringCtx:
    """What a lowering rule may ask about the graph being compiled."""

    graph: Graph
    use_pallas: bool = False
    interpret: Optional[bool] = None   # Pallas interpret override (None=auto)

    def shape(self, tensor: str) -> Tuple[int, ...]:
        t = self.graph.tensors[tensor]
        return tuple(t.shape) if t.shape else (t.elements,)

    def dtype(self, tensor: str) -> str:
        return self.graph.tensors[tensor].dtype


_RULES: Dict[str, Callable[..., Any]] = {}


def register_lowering(kind: str):
    """Register ``fn(ctx, op, *inputs) -> output`` as the compiled lowering
    for operators of ``kind``.  Rules live next to the op semantics."""
    def deco(fn):
        _RULES[kind] = fn
        return fn
    return deco


def _fallback(ctx: LoweringCtx, op: Operator, *args):
    if op.fn is None:
        raise ValueError(
            f"operator {op.name!r} (kind={op.kind!r}) has neither a lowering "
            f"rule nor executable semantics")
    return op.fn(*args)


def lower_op(ctx: LoweringCtx, op: Operator, *args):
    return _RULES.get(op.kind, _fallback)(ctx, op, *args)


@register_lowering("pex_slice")
def _lower_pex_slice(ctx: LoweringCtx, op: Operator, x):
    rows = op.attrs.get("pex_rows")
    if rows is None:                    # pre-metadata graph: trace the closure
        return _fallback(ctx, op, x)
    lo, hi = rows
    x = lax.slice_in_dim(x, lo, hi, axis=0)
    cols = op.attrs.get("pex_cols")     # 2-D tile extract: columns too
    if cols is not None:
        x = lax.slice_in_dim(x, cols[0], cols[1], axis=1)
    return x


@register_lowering("pex_concat")
def _lower_pex_concat(ctx: LoweringCtx, op: Operator, *args):
    start = op.attrs.get("pex_start")
    if start is None:
        return _fallback(ctx, op, *args)
    if op.attrs.get("pex_first"):
        (part,) = args
        acc = jnp.zeros(ctx.shape(op.output), part.dtype)
    else:
        acc, part = args
    # 2-D tiles scatter at (row, column) — pex_cstart is 0 for row cascades
    idx = (start, op.attrs.get("pex_cstart", 0)) + (0,) * (np.ndim(part) - 2)
    idx = idx[:np.ndim(part)]
    return lax.dynamic_update_slice(acc, part, idx)


# Cascaded-streaming ring ops (core/partition.py cascade rewrite): boundary
# tensors between cascaded segments never exist whole — row ``r`` of the
# boundary lives at ring position ``r % ring_rows``.  A push is a rolling
# scatter of the producer's new delta rows (the SSA chain of ring states
# aliases to one arena offset through the inplace accounting, so the
# compiled read-modify-write at that offset IS the rolling buffer); a read
# gathers the consumer's halo'd window back into row order.  Both are pure
# row copies at static indices — bit-identity is structural.
@register_lowering("pex_ring_push")
def _lower_pex_ring_push(ctx: LoweringCtx, op: Operator, *args):
    a = op.attrs
    rows, dst = a.get("pex_ring_rows"), a.get("pex_ring_dst")
    if rows is None or dst is None:
        return _fallback(ctx, op, *args)
    if a.get("pex_first"):
        (part,) = args
        ring = jnp.zeros(ctx.shape(op.output), part.dtype)
    else:
        ring, part = args
    idx = (dst + jnp.arange(part.shape[0])) % rows
    return ring.at[idx].set(part)


@register_lowering("pex_ring_read")
def _lower_pex_ring_read(ctx: LoweringCtx, op: Operator, ring):
    a = op.attrs
    rows, src = a.get("pex_ring_rows"), a.get("pex_ring_src")
    if rows is None or src is None:
        return _fallback(ctx, op, ring)
    n = ctx.shape(op.output)[0]
    idx = (src + jnp.arange(n)) % rows
    return jnp.take(ring, idx, axis=0)


# ------------------------------------------------------- zero-copy ring reads
# A ``pex_ring_read`` gathers a halo'd window out of the ring in row order.
# Materialising that window into the arena is a pure copy the MCU never
# needs: the consumer can index the ring directly (the Helium ping-pong
# buffering model — never re-materialise what the arena already holds).
# The compiled program fuses the gather into the consumer by keeping the
# gathered window as an SSA value — no arena write, no barrier between the
# read and its consumer — when that is provably bit-safe:
#
# * the window is integer-typed and the consumer is an integer-exact kind
#   (int32 accumulation is order-independent and element-wise f32 requant
#   ops are deterministic under any fusion context, so removing the module
#   boundary cannot perturb results — unlike f32 convs, which XLA CPU
#   compiles context-sensitively);
# * the read's output has exactly one consumer, scheduled immediately after
#   it in the same Pex slice group (true by construction for the cascade
#   rewrite's ``cpexrd__*`` reads), and is not a graph output.
#
# The arena plan is untouched: the window keeps its placement (the memory
# model still charges it — the liveness story is unchanged), the compiled
# program just never writes it.
_ZERO_COPY_KINDS = frozenset({"qconv", "qdwconv", "qmaxpool"})
_INT_DTYPES = frozenset({"int8", "uint8", "int16", "int32"})


def _zero_copy_reads(graph: Graph, sched: Sequence[Operator]) -> set:
    """Tensor names of ring-read windows to keep as SSA values."""
    outs = set(graph.outputs)
    fused = set()
    for idx in range(len(sched) - 1):
        op, nxt = sched[idx], sched[idx + 1]
        if op.kind != "pex_ring_read" or "pex_ring_src" not in op.attrs:
            continue
        name = op.output
        if name in outs or graph.tensors[name].dtype not in _INT_DTYPES:
            continue
        cons = graph.consumers(name)
        if (len(cons) == 1 and cons[0].name == nxt.name
                and nxt.kind in _ZERO_COPY_KINDS
                and op.attrs.get("pex_seg") == nxt.attrs.get("pex_seg")
                and op.attrs.get("pex_slice_idx")
                == nxt.attrs.get("pex_slice_idx")):
            fused.add(name)
    return fused


# ------------------------------------------------------- pex fori_loop rolling
def _roll_key(ctx: LoweringCtx, op: Operator):
    """Hashable description of what an op *computes* (not where its tensors
    live).  Two ops with equal keys run the same program on same-shaped data,
    so consecutive slices whose keys match position-for-position can share
    one fori_loop body.  ``None`` = not rollable."""
    ins = tuple((ctx.shape(i), ctx.dtype(i)) for i in op.inputs)
    outs = (ctx.shape(op.output), ctx.dtype(op.output))
    a = op.attrs
    if op.kind == "pex_slice":
        if "pex_rows" not in a:
            return None
        lo, hi = a["pex_rows"]
        # the column window is traced statically into the body, so it must
        # match across rolled iterations (constant within a W-strip)
        return ("pex_slice", hi - lo, a.get("pex_cols"), ins, outs)
    if op.kind == "pex_concat":
        if "pex_start" not in a:
            return None
        return ("pex_concat", bool(a.get("pex_first")),
                a.get("pex_cstart"), ins, outs)
    if op.kind == "pex_ring_push":
        if "pex_ring_dst" not in a:
            return None
        return ("pex_ring_push", bool(a.get("pex_first")),
                a["pex_ring_rows"], ins, outs)
    if op.kind == "pex_ring_read":
        if "pex_ring_src" not in a:
            return None
        return ("pex_ring_read", a["pex_ring_rows"], ins, outs)
    if "pex_of" in a and "pex_pads" in a:
        wpads = a.get("pex_wpads")
        return (op.kind, a["pex_of"], tuple(a["pex_pads"]),
                None if wpads is None else tuple(wpads), ins, outs)
    return None


@dataclasses.dataclass
class _Slot:
    """Where one operand lives, across the iterations of a rolled loop."""

    offset: Any                 # int (static) or jnp int32 array [n] (param)
    size: int                   # bytes
    shape: Tuple[int, ...]
    dtype: str

    @property
    def static(self) -> bool:
        return isinstance(self.offset, int)


@dataclasses.dataclass
class _Template:
    op: Operator                       # representative (first iteration's op)
    in_slots: List[_Slot]
    out_slot: _Slot
    lo: Optional[Any] = None           # pex_slice: row start per iteration
    col: int = 0                      # pex_slice: static column start (2-D)
    start: Optional[Any] = None       # pex_concat: write start per iteration
    cstart: int = 0                   # pex_concat: static column start (2-D)
    ring_dst: Optional[Any] = None    # pex_ring_push: dst row per iteration
    ring_src: Optional[Any] = None    # pex_ring_read: src row per iteration
    ring_rows: int = 0                # ring size (rows); static per template
    defer: bool = False               # zero-copy: keep output as SSA value
    fused_in: Optional[Tuple[int, int]] = None   # (input j, source template)


@dataclasses.dataclass
class _RolledLoop:
    templates: List[_Template]
    n: int


def _slice_groups(sched: Sequence[Operator]):
    """Split the schedule into maximal runs of ops tagged with the same
    (segment, slice index); untagged ops stand alone."""
    groups: List[Tuple[Optional[str], Optional[int], List[Operator]]] = []
    for op in sched:
        seg = op.attrs.get("pex_seg")
        s = op.attrs.get("pex_slice_idx")
        if (seg is not None and groups and groups[-1][0] == seg
                and groups[-1][1] == s):
            groups[-1][2].append(op)
        else:
            groups.append((seg, s, [op]))
    return groups


def _build_loop(ctx: LoweringCtx, offsets: Dict[str, Tuple[int, int]],
                run: List[List[Operator]],
                zero_copy: frozenset = frozenset()
                ) -> Optional[_RolledLoop]:
    """Merge ≥2 structurally-identical slice groups into one fori_loop.
    Returns None when any operand breaks the uniformity conditions."""
    n = len(run)
    templates: List[_Template] = []
    out_names: List[List[str]] = []
    for d in range(len(run[0])):
        ops = [g[d] for g in run]
        rep = ops[0]
        in_slots: List[_Slot] = []
        for j in range(len(rep.inputs)):
            names = [o.inputs[j] for o in ops]
            shape = ctx.shape(names[0])
            dtype = ctx.dtype(names[0])
            sizes = {offsets[nm][1] for nm in names}
            if len(sizes) != 1:
                return None
            size = sizes.pop()
            if all(nm == names[0] for nm in names):
                in_slots.append(_Slot(offsets[names[0]][0], size, shape,
                                      dtype))
            else:
                offs = jnp.asarray([offsets[nm][0] for nm in names],
                                   jnp.int32)
                in_slots.append(_Slot(offs, size, shape, dtype))
        onames = [o.output for o in ops]
        osizes = {offsets[nm][1] for nm in onames}
        if len(osizes) != 1:
            return None
        tpl = _Template(rep, in_slots,
                        _Slot(jnp.asarray([offsets[nm][0] for nm in onames],
                                          jnp.int32),
                              osizes.pop(), ctx.shape(onames[0]),
                              ctx.dtype(onames[0])))
        if rep.kind == "pex_slice":
            tpl.lo = jnp.asarray([o.attrs["pex_rows"][0] for o in ops],
                                 jnp.int32)
            tpl.col = rep.attrs.get("pex_cols", (0, 0))[0]
        elif rep.kind == "pex_concat":
            tpl.start = jnp.asarray([o.attrs["pex_start"] for o in ops],
                                    jnp.int32)
            tpl.cstart = rep.attrs.get("pex_cstart", 0)
        elif rep.kind == "pex_ring_push":
            tpl.ring_dst = jnp.asarray([o.attrs["pex_ring_dst"]
                                        for o in ops], jnp.int32)
            tpl.ring_rows = rep.attrs["pex_ring_rows"]
        elif rep.kind == "pex_ring_read":
            tpl.ring_src = jnp.asarray([o.attrs["pex_ring_src"]
                                        for o in ops], jnp.int32)
            tpl.ring_rows = rep.attrs["pex_ring_rows"]
        templates.append(tpl)
        out_names.append(onames)
    # zero-copy ring reads inside the rolled body: a deferred template's
    # per-iteration outputs flow straight into the next template's matching
    # input instead of round-tripping through the arena
    for d in range(len(templates) - 1):
        if templates[d].op.kind != "pex_ring_read":
            continue
        if not all(nm in zero_copy for nm in out_names[d]):
            continue
        nxt_ops = [g[d + 1] for g in run]
        for j in range(len(nxt_ops[0].inputs)):
            if [o.inputs[j] for o in nxt_ops] == out_names[d]:
                templates[d].defer = True
                templates[d + 1].fused_in = (j, d)
                break
    return _RolledLoop(templates, n)


def _plan_items(ctx: LoweringCtx, offsets: Dict[str, Tuple[int, int]],
                sched: Sequence[Operator], roll_loops: bool,
                zero_copy: frozenset = frozenset()) -> List[Any]:
    """The compiled program structure: a list of Operators (straight-line
    steps) and _RolledLoops."""
    if not roll_loops:
        return list(sched)
    items: List[Any] = []
    groups = _slice_groups(sched)
    i = 0
    while i < len(groups):
        seg, s, ops = groups[i]
        key = (None if seg is None
               else tuple(_roll_key(ctx, op) for op in ops))
        if seg is None or key is None or any(k is None for k in key):
            items.extend(ops)
            i += 1
            continue
        run = [ops]
        j = i + 1
        while j < len(groups):
            seg2, s2, ops2 = groups[j]
            if (seg2 != seg or s2 != s + (j - i)
                    or len(ops2) != len(ops)
                    or tuple(_roll_key(ctx, op) for op in ops2) != key):
                break
            run.append(ops2)
            j += 1
        loop = (_build_loop(ctx, offsets, run, zero_copy)
                if len(run) >= 2 else None)
        if loop is None:
            items.extend(ops)
            i += 1
        else:
            items.append(loop)
            i = j
    return items


# ------------------------------------------------------------------- executor
@dataclasses.dataclass
class CompiledExecutor:
    """A scheduled graph lowered to one jitted arena program.

    ``raw_fn(arena) -> arena`` is the pure staged program (composable under
    ``jax.vmap`` for micro-batched serving); ``fn`` is its jitted,
    donated-argument form.  The arena is **uint8**: ``arena_size`` equals
    ``plan.arena_size`` bytes, and the program never reads or writes past
    it.  Tensors are typed bitcast views of their placements.
    """

    graph: Graph
    schedule: List[Operator]
    plan: ArenaPlan
    arena_size: int              # bytes
    dtype: Any                   # arena element type: always uint8
    raw_fn: Callable[[Any], Any]
    fn: Callable[[Any], Any]
    rolled_loops: int
    rolled_ops: int
    steps: int
    offsets: Dict[str, Tuple[int, int]]    # tensor -> (byte offset, bytes)
    zero_copy_reads: int = 0    # ring windows fused into their consumers
    # guard-byte debug mode: (offset, size) arena ranges no placement ever
    # covers; () in production (guard_bytes=0 plans) — the arena is then
    # byte-identical to the un-guarded executor
    guard_regions: Tuple[Tuple[int, int], ...] = ()
    # jit/pmap wrappers are built lazily and cached per geometry: engines
    # ask for the same batched program every dispatch, and an XLA compile
    # per call would dwarf the work
    _fn_cache: Dict[Any, Callable] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def _offsets(self, tensor: str) -> Tuple[int, int]:
        return self.offsets[tensor]

    # ------------------------------------------------- serving entry points
    # ``raw_fn`` is a pure [arena] -> [arena] program, so batching and
    # replication are plain jax transforms of it: one jit(vmap) for
    # micro-batched single-device serving, one pmap(vmap) to shard replica
    # batches across host/accelerator devices (the engines in
    # ``serving/`` own the queueing; the executor owns the compiled forms).
    def batched_fn(self, *, donate: bool = True) -> Callable:
        """``[B, arena] -> [B, arena]``: one jitted vmap dispatch over a
        stack of B arenas (B inferences amortise one XLA dispatch)."""
        key = ("batched", donate)
        if key not in self._fn_cache:
            f = jax.vmap(self.raw_fn)
            self._fn_cache[key] = (jax.jit(f, donate_argnums=0) if donate
                                   else jax.jit(f))
        return self._fn_cache[key]

    def replicated_fn(self, replicas: int) -> Callable:
        """``[R, B, arena] -> [R, B, arena]``: the vmapped arena program
        pmapped over the first ``replicas`` visible devices — each replica
        executes its lane batch independently (no collectives; requests
        are embarrassingly parallel), so per-replica results are
        bit-identical to the single-device ``batched_fn``."""
        devices = jax.devices()
        if replicas > len(devices):
            raise ValueError(
                f"replicas={replicas} but only {len(devices)} devices "
                f"visible; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={replicas} "
                f"before the first jax import (serving.force_host_devices)")
        key = ("replicated", replicas)
        if key not in self._fn_cache:
            self._fn_cache[key] = jax.pmap(jax.vmap(self.raw_fn),
                                           devices=devices[:replicas])
        return self._fn_cache[key]

    def pad_arena(self):
        """An all-zeros arena for pad lanes (ragged tails): executed but
        never read back, and visibly not a duplicated request."""
        return jnp.zeros((self.arena_size,), self.dtype)

    def make_arena(self, inputs: Dict[str, Any]):
        """Fresh arena with the graph inputs written (as bytes) at their
        offsets.  Input values must already be in the tensor's declared
        dtype — an int8 graph takes quantized int8 inputs."""
        g = self.graph
        needed = {c for c in g.constants() if g.consumers(c)}
        missing = needed - set(inputs)
        if missing:
            raise ValueError(f"missing graph inputs: {sorted(missing)}")
        arena = jnp.zeros((self.arena_size,), self.dtype)
        for off, size in self.guard_regions:   # () in production plans
            arena = lax.dynamic_update_slice(
                arena, jnp.full((size,), CANARY_BYTE, self.dtype), (off,))
        for name, value in inputs.items():
            if name not in g.tensors:
                raise ValueError(f"unknown tensor {name!r}")
            if g.producer(name) is not None:
                raise ValueError(f"{name!r} is not a graph input")
            if not g.consumers(name):
                continue       # unused input: not arena-resident in the plan
            off, size = self._offsets(name)
            t = g.tensors[name]
            want = jnp.dtype(_JNP_DTYPES[t.dtype])
            val = jnp.asarray(value)
            if val.dtype != want:     # same contract as MicroInterpreter
                raise ValueError(
                    f"input {name!r} is {val.dtype}, graph declares "
                    f"{t.dtype} (quantize inputs for int8 graphs)")
            flat = jnp.ravel(val)
            if flat.shape[0] != t.elements:
                raise ValueError(
                    f"input {name!r}: got {flat.shape[0]} elements, "
                    f"plan expects {t.elements} ({size} bytes as {t.dtype})")
            arena = lax.dynamic_update_slice(arena, _as_bytes(flat), (off,))
        return arena

    def outputs_from(self, arena, as_numpy: bool = True) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for o in self.graph.outputs:
            off, size = self._offsets(o)
            t = self.graph.tensors[o]
            shape = tuple(t.shape) if t.shape else (t.elements,)
            val = _view_bytes(arena[off:off + size], t.dtype, shape)
            out[o] = np.asarray(val) if as_numpy else val
        return out

    def verify_guards(self, arena) -> None:
        """Guard-byte debug mode: assert every canary region still holds
        ``CANARY_BYTE`` after execution; a stomped byte is a genuine
        out-of-bounds write by a lowering or a planner bug and raises
        ``GuardViolation`` naming the first bad offset.  No-op (and free)
        when the plan carries no guard regions."""
        if not self.guard_regions:
            return
        from repro.errors import GuardViolation
        a = np.asarray(arena)
        for off, size in self.guard_regions:
            region = a[off:off + size]
            bad = np.nonzero(region != CANARY_BYTE)[0]
            if bad.size:
                at = off + int(bad[0])
                raise GuardViolation(
                    f"guard canary stomped at arena byte {at} (region "
                    f"[{off},{off + size}), found 0x{int(a[at]):02x}, "
                    f"expected 0x{CANARY_BYTE:02x}) — out-of-bounds write "
                    f"by a lowering or an arena-plan bug")

    def run(self, inputs: Dict[str, Any], as_numpy: bool = True
            ) -> Dict[str, Any]:
        arena = self.fn(self.make_arena(inputs))
        self.verify_guards(arena)
        return self.outputs_from(arena, as_numpy)


def compile_schedule(graph: Graph,
                     schedule: Optional[Sequence[Operator]] = None,
                     plan: Optional[ArenaPlan] = None, *,
                     use_pallas: bool = False,
                     interpret: Optional[bool] = None,
                     roll_loops: bool = True,
                     zero_copy_rings: bool = True,
                     fuse: bool = False,
                     donate: bool = True) -> CompiledExecutor:
    """Lower ``schedule`` (default: the graph's embedded order) against
    ``plan`` (default: ``ArenaPlanner.plan``) into a single jitted arena
    program over one uint8 byte buffer.  See the module docstring for the
    lowering model.

    ``fuse=False`` (default) pins an ``optimization_barrier`` after every
    operator, reproducing the per-operator module boundaries of eager
    dispatch — an MCU runtime materialises each output into the arena the
    same way — which keeps compiled outputs bit-identical to the
    interpreter.  ``fuse=True`` lets XLA fuse across operators: fastest,
    but float results may drift within accumulation tolerance.

    ``zero_copy_rings=True`` (default) fuses each eligible
    ``pex_ring_read``'s window gather into its consumer instead of
    materialising the window in the arena — bit-safe by construction (only
    integer-exact consumers qualify; see ``_zero_copy_reads``) and a pure
    win: one fewer copy and barrier per streamed slice."""
    sched = list(schedule) if schedule is not None else graph.default_schedule()
    if not graph.is_valid_schedule(sched):
        raise ValueError("invalid schedule for this graph")
    if plan is None:
        plan = ArenaPlanner.plan(graph, sched)
    offsets = {p.tensor: (p.offset, p.size) for p in plan.placements}
    for op in sched:
        for t in list(op.inputs) + [op.output]:
            if t not in offsets:
                raise KeyError(f"tensor {t!r} missing from the arena plan")
            isz = graph.itemsize(t)
            if offsets[t][0] % isz:
                raise ValueError(
                    f"tensor {t!r} ({graph.tensors[t].dtype}) placed at "
                    f"misaligned byte offset {offsets[t][0]}; plan with "
                    f"ArenaPlanner.plan(..., alignment=None) so offsets "
                    f"are aligned to the widest itemsize")
    ctx = LoweringCtx(graph, use_pallas=use_pallas, interpret=interpret)
    zc = (frozenset(_zero_copy_reads(graph, sched)) if zero_copy_rings
          else frozenset())
    items = _plan_items(ctx, offsets, sched, roll_loops, zc)

    def read(arena, name: str):
        off, size = offsets[name]
        return _view_bytes(arena[off:off + size], ctx.dtype(name),
                           ctx.shape(name))

    def write(arena, name: str, val):
        off, size = offsets[name]
        want = jnp.dtype(_JNP_DTYPES[ctx.dtype(name)])
        if jnp.asarray(val).dtype != want:   # checked once, at trace time
            raise ValueError(
                f"{name}: lowered output is {jnp.asarray(val).dtype}, "
                f"graph declares {ctx.dtype(name)} — quantized semantics "
                f"must requantize before writing to the arena")
        flat = _as_bytes(val)
        if flat.shape[0] != size:     # static shape: checked at trace time
            raise ValueError(
                f"{name}: lowered output has {flat.shape[0]} bytes, "
                f"plan expects {size}")
        return lax.dynamic_update_slice(arena, flat, (off,))

    def barrier(arena):
        return arena if fuse else lax.optimization_barrier(arena)

    def step(arena, op: Operator, pending: Dict[str, Any]):
        args = [pending.pop(i) if i in pending else read(arena, i)
                for i in op.inputs]
        val = lower_op(ctx, op, *args)
        if op.output in zc:       # zero-copy: flows straight to the consumer
            pending[op.output] = val
            return arena
        return barrier(write(arena, op.output, val))

    def loop_step(arena, loop: _RolledLoop):
        def body(i, arena):
            deferred: Dict[int, Any] = {}
            for t_i, tpl in enumerate(loop.templates):
                args = []
                for j, slot in enumerate(tpl.in_slots):
                    if tpl.fused_in is not None and j == tpl.fused_in[0]:
                        args.append(deferred.pop(tpl.fused_in[1]))
                        continue
                    if slot.static:
                        raw = arena[slot.offset:slot.offset + slot.size]
                    else:
                        raw = lax.dynamic_slice(arena, (slot.offset[i],),
                                                (slot.size,))
                    args.append(_view_bytes(raw, slot.dtype, slot.shape))
                op = tpl.op
                if tpl.lo is not None:            # pex_slice, dynamic rows
                    x = args[0]
                    # sizes come from the out slot so 2-D tile extracts
                    # (static column window, dynamic row start) roll too;
                    # for row extracts out shape == (rows,) + x.shape[1:]
                    idx = (tpl.lo[i], tpl.col) + (0,) * (x.ndim - 2)
                    out = lax.dynamic_slice(x, idx[:x.ndim],
                                            tpl.out_slot.shape)
                elif tpl.start is not None:       # pex_concat, dynamic start
                    acc, part = args
                    idx = (tpl.start[i], tpl.cstart) + (0,) * (part.ndim - 2)
                    out = lax.dynamic_update_slice(acc, part, idx[:part.ndim])
                elif tpl.ring_dst is not None:    # pex_ring_push, dyn. dst
                    if op.attrs.get("pex_first"):
                        (part,) = args
                        ring = jnp.zeros(tpl.out_slot.shape, part.dtype)
                    else:
                        ring, part = args
                    rows = (tpl.ring_dst[i]
                            + jnp.arange(part.shape[0])) % tpl.ring_rows
                    out = ring.at[rows].set(part)
                elif tpl.ring_src is not None:    # pex_ring_read, dyn. src
                    (ring,) = args
                    rows = (tpl.ring_src[i]
                            + jnp.arange(tpl.out_slot.shape[0])
                            ) % tpl.ring_rows
                    out = jnp.take(ring, rows, axis=0)
                else:
                    out = lower_op(ctx, op, *args)
                if tpl.defer:     # zero-copy: no arena write, no barrier
                    deferred[t_i] = out
                    continue
                want = jnp.dtype(_JNP_DTYPES[tpl.out_slot.dtype])
                if jnp.asarray(out).dtype != want:
                    raise ValueError(
                        f"{op.name}: lowered output is "
                        f"{jnp.asarray(out).dtype}, graph declares "
                        f"{tpl.out_slot.dtype}")
                flat = _as_bytes(out)
                if tpl.out_slot.static:
                    arena = lax.dynamic_update_slice(
                        arena, flat, (tpl.out_slot.offset,))
                else:
                    arena = lax.dynamic_update_slice(
                        arena, flat, (tpl.out_slot.offset[i],))
                arena = barrier(arena)
            return arena
        return lax.fori_loop(0, loop.n, body, arena)

    def raw_fn(arena):
        pending: Dict[str, Any] = {}
        for item in items:
            if isinstance(item, _RolledLoop):
                arena = loop_step(arena, item)
            else:
                arena = step(arena, item, pending)
        return arena

    fn = jax.jit(raw_fn, donate_argnums=0) if donate else jax.jit(raw_fn)
    loops = [it for it in items if isinstance(it, _RolledLoop)]
    return CompiledExecutor(
        graph=graph, schedule=sched, plan=plan,
        arena_size=int(plan.arena_size), dtype=jnp.uint8,
        raw_fn=raw_fn, fn=fn,
        rolled_loops=len(loops),
        rolled_ops=sum(lp.n * len(lp.templates) for lp in loops),
        steps=len(sched), offsets=offsets, zero_copy_reads=len(zc),
        guard_regions=tuple(plan.guard_regions())
        if getattr(plan, "guard_bytes", 0) else ())
