"""Paper Table 1: SwiftNet-Cell default vs optimal operator order, and
MobileNet-v1 static vs dynamic allocation — peak KB + interpreter timings
on the micro-interpreter simulator (512 KB SRAM target, 200 KB framework
overhead, as in the paper)."""
import time

import numpy as np

from repro.core import ArenaPlanner, schedule, static_plan_size
from repro.graphs import mobilenet_v1_graph, swiftnet_cell_graph
from repro.mcu import MicroInterpreter

SRAM = 512 * 1024
OVERHEAD = 200 * 1024


def _input(g, seed=0):
    h, w, c = g.tensors["input"].shape
    return {"input": np.random.default_rng(seed)
            .standard_normal((h, w, c)).astype(np.float32)}


def run(report):
    # ---- SwiftNet Cell: reordering ----------------------------------
    g = swiftnet_cell_graph()
    t0 = time.perf_counter()
    res = schedule(g)
    sched_us = (time.perf_counter() - t0) * 1e6
    d_peak = g.peak_usage(g.default_schedule())
    report("table1.swiftnet.default_peak_KB", sched_us, d_peak / 1024)
    report("table1.swiftnet.optimal_peak_KB", sched_us, res.peak / 1024)
    report("table1.swiftnet.saving_KB", sched_us, (d_peak - res.peak) / 1024)
    report("table1.swiftnet.fits_512KB_default", 0,
           int(d_peak + OVERHEAD <= SRAM))
    report("table1.swiftnet.fits_512KB_optimal", 0,
           int(res.peak + OVERHEAD <= SRAM))

    interp = MicroInterpreter(g)
    rep = interp.run(_input(g), schedule=res.schedule)
    report("table1.swiftnet.exec_us", rep.wall_time_s * 1e6,
           rep.peak_sram / 1024)
    report("table1.swiftnet.defrag_KB_moved", rep.wall_time_s * 1e6,
           rep.bytes_moved / 1024)

    # ---- MobileNet v1: static vs dynamic allocation ------------------
    g = mobilenet_v1_graph()
    static_kb = static_plan_size(g) / 1024
    t0 = time.perf_counter()
    rep_d = MicroInterpreter(g, defragment=True).run(_input(g))
    t_dyn = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    rep_s = MicroInterpreter(g, defragment=False).run(_input(g))
    t_sta = (time.perf_counter() - t0) * 1e6
    report("table1.mobilenet.static_KB", t_sta, static_kb)
    report("table1.mobilenet.dynamic_KB", t_dyn, rep_d.peak_sram / 1024)
    # paper: sub-1% overhead from defragmentation
    overhead = (t_dyn - t_sta) / max(t_sta, 1)
    report("table1.mobilenet.defrag_overhead_pct", t_dyn, overhead * 100)

    # ---- offline arena plan (paper §6 extension) ----------------------
    plan = ArenaPlanner.plan(g, g.default_schedule())
    ArenaPlanner.validate(plan)
    report("table1.mobilenet.arena_plan_KB", 0, plan.arena_size / 1024)
