"""Paper Table 1: SwiftNet-Cell default vs optimal operator order, and
MobileNet-v1 static vs dynamic allocation — peak KB + interpreter timings
on the micro-interpreter simulator (512 KB SRAM target, 200 KB framework
overhead, as in the paper).

The paper's deployments are int8, so the Table-1 rows run the honest
quantized models (``quantize_graph``): their byte sizes reproduce the
paper's KB figures exactly, while the f32 builds (reported alongside for
the SwiftNet cell) cost 4x and no longer fit the budget — the point of
byte-granular accounting.
"""
import time

import numpy as np

from repro.core import ArenaPlanner, schedule, static_plan_size
from repro.graphs import (mobilenet_v1_graph, quantize_graph, random_input,
                          swiftnet_cell_graph)
from repro.mcu import MicroInterpreter

SRAM = 512 * 1024
OVERHEAD = 200 * 1024


def run(report):
    # ---- SwiftNet Cell (int8): reordering -----------------------------
    f = swiftnet_cell_graph()
    qm = quantize_graph(f, random_input(f))
    g = qm.graph
    t0 = time.perf_counter()
    res = schedule(g)
    sched_us = (time.perf_counter() - t0) * 1e6
    d_peak = g.peak_usage(g.default_schedule())
    report("table1.swiftnet.default_peak_KB", sched_us, d_peak / 1024,
           arena_bytes=d_peak, dtypes="int8")
    report("table1.swiftnet.optimal_peak_KB", sched_us, res.peak / 1024,
           arena_bytes=res.peak, dtypes="int8")
    report("table1.swiftnet.saving_KB", sched_us, (d_peak - res.peak) / 1024,
           dtypes="int8")
    report("table1.swiftnet.fits_512KB_default", 0,
           int(d_peak + OVERHEAD <= SRAM), dtypes="int8")
    report("table1.swiftnet.fits_512KB_optimal", 0,
           int(res.peak + OVERHEAD <= SRAM), dtypes="int8")
    f_peak = f.peak_usage(f.default_schedule())
    report("table1.swiftnet.f32_default_peak_KB", 0, f_peak / 1024,
           arena_bytes=f_peak, dtypes="float32")

    interp = MicroInterpreter(g)
    x = qm.quantize_inputs(random_input(f))
    rep = interp.run(x, schedule=res.schedule)
    report("table1.swiftnet.exec_us", rep.wall_time_s * 1e6,
           rep.peak_sram / 1024, arena_bytes=rep.peak_sram, dtypes="int8")
    report("table1.swiftnet.defrag_KB_moved", rep.wall_time_s * 1e6,
           rep.bytes_moved / 1024, dtypes="int8")

    # ---- MobileNet v1 (int8): static vs dynamic allocation -------------
    f = mobilenet_v1_graph()
    qm = quantize_graph(f, random_input(f))
    g = qm.graph
    x = qm.quantize_inputs(random_input(f))
    static_kb = static_plan_size(g) / 1024
    t0 = time.perf_counter()
    rep_d = MicroInterpreter(g, defragment=True).run(x)
    t_dyn = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    rep_s = MicroInterpreter(g, defragment=False).run(x)
    t_sta = (time.perf_counter() - t0) * 1e6
    for o in g.outputs:       # defrag must not change numerics
        np.testing.assert_array_equal(rep_d.outputs[o], rep_s.outputs[o])
    report("table1.mobilenet.static_KB", t_sta, static_kb,
           arena_bytes=int(static_kb * 1024), dtypes="int8")
    report("table1.mobilenet.dynamic_KB", t_dyn, rep_d.peak_sram / 1024,
           arena_bytes=rep_d.peak_sram, dtypes="int8")
    # paper: sub-1% overhead from defragmentation
    overhead = (t_dyn - t_sta) / max(t_sta, 1)
    report("table1.mobilenet.defrag_overhead_pct", t_dyn, overhead * 100,
           dtypes="int8")

    # ---- offline arena plan (paper §6 extension) ----------------------
    plan = ArenaPlanner.plan(g, g.default_schedule())
    ArenaPlanner.validate(plan, g)
    report("table1.mobilenet.arena_plan_KB", 0, plan.arena_size / 1024,
           arena_bytes=int(plan.arena_size), dtypes="int8")
