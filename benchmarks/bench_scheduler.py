"""Scheduler scaling: exact DP vs chain-contracted DP vs greedy vs beam —
runtime and solution quality over random branchy DAGs (the paper reports
O(|V|·2^|V|); this quantifies where each method stays tractable)."""
import random
import time

from repro.core import (beam_schedule, greedy_schedule, minimise_peak_memory,
                        minimise_peak_memory_contracted)
from repro.core.graph import Graph


def random_branchy(seed, n_ops, fanout=0.3):
    rng = random.Random(seed)
    g = Graph()
    g.add_tensor("in", 64)
    frontier = ["in"]
    for k in range(n_ops):
        out = f"a{k}"
        g.add_tensor(out, rng.choice([16, 32, 64, 128, 256]))
        src = rng.choice(frontier[-4:])
        ins = [src]
        if rng.random() < fanout and len(frontier) > 2:
            ins.append(rng.choice(frontier))
        g.add_operator(f"op{k}", ins, out)
        frontier.append(out)
    sinks = [t for t in g.tensors if not g.consumers(t) and g.producer(t)]
    g.set_outputs(sinks)
    return g


def run(report):
    for n in (8, 12, 16, 20):
        g = random_branchy(42, n)
        t0 = time.perf_counter()
        exact = minimise_peak_memory(g)
        t_exact = (time.perf_counter() - t0) * 1e6
        report(f"scheduler.exact.n{n}", t_exact, exact.peak)
    for n in (16, 32, 64, 128):
        g = random_branchy(42, n)
        ub = greedy_schedule(g).peak + 1
        t0 = time.perf_counter()
        c = minimise_peak_memory_contracted(g, upper_bound=ub,
                                            max_states=100_000)
        t_c = (time.perf_counter() - t0) * 1e6
        report(f"scheduler.contracted.n{n}", t_c,
               c.peak if c else -1)   # -1 = budget hit -> beam fallback
        t0 = time.perf_counter()
        gr = greedy_schedule(g)
        report(f"scheduler.greedy.n{n}",
               (time.perf_counter() - t0) * 1e6, gr.peak)
        t0 = time.perf_counter()
        bm = beam_schedule(g, width=32)
        report(f"scheduler.beam32.n{n}",
               (time.perf_counter() - t0) * 1e6, bm.peak)
