"""Scheduler scaling: exact DP vs chain-contracted DP vs greedy vs beam vs
the joint branch-and-bound solver — runtime and solution quality over
random branchy DAGs (the paper reports O(|V|·2^|V|); this quantifies where
each method stays tractable), plus the solver's Pareto front on a
sliceable chain (the row the CI gate pins point-by-point).

Smoke mode (``run.py --smoke`` / ``REPRO_BENCH_SMOKE=1``) keeps the small
sizes only, so the CI leg stays fast while the full run still sweeps the
tractability cliff.
"""
import os
import random
import time

from repro.core import (beam_schedule, greedy_schedule, minimise_peak_memory,
                        minimise_peak_memory_contracted, schedule, solve)
from repro.core.graph import Graph
from repro.core.partition import PEX_ATTR, SliceSpec


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_BENCH_SMOKE"))


def random_branchy(seed, n_ops, fanout=0.3):
    rng = random.Random(seed)
    g = Graph()
    g.add_tensor("in", 64)
    frontier = ["in"]
    for k in range(n_ops):
        out = f"a{k}"
        g.add_tensor(out, rng.choice([16, 32, 64, 128, 256]))
        src = rng.choice(frontier[-4:])
        ins = [src]
        if rng.random() < fanout and len(frontier) > 2:
            ins.append(rng.choice(frontier))
        g.add_operator(f"op{k}", ins, out)
        frontier.append(out)
    sinks = [t for t in g.tensors if not g.consumers(t) and g.producer(t)]
    g.set_outputs(sinks)
    return g


def pareto_chain(n_ops=6, h=64, row_bytes=(64, 256, 256, 192, 256, 128, 64)):
    """A deterministic sliceable conv-ish chain with a fat interior: the
    joint solver's showcase graph (slicing the middle trades recompute
    MACs for peak bytes, so the front has several points)."""
    g = Graph()
    g.add_tensor("in", h * row_bytes[0], shape=(h,))
    prev = "in"
    for i in range(n_ops):
        out = f"t{i}"
        g.add_tensor(out, h * row_bytes[i + 1], shape=(h,))
        op = g.add_operator(f"op{i}", [prev], out)
        op.attrs[PEX_ATTR] = SliceSpec(kernel=3, stride=1,
                                       sliced_inputs=(0,),
                                       macs_per_row=row_bytes[i + 1])
        prev = out
    g.set_outputs([prev])
    return g


def run(report):
    exact_sizes = (8, 12) if _smoke() else (8, 12, 16, 20)
    for n in exact_sizes:
        g = random_branchy(42, n)
        t0 = time.perf_counter()
        exact = minimise_peak_memory(g)
        t_exact = (time.perf_counter() - t0) * 1e6
        report(f"scheduler.exact.n{n}", t_exact, exact.peak)

    heur_sizes = (16, 32) if _smoke() else (16, 32, 64, 128)
    for n in heur_sizes:
        g = random_branchy(42, n)
        ub = greedy_schedule(g).peak + 1
        t0 = time.perf_counter()
        c = minimise_peak_memory_contracted(g, upper_bound=ub,
                                            max_states=100_000)
        t_c = (time.perf_counter() - t0) * 1e6
        report(f"scheduler.contracted.n{n}", t_c,
               c.peak if c else -1)   # -1 = budget hit -> beam fallback
        t0 = time.perf_counter()
        gr = greedy_schedule(g)
        report(f"scheduler.greedy.n{n}",
               (time.perf_counter() - t0) * 1e6, gr.peak)
        t0 = time.perf_counter()
        bm = beam_schedule(g, width=32)
        report(f"scheduler.beam32.n{n}",
               (time.perf_counter() - t0) * 1e6, bm.peak)

    # ---- solver vs ladder: same graphs, wall-clock + node counts --------
    solver_sizes = (8, 12) if _smoke() else (8, 12, 16, 20)
    for n in solver_sizes:
        g = random_branchy(42, n)
        t0 = time.perf_counter()
        lad = schedule(g, solver_nodes=0)    # the pre-solver ladder alone
        report(f"scheduler.ladder.n{n}",
               (time.perf_counter() - t0) * 1e6, lad.peak)
        t0 = time.perf_counter()
        sr = solve(g, max_rewrites=0, max_nodes=50_000)
        report(f"scheduler.solver.n{n}",
               (time.perf_counter() - t0) * 1e6, sr.best.peak,
               nodes=sr.nodes)
        # the rung contract the property suite also pins: never worse
        assert sr.best.peak <= lad.peak or not sr.complete

    # ---- the Pareto showcase: joint order x split search on a chain -----
    g = pareto_chain()
    t0 = time.perf_counter()
    sr = solve(g, max_k=8, max_nodes=50_000)
    us = (time.perf_counter() - t0) * 1e6
    front = [[p.extra_macs, p.peak] for p in sr.front]
    report("scheduler.pareto.chain", us, sr.best.peak,
           arena_bytes=sr.best.peak, dtypes="int8",
           pareto=front, nodes=sr.nodes)
