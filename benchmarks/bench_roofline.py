"""Aggregate the dry-run JSONs into the §Roofline table (one row per
arch × shape on the single-pod mesh).  Derived value = step-time lower
bound in ms from the dominant term."""
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def rows(mesh="single"):
    out = []
    for f in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        out.append(json.load(open(f)))
    return out


def run(report):
    rs = rows()
    if not rs:
        print("  (no dry-run results yet — run "
              "`python -m repro.launch.dryrun` first)")
        return
    for r in rs:
        roof = r["roofline"]
        name = f"roofline.{r['arch']}.{r['shape']}"
        report(name, r["compile_s"] * 1e6, roof["step_time_lb_s"] * 1e3)
    print(f"\n  {'arch':24s} {'shape':12s} {'dom':12s} "
          f"{'comp_ms':>8s} {'mem_ms':>8s} {'coll_ms':>8s} "
          f"{'useful%':>8s} {'HBM_GB':>7s}")
    for r in rs:
        roof = r["roofline"]
        peak = r["memory_analysis"].get("peak_memory_in_bytes", 0) / 1e9
        print(f"  {r['arch']:24s} {r['shape']:12s} "
              f"{roof['dominant'][:-2]:12s} "
              f"{roof['compute_s']*1e3:8.2f} {roof['memory_s']*1e3:8.2f} "
              f"{roof['collective_s']*1e3:8.2f} "
              f"{roof.get('useful_flop_fraction', 0)*100:8.1f} "
              f"{peak:7.2f}")
