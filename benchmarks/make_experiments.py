"""Generate EXPERIMENTS.md from the dry-run / perf artifacts.

    PYTHONPATH=src python -m benchmarks.make_experiments

Narrative text lives here; all numbers come from benchmarks/results/.
"""
import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
DRY = os.path.join(HERE, "results", "dryrun")
PERF = os.path.join(HERE, "results", "perf")
OUT = os.path.join(os.path.dirname(HERE), "EXPERIMENTS.md")

ARCH_ORDER = ["phi3.5-moe-42b-a6.6b", "llama3.2-3b", "internvl2-1b",
              "qwen2-7b", "granite-moe-1b-a400m", "zamba2-2.7b",
              "phi3-medium-14b", "whisper-large-v3", "glm4-9b", "xlstm-350m"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

FIX_HINTS = {
    ("memory_s", "decode"): "stream the KV cache through the Pallas decode "
        "kernel (bf16 end-to-end, no convert round-trips) and fuse the "
        "rolling-cache update",
    ("memory_s", "train"): "raise arithmetic intensity: larger microbatch "
        "per device, fp8/bf16 master copies, fused optimizer update",
    ("memory_s", "prefill"): "larger attention chunks (more reuse per HBM "
        "read) and fused QKV projections",
    ("collective_s", "train"): "overlap weight/expert all-gathers with "
        "compute (async collectives) or drop FSDP re-gather via ZeRO-1",
    ("collective_s", "prefill"): "context-parallel activations + FSDP "
        "weight gather instead of per-layer activation all-reduce",
    ("collective_s", "decode"): "shard the cache, not the heads; merge "
        "partial softmaxes (flash-decoding)",
    ("compute_s", "train"): "already compute-bound — approach MFU via "
        "remat policy tuning",
}


def load(d, mesh=None, variant_none=True):
    out = {}
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        if mesh and r["mesh"] != mesh:
            continue
        if variant_none and r.get("variant"):
            continue
        out[(r["arch"], r["shape"], r["mesh"], r.get("variant", ""))] = r
    return out


def fmt_bytes(b):
    return f"{b / 1e9:.2f}"


def shape_kind(shape):
    return {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "decode"}[shape]


def main():
    single = load(DRY, "single")
    multi = load(DRY, "multi")
    perf = {}
    if os.path.isdir(PERF):
        for f in sorted(glob.glob(os.path.join(PERF, "*.json"))):
            r = json.load(open(f))
            perf[(r["arch"], r["shape"], r.get("variant", ""))] = r

    L = []
    w = L.append
    w("# EXPERIMENTS\n")
    w("Reproduction + systems evaluation for *Neural networks on "
      "microcontrollers: saving memory at inference via operator "
      "reordering* (Liberis & Lane, 2019). Paper-validation numbers are "
      "asserted in `tests/` and printed by `python -m benchmarks.run`; "
      "this file holds the dry-run, roofline, and perf-iteration results "
      "for the TPU-pod system built around the paper's technique.\n")

    # ------------------------------------------------------ paper validation
    w("## §Paper-validation (the faithful baseline)\n")
    w("| paper claim | paper value | this repo | where |")
    w("|---|---|---|---|")
    w("| Figure 1/2: default-order peak of the example graph | 5,216 B | "
      "**5,216 B** (bit-exact per-operator table) | "
      "`tests/test_core_scheduler.py::test_figure1_default_order_matches_paper_figure2` |")
    w("| Figure 3: optimal-order peak found by Algorithm 1 | 4,960 B | "
      "**4,960 B** (schedule 1,4,6,2,3,5,7 recovered) | "
      "`test_algorithm1_finds_paper_optimum` |")
    w("| Table 1: SwiftNet-Cell peak, default → optimal | 351 → 301 KB "
      "(−50 KB) | 360 → 306 KB (−54 KB) on our reconstructed cell (exact "
      "cell graph unpublished; same shape/regime) | `benchmarks/bench_table1.py` |")
    w("| Fits 512 KB SRAM only after reordering | ✓ | ✓ (with the paper's "
      "≈200 KB framework overhead: 560 KB ✗ → 506 KB ✓) | "
      "`tests/test_mcu.py::test_swiftnet_fits_only_with_optimised_order` |")
    w("| Table 1: MobileNet-v1 static → dynamic alloc | 241 → 55 KB | "
      "226 → **54 KB** (55,296 B peak exactly matches the paper's 55 KB) | "
      "`tests/test_mcu.py::test_mobilenet_dynamic_vs_static_alloc` |")
    w("| Reordering does not change outputs | ✓ | bit-identical across "
      "schedules | `test_reordering_is_output_invariant` |")
    w("| Defrag overhead | <1 % latency | bytes-moved accounting + <1 % "
      "interpreter overhead on CPU timings | `bench_table1` |\n")
    w("Two findings against the paper's own text (documented in "
      "DESIGN.md/code):\n")
    w("1. **Algorithm 1 double-counts multi-consumer constants** — line 18 "
      "adds `Σ|cs|` on top of a `here`-term that may already include a "
      "constant consumed by the candidate's producer. Found by a hypothesis "
      "property test; fixed with set-deduplicated accounting (identical on "
      "the paper's own graphs).")
    w("2. **Chain contraction is not exactness-preserving**: the optimum "
      "may interleave chains (running another chain's op mid-chain frees a "
      "held tensor earlier). Our contracted DP is therefore labelled "
      "near-exact and property-tested as an upper bound.\n")
    w("Also implemented: the paper's §6 proposed extension (accumulate an "
      "add into a dying input, eliminating its output buffer) as an "
      "`inplace` operator attribute in the working-set model "
      "(`test_inplace_accumulation_paper_s6_extension`).\n")

    # ------------------------------------------------------------- dry-run
    w("## §Dry-run\n")
    w("Production mesh 16×16 (`data`,`model`) = 256 chips/pod; multi-pod "
      "2×16×16 (`pod`,`data`,`model`) = 512 chips, forced-host-device "
      "lowering (no allocation, inputs are ShapeDtypeStructs). Every "
      "(architecture × applicable shape) lowers **and compiles** on both "
      "meshes; whisper × long_500k is skipped by policy (DESIGN.md §6). "
      "`memory_analysis()` is per-device.\n")
    w("Counting methodology: XLA's HloCostAnalysis visits a while-loop "
      "body once, so FLOPs/bytes/collectives are taken from a second, "
      "scan-UNROLLED lowering (`analysis_mode=unrolled`; exact trip-count "
      "accounting — verified against an analytic matmul count). Two known "
      "biases, both held constant across §Perf A/Bs: (a) the CPU XLA "
      "pipeline cannot consume bf16 in dots and inserts f32 converts a TPU "
      "MXU would not emit, inflating the memory term; (b) elementwise/"
      "transcendental ops count as FLOPs, so `useful_flop_fraction` "
      "compares matmul-only MODEL_FLOPS against all-ops HLO FLOPs; "
      "(c) the CPU pipeline *promotes bf16 collectives to f32* "
      "(`add.clone_promoted` in the HLO), so collective terms are ≈2× "
      "upper bounds for bf16 traffic — uniformly, on both sides of every "
      "§Perf A/B.\n")
    w("| arch | shape | mesh | compile_s | peak GB/dev | args GB/dev | "
      "collectives |")
    w("|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh, tbl in (("single", single), ("multi", multi)):
                r = tbl.get((arch, shape, mesh, ""))
                if r is None:
                    continue
                ma = r["memory_analysis"]
                w(f"| {arch} | {shape} | {mesh} | {r['compile_s']:.0f} | "
                  f"{fmt_bytes(ma.get('peak_memory_in_bytes', 0))} | "
                  f"{fmt_bytes(ma.get('argument_size_in_bytes', 0))} | "
                  f"{r['collectives']['total'] / 1e6:.0f} MB |")
    n_s = len([1 for k in single if not k[3]])
    n_m = len([1 for k in multi if not k[3]])
    w(f"\nAll {n_s} single-pod and {n_m} multi-pod combinations lowered and "
      "compiled without error (the multi-pod pass proves the `pod` axis "
      "shards; roofline below is single-pod per the assignment).\n")

    # ------------------------------------------------------------ roofline
    w("## §Roofline (single pod, 256 × TPU v5e)\n")
    w("Constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI per link. "
      "All three terms are seconds per step from per-device quantities "
      "(the SPMD-partitioned module *is* the per-device program, so the "
      "formula's ÷chips is the partitioning itself). MODEL_FLOPS = "
      "6·N·D (train) / 2·N·D (inference), N = active params (MoE: top-k "
      "slice).\n")
    w("| arch | shape | compute_s | memory_s | collective_s | dominant | "
      "MODEL_FLOPS/HLO | what moves the dominant term |")
    w("|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = single.get((arch, shape, "single", ""))
            if r is None:
                continue
            ro = r["roofline"]
            hint = FIX_HINTS.get((ro["dominant"], shape_kind(shape)), "—")
            w(f"| {arch} | {shape} | {ro['compute_s']:.3f} | "
              f"{ro['memory_s']:.3f} | {ro['collective_s']:.3f} | "
              f"**{ro['dominant'][:-2]}** | "
              f"{ro.get('useful_flop_fraction', 0):.3f} | {hint} |")
    w("")

    # ---------------------------------------------------------------- perf
    w("## §Perf — hillclimbing log\n")
    if perf:
        w("A/B artifact summary (all unrolled-analysis, single pod, per "
          "device; rows pair with the narrative below):\n")
        w("| arch × shape | variant | peak GB | coll GB | mem_s | coll_s |")
        w("|---|---|---|---|---|---|")
        for (arch, shape, var), r in sorted(perf.items()):
            ro, ma = r["roofline"], r["memory_analysis"]
            w(f"| {arch} × {shape} | {var or 'optimised-default'} | "
              f"{ma.get('peak_memory_in_bytes', 0)/1e9:.2f} | "
              f"{r['collectives']['total']/1e9:.1f} | "
              f"{ro['memory_s']:.3f} | {ro['collective_s']:.3f} |")
        w("")
    w(open(os.path.join(HERE, "perf_log.md")).read())

    with open(OUT, "w") as f:
        f.write("\n".join(L))
    print(f"wrote {OUT} ({len(L)} lines)")


if __name__ == "__main__":
    main()
