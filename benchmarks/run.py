# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows, then the §Roofline aggregation from the dry-run artifacts.
#
#   --json PATH   also emit a machine-readable BENCH_executor.json-style
#                 trajectory (name, us_per_call, derived, arena_bytes,
#                 dtypes) so future PRs have a perf baseline to diff
#                 against (see benchmarks/compare.py for the CI gate)
#   --only a,b    run only the named benchmarks (e.g. figure1,executor)
#   --smoke       small-graph subset inside each benchmark (CI)
#
# Benchmarks call ``report(name, us_per_call, derived, **meta)``; the
# recognised meta keys are ``arena_bytes`` (peak/arena BYTES — the unit is
# part of the trajectory contract since the byte-granular dtype refactor)
# and ``dtypes`` ("float32" / "int8" / "mixed"), so the trajectory stays
# comparable across quantization changes.
import argparse
import json
import os
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write rows as JSON to PATH as well as CSV stdout")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names to run "
                         "(figure1,table1,scheduler,jaxpr,pex,executor,"
                         "kernels,roofline)")
    ap.add_argument("--smoke", action="store_true",
                    help="restrict benchmarks to their small-graph subsets")
    args = ap.parse_args(argv)
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    from . import (bench_figure1, bench_table1, bench_scheduler,
                   bench_jaxpr, bench_kernels, bench_pex, bench_roofline,
                   bench_executor)

    by_name = {
        "figure1": bench_figure1,
        "table1": bench_table1,
        "scheduler": bench_scheduler,
        "jaxpr": bench_jaxpr,
        "pex": bench_pex,
        "executor": bench_executor,
        "kernels": bench_kernels,
        "roofline": bench_roofline,
    }
    if args.only:
        unknown = [n for n in args.only.split(",") if n not in by_name]
        if unknown:
            ap.error(f"unknown benchmarks {unknown}; "
                     f"choose from {sorted(by_name)}")
        mods = [by_name[n] for n in args.only.split(",")]
    else:
        mods = list(by_name.values())

    rows = []

    def report(name, us_per_call, derived, **meta):
        rows.append((name, us_per_call, derived, meta))
        print(f"{name},{us_per_call:.1f},{derived}")

    failed = []
    for mod in mods:
        print(f"# --- {mod.__name__} ---", flush=True)
        try:
            mod.run(report)
        except Exception:
            traceback.print_exc()
            failed.append(mod.__name__)

    if args.json:
        payload = {
            "rows": [{
                "name": name,
                "us_per_call": us,
                "derived": derived if isinstance(derived, (int, float, str,
                                                           bool)) else
                repr(derived),
                # fallback: an int `derived` is a byte figure on legacy
                # rows — but only when non-negative (benchmarks use -1 as
                # a "budget exhausted" sentinel, which must not enter the
                # strict bytes gate)
                "arena_bytes": meta.get(
                    "arena_bytes",
                    derived if isinstance(derived, int)
                    and not isinstance(derived, bool)
                    and derived >= 0 else None),
                "dtypes": meta.get("dtypes"),
            } for name, us, derived, meta in rows],
            "failed": failed,
            "smoke": args.smoke,
            "units": {"us_per_call": "microseconds",
                      "arena_bytes": "bytes"},
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {len(rows)} rows to {args.json}")

    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    print(f"# {len(rows)} benchmark rows OK")


if __name__ == "__main__":
    main()
