# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows, then the §Roofline aggregation from the dry-run artifacts.
import sys
import traceback


def main() -> None:
    from . import (bench_figure1, bench_table1, bench_scheduler,
                   bench_jaxpr, bench_kernels, bench_pex, bench_roofline)

    rows = []

    def report(name, us_per_call, derived):
        rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.1f},{derived}")

    failed = []
    for mod in (bench_figure1, bench_table1, bench_scheduler, bench_jaxpr,
                bench_pex, bench_kernels, bench_roofline):
        print(f"# --- {mod.__name__} ---", flush=True)
        try:
            mod.run(report)
        except Exception:
            traceback.print_exc()
            failed.append(mod.__name__)
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    print(f"# {len(rows)} benchmark rows OK")


if __name__ == "__main__":
    main()
