# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows, then the §Roofline aggregation from the dry-run artifacts.
#
#   --json PATH   also emit a machine-readable BENCH_executor.json-style
#                 trajectory (name, us_per_call, derived, arena_bytes,
#                 dtypes) so future PRs have a perf baseline to diff
#                 against (see benchmarks/compare.py for the CI gate)
#   --only a,b    run only the named benchmarks (e.g. figure1,executor)
#   --smoke       small-graph subset inside each benchmark (CI)
#   --update-baseline [PATH]
#                 envelope-merge this run into the committed baseline
#                 (default benchmarks/BENCH_baseline.json) instead of
#                 hand-editing it: us_per_call takes the max of old and
#                 new (first-call timings vary run to run — the baseline
#                 is an envelope), arena_bytes are exact and may only
#                 shrink; growth aborts the merge unless
#                 --allow-bytes-growth is passed (a deliberate memory
#                 regression must be visible in the diff, not slipped in)
#
# Benchmarks call ``report(name, us_per_call, derived, **meta)``; the
# recognised meta keys are ``arena_bytes`` (peak/arena BYTES — the unit is
# part of the trajectory contract since the byte-granular dtype refactor),
# ``dtypes`` ("float32" / "int8" / "mixed"), ``pareto`` (the joint
# solver's memory/latency front as sorted [extra_macs, peak_bytes] pairs
# — gated point-by-point by compare.py), and ``nodes`` (solver search
# nodes, informational).  ``--pareto-json PATH`` additionally collects
# every reported front into one artifact for the CI upload / README link.
import argparse
import json
import os
import sys
import traceback

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__),
                                "BENCH_baseline.json")


def merge_baseline(baseline: dict, fresh_rows: list,
                   allow_bytes_growth: bool = False) -> list:
    """Envelope-merge ``fresh_rows`` into ``baseline`` in place: max-us,
    exact bytes (growth refused), new rows appended, rows not re-run kept.
    Returns a list of human-readable change notes; raises ``SystemExit``
    on a bytes regression without ``allow_bytes_growth``."""
    by_name = {r["name"]: r for r in baseline["rows"]}
    notes = []
    for row in fresh_rows:
        old = by_name.get(row["name"])
        if old is None:
            by_name[row["name"]] = dict(row)
            baseline["rows"].append(by_name[row["name"]])
            notes.append(f"new row {row['name']}")
            continue
        of, nf = old.get("pareto"), row.get("pareto")
        if of:
            if not nf:
                # same reasoning as the arena_bytes guard below: a merge
                # must not silently disarm the compare.py Pareto gate
                raise SystemExit(
                    f"refusing to merge: {row['name']} lost its pareto "
                    f"front (baseline has {len(of)} points); fix the "
                    f"benchmark row before refreshing the baseline")
            from .compare import front_covers
            uncovered = front_covers(of, nf)
            if uncovered and not allow_bytes_growth:
                raise SystemExit(
                    f"refusing to loosen baseline: {row['name']} pareto "
                    f"points {uncovered} no longer matched or dominated; "
                    f"pass --allow-bytes-growth if this regression is "
                    f"deliberate")
            if [list(p) for p in of] != [list(p) for p in nf]:
                notes.append(f"{row['name']}: pareto front "
                             f"{len(of)} -> {len(nf)} points")
        ob, nb = old.get("arena_bytes"), row.get("arena_bytes")
        if ob is not None and nb is None:
            # a fresh row without bytes (e.g. the -1 budget-exhausted
            # sentinel) must not wipe the committed exact figure — that
            # would silently disarm the compare.py growth gate for it
            raise SystemExit(
                f"refusing to merge: {row['name']} lost its arena_bytes "
                f"(baseline has {ob}); fix the benchmark row before "
                f"refreshing the baseline")
        if ob is not None and nb is not None and nb > ob:
            if not allow_bytes_growth:
                raise SystemExit(
                    f"refusing to loosen baseline: {row['name']} "
                    f"arena_bytes grew {ob} -> {nb} (+{nb - ob} B); "
                    f"pass --allow-bytes-growth if this regression is "
                    f"deliberate")
            notes.append(f"{row['name']}: bytes grew {ob} -> {nb} "
                         f"(--allow-bytes-growth)")
        elif ob != nb:
            notes.append(f"{row['name']}: bytes {ob} -> {nb}")
        orps, nrps = old.get("requests_per_s"), row.get("requests_per_s")
        if orps is not None and nrps is None:
            # same reasoning as arena_bytes: a merge must not silently
            # disarm the compare.py requests/s floor gate
            raise SystemExit(
                f"refusing to merge: {row['name']} lost its requests_per_s "
                f"(baseline has {orps}); fix the benchmark row before "
                f"refreshing the baseline")
        ou, nu = old.get("us_per_call"), row.get("us_per_call")
        if ou is not None and nu is not None and nu > ou:
            notes.append(f"{row['name']}: us envelope {ou:.0f} -> {nu:.0f}")
        old.update({k: v for k, v in row.items() if k != "us_per_call"})
        old["us_per_call"] = (max(ou, nu) if ou is not None
                              and nu is not None else nu or ou)
        if orps is not None and nrps is not None:
            # floor envelope: the committed figure is the weakest observed
            # run, so the CI floor gate holds on any reference-class host
            if nrps < orps:
                notes.append(f"{row['name']}: requests/s floor "
                             f"{orps:.1f} -> {nrps:.1f}")
            old["requests_per_s"] = min(orps, nrps)
    return notes


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write rows as JSON to PATH as well as CSV stdout")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names to run "
                         "(figure1,table1,scheduler,jaxpr,pex,executor,"
                         "kernels,roofline,serving)")
    ap.add_argument("--smoke", action="store_true",
                    help="restrict benchmarks to their small-graph subsets")
    ap.add_argument("--pareto-json", metavar="PATH", default=None,
                    help="collect every reported Pareto front (joint "
                         "solver memory/latency trade-offs) into one "
                         "JSON artifact at PATH")
    ap.add_argument("--update-baseline", metavar="PATH", nargs="?",
                    const=DEFAULT_BASELINE, default=None,
                    help="envelope-merge this run into the committed "
                         "baseline (max-us, exact bytes; see header)")
    ap.add_argument("--allow-bytes-growth", action="store_true",
                    help="permit --update-baseline to record larger "
                         "arena_bytes (deliberate memory regression)")
    args = ap.parse_args(argv)
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    from . import (bench_figure1, bench_table1, bench_scheduler,
                   bench_jaxpr, bench_kernels, bench_pex, bench_roofline,
                   bench_executor, bench_serving)

    by_name = {
        "figure1": bench_figure1,
        "table1": bench_table1,
        "scheduler": bench_scheduler,
        "jaxpr": bench_jaxpr,
        "pex": bench_pex,
        "executor": bench_executor,
        "kernels": bench_kernels,
        "roofline": bench_roofline,
        "serving": bench_serving,
    }
    if args.only:
        unknown = [n for n in args.only.split(",") if n not in by_name]
        if unknown:
            ap.error(f"unknown benchmarks {unknown}; "
                     f"choose from {sorted(by_name)}")
        mods = [by_name[n] for n in args.only.split(",")]
    else:
        mods = list(by_name.values())

    rows = []

    def report(name, us_per_call, derived, **meta):
        rows.append((name, us_per_call, derived, meta))
        print(f"{name},{us_per_call:.1f},{derived}")

    failed = []
    for mod in mods:
        print(f"# --- {mod.__name__} ---", flush=True)
        try:
            mod.run(report)
        except Exception:
            traceback.print_exc()
            failed.append(mod.__name__)

    json_rows = []
    for name, us, derived, meta in rows:
        jr = {
            "name": name,
            "us_per_call": us,
            "derived": derived if isinstance(derived, (int, float, str,
                                                       bool)) else
            repr(derived),
            # fallback: an int `derived` is a byte figure on legacy
            # rows — but only when non-negative (benchmarks use -1 as
            # a "budget exhausted" sentinel, which must not enter the
            # strict bytes gate)
            "arena_bytes": meta.get(
                "arena_bytes",
                derived if isinstance(derived, int)
                and not isinstance(derived, bool)
                and derived >= 0 else None),
            "dtypes": meta.get("dtypes"),
        }
        # solver metadata, only on rows that carry it (keeps the committed
        # baseline free of null noise)
        if meta.get("pareto") is not None:
            jr["pareto"] = [list(p) for p in meta["pareto"]]
        if meta.get("nodes") is not None:
            jr["nodes"] = meta["nodes"]
        # serving throughput metadata: requests_per_s enters the
        # compare.py floor gate; the latency percentiles ride along.
        # tile_rows/tile_cols tag 2-D tiled-cascade rows with the steady
        # working-tile shape (rows per chunk x columns per W-strip)
        # expired/shed are the chaos-gate counters: compare.py requires
        # them to be exactly zero on no-fault serving rows
        for k in ("requests_per_s", "p50_ms", "p99_ms", "replicas",
                  "tile_rows", "tile_cols", "expired", "shed"):
            if meta.get(k) is not None:
                jr[k] = meta[k]
        json_rows.append(jr)

    if args.json:
        payload = {
            "rows": json_rows,
            "failed": failed,
            "smoke": args.smoke,
            "units": {"us_per_call": "microseconds",
                      "arena_bytes": "bytes"},
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {len(rows)} rows to {args.json}")

    if args.pareto_json:
        fronts = {r["name"]: r["pareto"] for r in json_rows
                  if r.get("pareto")}
        with open(args.pareto_json, "w") as f:
            json.dump({"fronts": fronts,
                       "units": {"point": "[extra_macs, peak_bytes]"},
                       "smoke": args.smoke}, f, indent=2)
            f.write("\n")
        print(f"# wrote {len(fronts)} Pareto fronts to {args.pareto_json}")

    if args.update_baseline:
        if failed:
            print(f"# NOT updating baseline: failed benchmarks {failed}")
        else:
            try:
                with open(args.update_baseline) as f:
                    baseline = json.load(f)
            except FileNotFoundError:
                baseline = {"rows": [],
                            "units": {"us_per_call": "microseconds",
                                      "arena_bytes": "bytes"}}
            notes = merge_baseline(baseline, json_rows,
                                   args.allow_bytes_growth)
            baseline["rows"].sort(key=lambda r: r["name"])
            baseline["note"] = ("envelope baseline: us_per_call is the max "
                                "over merged runs on the reference machine; "
                                "arena_bytes are exact (refreshed via "
                                "run.py --update-baseline)")
            with open(args.update_baseline, "w") as f:
                json.dump(baseline, f, indent=2)
                f.write("\n")
            for n in notes:
                print(f"# baseline: {n}")
            print(f"# merged {len(json_rows)} rows into "
                  f"{args.update_baseline}")

    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    print(f"# {len(rows)} benchmark rows OK")


if __name__ == "__main__":
    main()
