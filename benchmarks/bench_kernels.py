"""Kernel micro-benchmarks (interpret mode on CPU: correctness + op-level
stats; wall-clock is meaningful only on a real TPU)."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention, flash_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import attention_ref


def run(report):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, K, D = 1, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)

    t0 = time.perf_counter()
    out = flash_attention(q, k, v, causal=True, bq=64, bk=64,
                          interpret=True)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) * 1e6
    err = float(jnp.abs(out - attention_ref(q, k, v, causal=True)).max())
    report("kernels.flash_attention.max_err", dt, err)

    qd = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    lengths = jnp.asarray([S // 2], jnp.int32)
    t0 = time.perf_counter()
    od = decode_attention(qd, k, v, lengths, bs=64, interpret=True)
    od.block_until_ready()
    dt = (time.perf_counter() - t0) * 1e6
    err = float(jnp.abs(od - decode_attention_ref(qd, k, v, lengths)).max())
    report("kernels.decode_attention.max_err", dt, err)

    _run_quant(report)


def _run_quant(report):
    """Fused int8 conv kernels vs the q-op reference semantics: max_err is
    in integer output units and must be exactly 0 (bit-identity is the
    contract, not a tolerance — see tests/test_qkernels.py)."""
    import numpy as np

    from repro.graphs.cnn_ops import qconv2d, qdwconv2d
    from repro.kernels import qconv_fused, qdwconv_fused

    rng = np.random.default_rng(0)

    def qrand(shape):
        return jnp.asarray(rng.integers(-128, 128, shape, dtype=np.int8))

    qp = dict(mult=0.0123, zp_in=3, zp_out=-5)
    x = qrand((48, 48, 32))

    w1 = qrand((1, 1, 32, 64))
    t0 = time.perf_counter()
    o = qconv_fused(x, w1, stride=1, interpret=True, **qp)
    o.block_until_ready()
    dt = (time.perf_counter() - t0) * 1e6
    ref = qconv2d(x, w1, 1, qp["mult"], qp["zp_in"], qp["zp_out"])
    report("kernels.qconv1x1.max_err", dt,
           int(jnp.abs(o.astype(jnp.int32) - ref.astype(jnp.int32)).max()))

    w3 = qrand((3, 3, 32, 64))
    t0 = time.perf_counter()
    o = qconv_fused(x, w3, stride=2, interpret=True, **qp)
    o.block_until_ready()
    dt = (time.perf_counter() - t0) * 1e6
    ref = qconv2d(x, w3, 2, qp["mult"], qp["zp_in"], qp["zp_out"])
    report("kernels.qconv3x3s2.max_err", dt,
           int(jnp.abs(o.astype(jnp.int32) - ref.astype(jnp.int32)).max()))

    wd = qrand((3, 3, 32, 1))
    t0 = time.perf_counter()
    o = qdwconv_fused(x, wd, stride=1, interpret=True, **qp)
    o.block_until_ready()
    dt = (time.perf_counter() - t0) * 1e6
    ref = qdwconv2d(x, wd, 1, qp["mult"], qp["zp_in"], qp["zp_out"])
    report("kernels.qdwconv3x3.max_err", dt,
           int(jnp.abs(o.astype(jnp.int32) - ref.astype(jnp.int32)).max()))

    # fused conv->requant->residual-add (the cascade tail's epilogue op)
    from repro.graphs.cnn_ops import qadd
    from repro.kernels import qconv_add_fused

    addp = (0.71, 0.39, qp["zp_out"], 2, -7)
    res = qrand((48, 48, 64))
    t0 = time.perf_counter()
    o = qconv_add_fused(x, w1, res, stride=1, add_params=addp,
                        interpret=True, **qp)
    o.block_until_ready()
    dt = (time.perf_counter() - t0) * 1e6
    conv = qconv2d(x, w1, 1, qp["mult"], qp["zp_in"], qp["zp_out"])
    ref = qadd(conv, res, *addp)
    report("kernels.qconv_add1x1.max_err", dt,
           int(jnp.abs(o.astype(jnp.int32) - ref.astype(jnp.int32)).max()))

    _run_tpu(report, x, w1, w3, wd, res, addp, qp)


def _run_tpu(report, x, w1, w3, wd, res, addp, qp):
    """Compiled (non-interpret) leg, TPU only: wall-clock on real hardware
    plus the same bit-identity contract.  Rows only exist on TPU runners,
    so the envelope baseline on CPU machines is unaffected."""
    if jax.default_backend() != "tpu":
        return
    from repro.graphs.cnn_ops import qadd, qconv2d, qdwconv2d
    from repro.kernels import qconv_add_fused, qconv_fused, qdwconv_fused

    cases = [
        ("qconv1x1", lambda: qconv_fused(x, w1, stride=1, **qp),
         lambda: qconv2d(x, w1, 1, qp["mult"], qp["zp_in"], qp["zp_out"])),
        ("qconv3x3s2", lambda: qconv_fused(x, w3, stride=2, **qp),
         lambda: qconv2d(x, w3, 2, qp["mult"], qp["zp_in"], qp["zp_out"])),
        ("qdwconv3x3", lambda: qdwconv_fused(x, wd, stride=1, **qp),
         lambda: qdwconv2d(x, wd, 1, qp["mult"], qp["zp_in"],
                           qp["zp_out"])),
        ("qconv_add1x1",
         lambda: qconv_add_fused(x, w1, res, stride=1, add_params=addp,
                                 **qp),
         lambda: qadd(qconv2d(x, w1, 1, qp["mult"], qp["zp_in"],
                              qp["zp_out"]), res, *addp)),
    ]
    for name, fn, ref_fn in cases:
        fn().block_until_ready()          # compile outside the timing
        t0 = time.perf_counter()
        for _ in range(10):
            o = fn()
        o.block_until_ready()
        dt = (time.perf_counter() - t0) * 1e5   # us per call over 10
        err = int(jnp.abs(o.astype(jnp.int32)
                          - ref_fn().astype(jnp.int32)).max())
        assert err == 0, f"{name}: TPU leg lost bit-identity (err={err})"
        report(f"kernels.{name}.tpu_us", dt, 0)
