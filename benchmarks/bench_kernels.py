"""Kernel micro-benchmarks (interpret mode on CPU: correctness + op-level
stats; wall-clock is meaningful only on a real TPU)."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention, flash_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import attention_ref


def run(report):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, K, D = 1, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)

    t0 = time.perf_counter()
    out = flash_attention(q, k, v, causal=True, bq=64, bk=64,
                          interpret=True)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) * 1e6
    err = float(jnp.abs(out - attention_ref(q, k, v, causal=True)).max())
    report("kernels.flash_attention.max_err", dt, err)

    qd = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    lengths = jnp.asarray([S // 2], jnp.int32)
    t0 = time.perf_counter()
    od = decode_attention(qd, k, v, lengths, bs=64, interpret=True)
    od.block_until_ready()
    dt = (time.perf_counter() - t0) * 1e6
    err = float(jnp.abs(od - decode_attention_ref(qd, k, v, lengths)).max())
    report("kernels.decode_attention.max_err", dt, err)
