"""Paper Figure 1 / Appendix A reproduction: exact peak working-set numbers
for the example graph, default vs optimal schedule."""
import time

from repro.core import minimise_peak_memory, profile
from repro.graphs.figure1 import DEFAULT_PEAK, OPTIMAL_PEAK, figure1_graph


def run(report):
    g = figure1_graph()
    t0 = time.perf_counter()
    res = minimise_peak_memory(g)
    dt = (time.perf_counter() - t0) * 1e6
    default_peak = g.peak_usage(g.default_schedule())
    report("figure1.default_peak_B", dt, default_peak)
    report("figure1.optimal_peak_B", dt, res.peak)
    assert default_peak == DEFAULT_PEAK == 5216
    assert res.peak == OPTIMAL_PEAK == 4960
    print(profile.usage_table(g, g.default_schedule()))
    print()
    print(profile.usage_table(g, res.schedule))
    print(profile.compare(g, g.default_schedule(), res.schedule))
