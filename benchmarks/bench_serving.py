"""Serving throughput: requests/s at measured p50/p99 latency, single-device
micro-batching vs the sharded continuous-batching engine.

The comparison is **weak scaling at fixed per-replica lanes**: the
single-device ``GraphServingEngine`` dispatches ``lanes`` vmap lanes per
XLA call; the ``ShardedServingEngine`` dispatches ``replicas x lanes``
lanes per call across a replica mesh of forced host devices
(``--xla_force_host_platform_device_count``, SNIPPETS.md Snippets 2-3).
Per-dispatch work per replica is identical, so with >= ``replicas`` real
cores the sharded engine's requests/s scales with the mesh while per
-request p50/p99 stays at single-device levels; on fewer cores the
replicas time-share and the ratio honestly degrades (the row still
reports it).  Output rows:

    serving.<case>.single_rps    us = us/request, derived = requests/s
    serving.<case>.sharded_rps   us = us/request, derived = requests/s
    serving.<case>.speedup_x     derived = sharded / single requests/s

The ``*_rps`` rows carry ``requests_per_s`` (floor-gated by
``benchmarks/compare.py --rps-tol``), ``p50_ms``/``p99_ms``, and the
deterministic ``arena_bytes`` of the deployment (strict bytes gate).
Outputs are checked bit-identical to one-shot ``Deployment.run`` before
any timing is reported.

The whole benchmark runs in a fresh subprocess: the replica mesh only
exists if XLA_FLAGS is set before the first jax import, which the parent
(run.py) process has long since done.  ``REPRO_SERVING_DEVICES`` sets the
mesh size (default 4; the CI smoke row uses 2).

Smoke mode (REPRO_BENCH_SMOKE=1): MobileNet-0.25@96 int8 only.  Full mode
adds the headline MobileNet-1.0@192 int8 deployment and, when the host
has at least ``replicas`` cores, asserts the >=2x scale-out bar.
"""
import json
import os
import subprocess
import sys
import time

_SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
_ROW_TAG = "SERVINGROW "


# --------------------------------------------------------- subprocess side
def _bench_case(case: str, graph, qmodel, *, replicas: int, lanes: int,
                n_requests: int, use_pallas: bool):
    import numpy as np

    import repro.deploy as deploy
    from repro.graphs import random_input
    from repro.serving import GraphServingEngine, ShardedServingEngine

    d = deploy.build(qmodel.graph if qmodel else graph,
                     use_pallas=use_pallas)
    reqs = [random_input(graph, seed=i) for i in range(n_requests)]
    if qmodel:
        reqs = [qmodel.quantize_inputs(r) for r in reqs]
    outs = graph.outputs if qmodel is None else qmodel.graph.outputs
    refs = [d.run(reqs[0]), d.run(reqs[-1])]   # bit-identity anchors

    def check(results):
        for got, ref in ((results[0], refs[0]), (results[-1], refs[1])):
            for t in outs:
                np.testing.assert_array_equal(ref[t], got[t])

    single = GraphServingEngine(deployment=d, micro_batch=lanes)
    single.serve(reqs[:2 * lanes])             # warm: compiles jit(vmap)
    check(single.serve(reqs))
    s = single.stats

    sharded = ShardedServingEngine(d, replicas=replicas, lanes=lanes)
    sharded.serve(reqs[:2 * sharded.capacity])  # warm: compiles pmap(vmap)
    check(sharded.serve(reqs))
    h = sharded.stats

    meta = dict(arena_bytes=d.arena_bytes, dtypes="int8")

    def row(name, us, derived, **extra):
        print(_ROW_TAG + json.dumps(
            {"name": name, "us": us, "derived": derived,
             "meta": {**meta, **extra}}))

    # expired/shed ride on the *_rps rows and are gated exactly zero by
    # compare.py: this is the no-fault configuration, so any nonzero count
    # is an admission-layer bug, not load (DESIGN.md §12)
    row(f"serving.{case}.single_rps", s.us_per_request,
        round(s.requests_per_s, 1), requests_per_s=round(s.requests_per_s, 2),
        p50_ms=round(s.p50_ms, 2), p99_ms=round(s.p99_ms, 2),
        expired=s.expired, shed=s.shed)
    row(f"serving.{case}.sharded_rps", h.us_per_request,
        round(h.requests_per_s, 1), requests_per_s=round(h.requests_per_s, 2),
        p50_ms=round(h.p50_ms, 2), p99_ms=round(h.p99_ms, 2),
        replicas=h.replicas, expired=h.expired, shed=h.shed)
    speedup = h.requests_per_s / s.requests_per_s if s.requests_per_s else 0.0
    row(f"serving.{case}.speedup_x", h.us_per_request, round(speedup, 2))
    return speedup


def _main():
    replicas = int(os.environ.get("REPRO_SERVING_DEVICES", "4"))
    import jax

    from repro.graphs import mobilenet_v1_graph, quantize_graph, random_input

    have = jax.local_device_count()
    if have < replicas:
        raise SystemExit(f"forced host mesh missing: {have} devices, "
                         f"wanted {replicas} (XLA_FLAGS not set pre-init?)")

    g = mobilenet_v1_graph()                  # 0.25@96
    q = quantize_graph(g, random_input(g))
    t0 = time.time()
    _bench_case("mobilenet_025_96_int8", g, q, replicas=replicas,
                lanes=2, n_requests=8 * replicas, use_pallas=True)
    print(f"# smoke case done in {time.time() - t0:.1f}s", file=sys.stderr)
    if _SMOKE:
        return
    g = mobilenet_v1_graph(alpha=1.0, resolution=192)
    q = quantize_graph(g, random_input(g))
    speedup = _bench_case("mobilenet_100_192_int8", g, q,
                          replicas=replicas, lanes=2,
                          n_requests=4 * replicas, use_pallas=True)
    # the scale-out bar is physical: replicas can only run concurrently
    # on >= that many cores.  Time-shared hosts report, but don't gate.
    if (os.cpu_count() or 1) >= replicas:
        assert speedup >= 2.0, (
            f"sharded engine only {speedup:.2f}x over single-device "
            f"({replicas} replicas on {os.cpu_count()} cores)")


# ------------------------------------------------------------- parent side
def run(report):
    """Spawn the benchmark in a fresh process with the replica mesh forced
    (2 devices in smoke mode, 4 otherwise), and re-report its rows."""
    env = dict(os.environ)
    env.setdefault("REPRO_SERVING_DEVICES", "2" if _SMOKE else "4")
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = (os.path.abspath(src) + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-m", "benchmarks.bench_serving"],
                          capture_output=True, text=True, env=env)
    for line in proc.stdout.splitlines():
        if line.startswith(_ROW_TAG):
            r = json.loads(line[len(_ROW_TAG):])
            report(r["name"], r["us"], r["derived"], **r["meta"])
    if proc.returncode != 0:
        raise RuntimeError(
            f"serving subprocess failed:\n{proc.stdout}\n{proc.stderr}")


if __name__ == "__main__":
    # the mesh must be forced before jax initialises; repro.serving is
    # import-safe (lazy submodules) so this works pre-jax
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                    "src"))
    from repro.serving import force_host_devices

    force_host_devices(int(os.environ.get("REPRO_SERVING_DEVICES", "4")))
    _main()
