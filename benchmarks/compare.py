"""CI benchmark-regression gate: diff a fresh ``run.py --json`` trajectory
against the committed baseline and FAIL the workflow when the memory story
regresses.

Three gates, per row name present in both files:

* **bytes (exact, strict)** — ``arena_bytes`` may never grow.  Arena/peak
  sizes are deterministic scheduling artefacts, so any growth is a real
  cost-model/scheduler/planner regression, never noise.  A fresh row that
  *loses* its byte figure (baseline has one, fresh is null) also fails:
  that silently disarms the gate.
* **time (tolerant)** — ``us_per_call`` may not regress more than
  ``--us-tol`` (default 20%) plus an absolute ``--us-slack`` grace
  (default 5000 us) that absorbs shared-runner jitter on sub-millisecond
  rows.
* **throughput (tolerant floor)** — rows carrying ``requests_per_s``
  (the serving engine benchmark) may not drop below ``baseline *
  (1 - --rps-tol)`` (default 0.5 = half the committed floor; serving
  throughput on shared runners is noisier than single-dispatch us/call).
  Like the bytes gate, a fresh row that *loses* its throughput figure
  fails rather than silently leaving the gate.
* **chaos counters (exact, strict)** — serving rows carrying ``expired``/
  ``shed`` counts must report exactly zero: CI benchmarks run the no-fault
  configuration, so any expired or shed request is an admission-layer bug,
  not load.  Losing the counters fails like losing a byte figure.
* **Pareto (exact, strict)** — rows carrying a ``pareto`` front (a sorted
  list of ``[extra_macs, peak_bytes]`` pairs from the joint solver) must
  *cover* the baseline front: every baseline point must be matched or
  dominated (<= on both axes) by some fresh point.  Fronts are
  deterministic solver artefacts like the byte rows; an uncovered point
  means a real scheduling-quality regression.  Losing the front entirely
  fails; a new front on a row the baseline has no front for is a note.

A baseline row missing from the fresh run is a coverage regression and
fails; new rows are reported and pass (they enter the gate when the
baseline is refreshed).  The committed baseline is an **envelope**: its
``us_per_call`` is the max over several runs on the reference machine
(first-call timings include JIT compiles and vary run-to-run), while its
``arena_bytes`` are exact and identical across runs.  Refresh it by
merging a few green fresh trajectories (max of us, assert bytes equal)
over ``benchmarks/BENCH_baseline.json`` in the PR that deliberately moves
the numbers.

Usage:
    python -m benchmarks.compare benchmarks/BENCH_baseline.json \\
        BENCH_executor.json [--us-tol 0.2] [--us-slack 5000]
"""

import argparse
import json
import sys
from typing import Dict, List, Tuple


def load_rows(path: str) -> Tuple[Dict[str, dict], dict]:
    """Load one trajectory file, failing with a one-line diagnosis (file +
    offending key) instead of a raw traceback on corrupt/truncated input —
    a CI gate whose own crash hides which artefact was bad is unactionable."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except OSError as e:
        raise SystemExit(f"benchmark compare: {path}: cannot read file "
                         f"({e.strerror or e})")
    except json.JSONDecodeError as e:
        raise SystemExit(f"benchmark compare: {path}: corrupt/truncated "
                         f"JSON ({e.msg} at line {e.lineno} col {e.colno})")
    if not isinstance(payload, dict) or "rows" not in payload:
        raise SystemExit(f"benchmark compare: {path}: missing key 'rows' "
                         f"(not a run.py --json trajectory?)")
    rows = payload["rows"]
    if not isinstance(rows, list):
        raise SystemExit(f"benchmark compare: {path}: key 'rows' is "
                         f"{type(rows).__name__}, expected a list of rows")
    out: Dict[str, dict] = {}
    for i, r in enumerate(rows):
        if not isinstance(r, dict) or "name" not in r:
            raise SystemExit(f"benchmark compare: {path}: rows[{i}] missing "
                             f"key 'name' (got: {r!r:.80})")
        out[r["name"]] = r
    return out, payload


def front_covers(base_front, fresh_front) -> List[Tuple[int, int]]:
    """The baseline points NOT matched-or-dominated by any fresh point.

    Fronts are ``[extra_macs, peak_bytes]`` pairs.  A fresh point covers a
    baseline point when it is at least as good on both axes — the fresh
    front may move, but every baseline trade-off must stay achievable."""
    uncovered = []
    for be, bp in base_front:
        if not any(fe <= be and fp <= bp for fe, fp in fresh_front):
            uncovered.append((be, bp))
    return uncovered


def compare_rows(
    base: Dict[str, dict],
    fresh: Dict[str, dict],
    us_tol: float,
    us_slack: float,
    rps_tol: float = 0.5,
) -> Tuple[List[str], List[str]]:
    """(failures, notes) of diffing ``fresh`` against ``base``."""
    failures: List[str] = []
    notes: List[str] = []
    for name, b in sorted(base.items()):
        f = fresh.get(name)
        if f is None:
            failures.append(f"{name}: row missing from the fresh run (coverage regressed)")
            continue
        bb, fb = b.get("arena_bytes"), f.get("arena_bytes")
        if bb is not None and fb is None:
            failures.append(
                f"{name}: arena_bytes lost (baseline has {bb}, fresh has none — "
                f"the bytes gate would be silently disarmed)"
            )
        if bb is not None and fb is not None and fb > bb:
            failures.append(f"{name}: arena/peak bytes grew {bb} -> {fb} (+{fb - bb} B)")
        bf, ff = b.get("pareto"), f.get("pareto")
        if bf:
            if not ff:
                failures.append(f"{name}: Pareto front lost (baseline has {len(bf)} points)")
            else:
                for be, bp in front_covers(bf, ff):
                    failures.append(
                        f"{name}: Pareto point (extra_macs={be}, peak={bp} B) "
                        f"no longer matched or dominated"
                    )
        elif ff:
            notes.append(f"{name}: new Pareto front ({len(ff)} points, not in baseline yet)")
        bus, fus = b.get("us_per_call"), f.get("us_per_call")
        if bus is not None and fus is not None:
            limit = bus * (1.0 + us_tol) + us_slack
            if fus > limit:
                failures.append(
                    f"{name}: us/call regressed {bus:.0f} -> {fus:.0f} "
                    f"(limit {limit:.0f} = baseline +{us_tol:.0%} +{us_slack:.0f}us)"
                )
        brps, frps = b.get("requests_per_s"), f.get("requests_per_s")
        if brps is not None and frps is None:
            failures.append(
                f"{name}: requests_per_s lost (baseline has {brps} — "
                f"the throughput floor gate would be silently disarmed)"
            )
        if brps is not None and frps is not None:
            floor = brps * (1.0 - rps_tol)
            if frps < floor:
                failures.append(
                    f"{name}: requests/s fell {brps:.1f} -> {frps:.1f} "
                    f"(floor {floor:.1f} = baseline -{rps_tol:.0%})"
                )
        # chaos gate: serving rows carry expired/shed counts measured in
        # the no-fault configuration — they must be exactly zero (a request
        # expired or shed during a clean benchmark is an admission bug),
        # and like the other gates they may not silently disappear
        for key in ("expired", "shed"):
            bk, fk = b.get(key), f.get(key)
            if bk is not None and fk is None:
                failures.append(
                    f"{name}: {key} count lost (baseline has {bk} — the "
                    f"no-fault chaos gate would be silently disarmed)"
                )
            if fk is not None and fk != 0:
                failures.append(
                    f"{name}: {key}={fk} in the no-fault configuration "
                    f"(must be exactly 0)"
                )
        if b.get("dtypes") and f.get("dtypes") and b["dtypes"] != f["dtypes"]:
            notes.append(f"{name}: dtypes changed {b['dtypes']} -> {f['dtypes']}")
    for name in sorted(set(fresh) - set(base)):
        notes.append(f"{name}: new row (not in baseline yet)")
    return failures, notes


def _fmt(value) -> str:
    return "-" if value is None else f"{value:.0f}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("fresh", help="fresh run.py --json output")
    ap.add_argument(
        "--us-tol",
        type=float,
        default=0.2,
        help="relative us/call regression tolerance (default 0.2 = 20%%)",
    )
    ap.add_argument(
        "--us-slack",
        type=float,
        default=5000.0,
        help="absolute us/call grace for runner jitter (default 5000 us)",
    )
    ap.add_argument(
        "--rps-tol",
        type=float,
        default=0.5,
        help="relative requests/s floor tolerance (default 0.5 = may fall "
        "to half the committed floor before failing)",
    )
    args = ap.parse_args(argv)

    base, _ = load_rows(args.baseline)
    fresh, fresh_payload = load_rows(args.fresh)
    failures, notes = compare_rows(base, fresh, args.us_tol, args.us_slack,
                                   args.rps_tol)
    if fresh_payload.get("failed"):
        failures.append(f"fresh run reported failed benchmarks: {fresh_payload['failed']}")

    width = max((len(n) for n in base), default=4) + 2
    print(f"{'row':<{width}} {'base us':>10} {'fresh us':>10} {'base B':>10} {'fresh B':>10}")
    for name, b in sorted(base.items()):
        f = fresh.get(name, {})
        print(
            f"{name:<{width}} {_fmt(b.get('us_per_call')):>10} "
            f"{_fmt(f.get('us_per_call')):>10} {_fmt(b.get('arena_bytes')):>10} "
            f"{_fmt(f.get('arena_bytes')):>10}"
        )
    for n in notes:
        print(f"NOTE: {n}")
    if failures:
        print(f"\nFAIL: {len(failures)} benchmark regression(s) vs {args.baseline}:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print(
        f"\nOK: {len(base)} baseline rows hold "
        f"(bytes exact, us within {args.us_tol:.0%} + {args.us_slack:.0f}us)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
