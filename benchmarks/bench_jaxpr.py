"""jaxpr operator reordering (the paper's technique on XLA programs):
peak-liveness reduction for branchy JAX functions, a transformer block, and
the serving decode step of a smoke model."""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.jaxpr_reorder import reorder_closed_jaxpr
from repro.models.model import Model, init_cache, init_params


def _measure(report, name, fn, *args):
    closed = jax.make_jaxpr(fn)(*args)
    t0 = time.perf_counter()
    _, rep = reorder_closed_jaxpr(closed)
    dt = (time.perf_counter() - t0) * 1e6
    report(f"jaxpr.{name}.eqns", dt, rep.n_eqns)
    report(f"jaxpr.{name}.peak_before_B", dt, rep.peak_before)
    report(f"jaxpr.{name}.peak_after_B", dt, rep.peak_after)
    report(f"jaxpr.{name}.saving_pct", dt,
           100.0 * rep.saving / max(rep.peak_before, 1))


def run(report):
    def branchy(x):
        t = jnp.tanh(x)
        a = jnp.tanh(t @ t.T).sum(axis=1)
        b = t.sum(axis=1)
        return a + b

    _measure(report, "branchy", branchy, jnp.ones((256, 256)))

    cfg = get_config("llama3.2-3b@smoke")
    model = Model(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 32), jnp.int32)}
    _measure(report, "train_loss", lambda p, b: model.loss_fn(
        p, b, remat=False)[0], params, batch)

    cache = init_cache(cfg, 2, 32)
    tok = jnp.zeros((2,), jnp.int32)
    _measure(report, "decode_step",
             lambda p, c, t: model.decode_step(p, c, t)[0],
             params, cache, tok)
