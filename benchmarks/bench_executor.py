"""Compiled arena executor vs the Python-loop MicroInterpreter: us/call on
figure1 and MobileNet-{0.5,1.0}@192, reorder-only and reorder+pex, at both
element widths (float32 and post-training int8).

Two interpreter numbers are reported, because they answer different
questions on this (server-CPU) rig:

* ``interp_us`` — the interpreter's first call in this process: the Python
  schedule loop plus per-operator dispatch/tracing.  This is the cost a
  TFLM-style interpreted runtime pays per operator and what the compiled
  executor eliminates — the acceptance bar (>=5x on MobileNet-1.0@192) is
  asserted against it.
* ``interp_warm_us`` — a repeat call after jax's eager dispatch caches are
  hot.  At 192x192 resolution the convolutions dominate and XLA runs them
  the same way in both executors, so this ratio approaches the compute
  floor (~1.4x here); on MCU-class single-shot inference there is no warm
  process to amortise into.

Output rows (all byte figures are bytes; rows carry ``arena_bytes`` and
``dtypes`` metadata into the --json trajectory):
    executor.<case>.interp_us        first interpreter pass (per-op dispatch)
    executor.<case>.interp_warm_us   warm interpreter pass
    executor.<case>.compiled_us      one jitted arena-program call (warm)
    executor.<case>.speedup_x        interp_us / compiled_us (derived)
    executor.<case>.arena_B          the plan the program executes against
    executor.<case>.pallas_us        warm call with the fused int8 kernels
                                     (use_pallas=True; int8 graphs only)
    executor.<case>.pallas_speedup_x default-lowering warm / pallas warm

The MobileNet@192 cases run in a fresh subprocess (``python -m
benchmarks.bench_executor``): earlier benchmarks in the same process warm
jax's eager-dispatch caches for exactly these shapes, which would silently
turn the first-call measurement into a warm one.

Smoke mode (REPRO_BENCH_SMOKE=1, set by ``run.py --smoke``) keeps only the
small graphs so CI stays fast.
"""
import os
import subprocess
import sys
import time

import numpy as np

import repro.deploy as deploy
from repro.graphs import (figure1_executable_graph, figure1_int8_graph,
                          graph_dtypes, mobilenet_v1_graph, quantize_graph,
                          random_input)
from repro.mcu import MicroInterpreter, compile_schedule

KB = 1024
MB = 1024 * KB
_SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _case(report, name, g, cap=None, repeats=3):
    # the facade runs schedule -> plan -> validate -> compile in one call
    d = deploy.build(g, arena_budget=cap)
    res, plan = d.schedule_result, d.plan
    gp = d.exec_graph
    x = random_input(g)
    dtypes = graph_dtypes(g)

    interp = MicroInterpreter(gp)
    t0 = time.perf_counter()
    rep = interp.run(x, schedule=res.schedule)
    interp_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    rep = interp.run(x, schedule=res.schedule)
    interp_warm_us = (time.perf_counter() - t0) * 1e6

    out = d.run(x)                       # warm-up: traces + compiles
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = d.run(x)
    compiled_us = (time.perf_counter() - t0) * 1e6 / repeats

    for o in g.outputs:                  # the executor must not drift
        np.testing.assert_array_equal(rep.outputs[o], out[o])
    speedup = interp_us / compiled_us
    meta = dict(arena_bytes=int(plan.arena_size), dtypes=dtypes)
    report(f"executor.{name}.interp_us", interp_us, res.peak, **meta)
    report(f"executor.{name}.interp_warm_us", interp_warm_us, res.peak,
           **meta)
    report(f"executor.{name}.compiled_us", compiled_us, plan.arena_size,
           **meta)
    report(f"executor.{name}.speedup_x", compiled_us, round(speedup, 1),
           **meta)
    report(f"executor.{name}.arena_B", compiled_us, plan.arena_size, **meta)
    return speedup


def _pallas_case(report, name, g, cap=None, repeats=3, base_repeats=1):
    """Fused int8 kernels (``use_pallas=True``, DESIGN.md §9) vs the default
    XLA-int32-conv lowering on the *same* schedule and arena plan: warm
    us/call both ways, bit-identity, and the arena-bytes-unchanged
    invariant (the kernels change lowering only, never placement).  The
    default side runs ``base_repeats`` times — it is the slow side by two
    orders of magnitude on conv-heavy int8 graphs."""
    d = deploy.build(g, arena_budget=cap)
    gp, plan = d.exec_graph, d.plan
    x = random_input(g)

    base = d.executor
    fused = compile_schedule(gp, d.schedule, plan, use_pallas=True)
    assert fused.arena_size == base.arena_size == plan.arena_size

    out_base = base.run(x)               # warm-up: traces + compiles
    t0 = time.perf_counter()
    for _ in range(base_repeats):
        out_base = base.run(x)
    base_us = (time.perf_counter() - t0) * 1e6 / base_repeats

    out_fused = fused.run(x)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out_fused = fused.run(x)
    fused_us = (time.perf_counter() - t0) * 1e6 / repeats

    for o in g.outputs:                  # fused kernels must not drift
        np.testing.assert_array_equal(out_base[o], out_fused[o])
    speedup = base_us / fused_us
    meta = dict(arena_bytes=int(plan.arena_size), dtypes=graph_dtypes(g))
    report(f"executor.{name}.pallas_us", fused_us, plan.arena_size, **meta)
    report(f"executor.{name}.pallas_speedup_x", fused_us,
           round(speedup, 1), **meta)
    return speedup


def _quantized_mobilenet(**kw):
    g = mobilenet_v1_graph(**kw)
    return quantize_graph(g, random_input(g)).graph


def _headline_cases(report):
    """The MobileNet@192 sweep; asserts the >=5x acceptance bar.  The f32
    builds carry 4 bytes per element since the byte-granular refactor, so
    the pex budgets are the old element budgets x4; the int8 build is the
    one that meets real MCU byte budgets (see bench_pex)."""
    _case(report, "mobilenet_050_192.reorder",
          mobilenet_v1_graph(alpha=0.5, resolution=192))
    _case(report, "mobilenet_050_192.pex",
          mobilenet_v1_graph(alpha=0.5, resolution=192), cap=1 * MB)
    _case(report, "mobilenet_100_192.reorder",
          mobilenet_v1_graph(alpha=1.0, resolution=192))
    s = _case(report, "mobilenet_100_192.pex",
              mobilenet_v1_graph(alpha=1.0, resolution=192), cap=2 * MB)
    assert s >= 5.0, f"compiled executor only {s:.1f}x over the interpreter"
    # the int8 deployment graph with the fused kernels: the §9 acceptance
    # bar is >=5x warm over the default lowering (measured ~300x)
    sp = _pallas_case(report, "mobilenet_100_192_int8.reorder",
                      _quantized_mobilenet(alpha=1.0, resolution=192))
    assert sp >= 5.0, f"fused int8 kernels only {sp:.1f}x over the lowering"


def _parse_derived(text):
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            continue
    return text


def run(report):
    _case(report, "figure1", figure1_executable_graph(), repeats=20)
    _case(report, "figure1_int8", figure1_int8_graph(), repeats=20)
    _case(report, "mobilenet_025_96", mobilenet_v1_graph())
    _case(report, "mobilenet_025_96_int8", _quantized_mobilenet())
    # fused int8 kernels vs the default lowering on the small int8 build —
    # runs in smoke mode too, so the CI gate always exercises the
    # use_pallas=True compile + bit-identity path
    _pallas_case(report, "mobilenet_025_96_int8", _quantized_mobilenet())
    if _SMOKE:
        return
    # fresh process: see module docstring
    proc = subprocess.run([sys.executable, "-m", "benchmarks.bench_executor"],
                          capture_output=True, text=True)
    for line in proc.stdout.splitlines():
        if line.startswith("executor."):
            parts = line.split(",")
            report(parts[0], float(parts[1]), _parse_derived(parts[2]),
                   dtypes=parts[3] if len(parts) > 3 else "float32")
    if proc.returncode != 0:
        raise RuntimeError(
            f"headline subprocess failed:\n{proc.stdout}\n{proc.stderr}")


if __name__ == "__main__":
    def _report(name, us_per_call, derived, **meta):
        print(f"{name},{us_per_call:.1f},{derived},"
              f"{meta.get('dtypes', 'float32')}")
    _headline_cases(_report)
