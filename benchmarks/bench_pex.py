"""Partial execution (Pex) benchmark: peak SRAM for {static allocation,
reorder-only, reorder + partial execution} across the paper graphs, plus the
headline capacity demos — models that fit a 512 KB (and a stretch 256 KB)
arena with reorder+partial but **cannot** with reordering alone.

Output rows (bytes):
    pex.<graph>.static_B            all-tensors-resident planning
    pex.<graph>.reorder_B           best reordered schedule, whole operators
    pex.<graph>.reorder_partial_B   reordering over the partitioned graph
    pex.<graph>.arena_plan_B        offline arena plan of the winning schedule

The capacity demos execute both graphs through the micro-interpreter and
assert bit-identical outputs — partial execution must not change numerics.
"""
import time

import numpy as np

from repro.core import ArenaPlanner, schedule, static_plan_size
from repro.graphs import (figure1_graph, mobilenet_v1_graph,
                          swiftnet_cell_graph)
from repro.mcu import MicroInterpreter

KB = 1024


def _case(report, name, g, cap=None):
    t0 = time.perf_counter()
    base = schedule(g)
    res = schedule(g, arena_budget=cap, partition=cap is None)
    dt = (time.perf_counter() - t0) * 1e6
    gp = res.graph if res.graph is not None else g
    plan = ArenaPlanner.plan(gp, res.schedule)
    ArenaPlanner.validate(plan)
    report(f"pex.{name}.static_B", dt, static_plan_size(g))
    report(f"pex.{name}.reorder_B", dt, base.peak)
    report(f"pex.{name}.reorder_partial_B", dt, res.peak)
    report(f"pex.{name}.arena_plan_B", dt, plan.arena_size)
    return base, res, plan


def _assert_bit_identical(g, res):
    h, w, c = g.tensors["input"].shape
    rng = np.random.default_rng(0)
    x = {"input": rng.standard_normal((h, w, c)).astype(np.float32)}
    ref = MicroInterpreter(g).run(x)
    got = MicroInterpreter(res.graph).run(x, schedule=res.schedule)
    for o in g.outputs:
        np.testing.assert_array_equal(ref.outputs[o], got.outputs[o])
    assert got.peak_sram == res.peak, (got.peak_sram, res.peak)


def run(report):
    # ---- the paper graphs: partial execution composes with reordering
    _case(report, "figure1", figure1_graph())          # too small to slice
    base, res, _ = _case(report, "mobilenet_025_96", mobilenet_v1_graph())
    assert res.peak < base.peak, "pure chain: partial execution must win"
    _case(report, "swiftnet_96", swiftnet_cell_graph())

    # ---- headline: fits 512 KB only with reorder+partial ----------------
    cap = 512 * KB
    g = mobilenet_v1_graph(alpha=1.0, resolution=192)
    base, res, plan = _case(report, "mobilenet_100_192", g, cap=cap)
    assert base.peak > cap, "reorder-only must NOT fit 512 KB"
    assert res.peak <= cap and plan.arena_size <= cap, "pex must fit 512 KB"
    _assert_bit_identical(g, res)
    report("pex.mobilenet_100_192.fits_512K", 0.0,
           int(plan.arena_size <= cap))

    # ---- stretch: 256 KB ------------------------------------------------
    cap = 256 * KB
    g = mobilenet_v1_graph(alpha=0.5, resolution=192)
    base, res, plan = _case(report, "mobilenet_050_192", g, cap=cap)
    assert base.peak > cap, "reorder-only must NOT fit 256 KB"
    assert res.peak <= cap and plan.arena_size <= cap, "pex must fit 256 KB"
    _assert_bit_identical(g, res)
    report("pex.mobilenet_050_192.fits_256K", 0.0,
           int(plan.arena_size <= cap))
