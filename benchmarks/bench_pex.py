"""Partial execution (Pex) benchmark: peak SRAM for {static allocation,
reorder-only, reorder + partial execution} across the paper graphs, plus
the headline capacity demos.

Since the byte-granular dtype refactor the float builds carry honest
4-byte elements, so the MCU capacity demos run on the **int8** models
(``quantize_graph`` / ``int8_scheduling_graph`` — byte-identical sizes):
MobileNet-1.0@192 fits a 512 KB arena with int8+reorder+pex (f32
reorder-only needs 3456 KB, int8 reorder-only 864 KB) and
MobileNet-0.5@192 fits the 256 KB stretch target.  Numeric bit-identity of
the partitioned int8 model is asserted through the micro-interpreter on
the person-detection build (0.25@96) — partial execution must not change
quantized numerics either.

Output rows (bytes; ``dtypes`` metadata tags the element width):
    pex.<graph>.static_B            all-tensors-resident planning
    pex.<graph>.reorder_B           best reordered schedule, whole operators
    pex.<graph>.reorder_partial_B   reordering over the partitioned graph
    pex.<graph>.arena_plan_B        offline arena plan of the winning schedule
"""
import time

import numpy as np

from repro.core import ArenaPlanner, schedule, static_plan_size
from repro.graphs import (figure1_graph, graph_dtypes,
                          int8_scheduling_graph, mobilenet_v1_graph,
                          quantize_graph, random_input, swiftnet_cell_graph)
from repro.mcu import MicroInterpreter

KB = 1024


def _case(report, name, g, cap=None, dtypes=None):
    if dtypes is None:
        dtypes = graph_dtypes(g)
    t0 = time.perf_counter()
    base = schedule(g)
    res = schedule(g, arena_budget=cap, partition=cap is None)
    dt = (time.perf_counter() - t0) * 1e6
    gp = res.graph if res.graph is not None else g
    plan = ArenaPlanner.plan(gp, res.schedule)
    ArenaPlanner.validate(plan, gp)
    report(f"pex.{name}.static_B", dt, static_plan_size(g),
           arena_bytes=static_plan_size(g), dtypes=dtypes)
    report(f"pex.{name}.reorder_B", dt, base.peak,
           arena_bytes=base.peak, dtypes=dtypes)
    report(f"pex.{name}.reorder_partial_B", dt, res.peak,
           arena_bytes=res.peak, dtypes=dtypes)
    report(f"pex.{name}.arena_plan_B", dt, plan.arena_size,
           arena_bytes=int(plan.arena_size), dtypes=dtypes)
    return base, res, plan


def _assert_bit_identical(g, res, x):
    ref = MicroInterpreter(g).run(x)
    got = MicroInterpreter(res.graph).run(x, schedule=res.schedule)
    for o in g.outputs:
        np.testing.assert_array_equal(ref.outputs[o], got.outputs[o])
    assert got.peak_sram == res.peak, (got.peak_sram, res.peak)


def run(report):
    # ---- the paper graphs (f32): partial execution composes with reorder
    _case(report, "figure1", figure1_graph())          # too small to slice
    base, res, _ = _case(report, "mobilenet_025_96", mobilenet_v1_graph())
    assert res.peak < base.peak, "pure chain: partial execution must win"
    _case(report, "swiftnet_96", swiftnet_cell_graph())

    # ---- int8 x reorder x pex composes bit-identically (person detection)
    g = mobilenet_v1_graph()
    qm = quantize_graph(g, random_input(g))
    base, res, _ = _case(report, "mobilenet_025_96_int8", qm.graph)
    assert res.peak < base.peak
    assert res.graph is not None
    _assert_bit_identical(qm.graph, res,
                          qm.quantize_inputs(random_input(g)))

    # ---- headline: int8 fits 512 KB only with reorder+partial ----------
    cap = 512 * KB
    q = int8_scheduling_graph(mobilenet_v1_graph(alpha=1.0, resolution=192))
    base, res, plan = _case(report, "mobilenet_100_192_int8", q, cap=cap)
    assert base.peak > cap, "int8 reorder-only must NOT fit 512 KB"
    assert res.peak <= cap and plan.arena_size <= cap, "pex must fit 512 KB"
    report("pex.mobilenet_100_192_int8.fits_512K", 0.0,
           int(plan.arena_size <= cap), dtypes="int8")

    # ---- cascaded streaming: the same model fits 256 KB ----------------
    # (whole-externals pex has a ~280 KB floor here: the 108 KB input plus
    # a whole segment accumulator; ring-buffer cascading breaks it)
    cap = 256 * KB
    whole_pex_arena = plan.arena_size
    base, res, plan = _case(report, "mobilenet_100_192_int8_cascade", q,
                            cap=cap)
    assert "cascade" in res.method, "256 KB must need cascaded streaming"
    assert res.peak <= cap and plan.arena_size <= cap, \
        "cascade must fit 256 KB"
    assert plan.arena_size < whole_pex_arena, \
        "cascade must beat the whole-externals arena"
    report("pex.mobilenet_100_192_int8.fits_256K", 0.0,
           int(plan.arena_size <= cap), dtypes="int8")

    # ---- stretch: 256 KB -----------------------------------------------
    cap = 256 * KB
    q = int8_scheduling_graph(mobilenet_v1_graph(alpha=0.5, resolution=192))
    base, res, plan = _case(report, "mobilenet_050_192_int8", q, cap=cap)
    assert base.peak > cap, "int8 reorder-only must NOT fit 256 KB"
    assert res.peak <= cap and plan.arena_size <= cap, "pex must fit 256 KB"
    report("pex.mobilenet_050_192_int8.fits_256K", 0.0,
           int(plan.arena_size <= cap), dtypes="int8")
