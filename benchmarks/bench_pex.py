"""Partial execution (Pex) benchmark: peak SRAM for {static allocation,
reorder-only, reorder + partial execution} across the paper graphs, plus
the headline capacity demos.

Since the byte-granular dtype refactor the float builds carry honest
4-byte elements, so the MCU capacity demos run on the **int8** models
(``quantize_graph`` / ``int8_scheduling_graph`` — byte-identical sizes):
MobileNet-1.0@192 fits a 512 KB arena with int8+reorder+pex (f32
reorder-only needs 3456 KB, int8 reorder-only 864 KB) and
MobileNet-0.5@192 fits the 256 KB stretch target.  Numeric bit-identity of
the partitioned int8 model is asserted through the micro-interpreter on
the person-detection build (0.25@96) — partial execution must not change
quantized numerics either.

Output rows (bytes; ``dtypes`` metadata tags the element width):
    pex.<graph>.static_B            all-tensors-resident planning
    pex.<graph>.reorder_B           best reordered schedule, whole operators
    pex.<graph>.reorder_partial_B   reordering over the partitioned graph
    pex.<graph>.arena_plan_B        offline arena plan of the winning schedule

Smoke mode (REPRO_BENCH_SMOKE=1, set by ``run.py --smoke``) keeps only
the 2-D tiled-cascade golden section — the rows the CI baseline pins
(exact bytes, the tile_rows/tile_cols meta, and the memory/latency
Pareto front gated by compare.py's ``front_covers``).  The full run
emits a superset; its extra rows surface as compare.py notes until the
baseline is deliberately refreshed.
"""
import os
import time

import numpy as np

from repro.core import ArenaPlanner, schedule, static_plan_size
from repro.core.partition import cascade_graph
from repro.graphs import (figure1_graph, graph_dtypes,
                          int8_scheduling_graph, mobilenet_v1_graph,
                          quantize_graph, random_input, swiftnet_cell_graph)
from repro.mcu import MicroInterpreter

KB = 1024
_SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _case(report, name, g, cap=None, dtypes=None):
    if dtypes is None:
        dtypes = graph_dtypes(g)
    t0 = time.perf_counter()
    base = schedule(g)
    res = schedule(g, arena_budget=cap, partition=cap is None)
    dt = (time.perf_counter() - t0) * 1e6
    gp = res.graph if res.graph is not None else g
    plan = ArenaPlanner.plan(gp, res.schedule)
    ArenaPlanner.validate(plan, gp)
    report(f"pex.{name}.static_B", dt, static_plan_size(g),
           arena_bytes=static_plan_size(g), dtypes=dtypes)
    report(f"pex.{name}.reorder_B", dt, base.peak,
           arena_bytes=base.peak, dtypes=dtypes)
    report(f"pex.{name}.reorder_partial_B", dt, res.peak,
           arena_bytes=res.peak, dtypes=dtypes)
    report(f"pex.{name}.arena_plan_B", dt, plan.arena_size,
           arena_bytes=int(plan.arena_size), dtypes=dtypes)
    return base, res, plan


def _assert_bit_identical(g, res, x):
    ref = MicroInterpreter(g).run(x)
    got = MicroInterpreter(res.graph).run(x, schedule=res.schedule)
    for o in g.outputs:
        np.testing.assert_array_equal(ref.outputs[o], got.outputs[o])
    assert got.peak_sram == res.peak, (got.peak_sram, res.peak)


def run(report):
    if _SMOKE:
        # the baseline-pinned golden section only: one 1-D cascade
        # schedule for the row-ring Pareto point, then the 2-D case
        q = int8_scheduling_graph(
            mobilenet_v1_graph(alpha=1.0, resolution=192))
        r1 = schedule(q, arena_budget=256 * KB)
        assert "cascade" in r1.method
        row_ring_arena = int(r1.peak)
        row_ring_macs = int(r1.extra_macs or 0)
        return _run_cascade2d(report, q, row_ring_arena, row_ring_macs)

    # ---- the paper graphs (f32): partial execution composes with reorder
    _case(report, "figure1", figure1_graph())          # too small to slice
    base, res, _ = _case(report, "mobilenet_025_96", mobilenet_v1_graph())
    assert res.peak < base.peak, "pure chain: partial execution must win"
    _case(report, "swiftnet_96", swiftnet_cell_graph())

    # ---- int8 x reorder x pex composes bit-identically (person detection)
    g = mobilenet_v1_graph()
    qm = quantize_graph(g, random_input(g))
    base, res, _ = _case(report, "mobilenet_025_96_int8", qm.graph)
    assert res.peak < base.peak
    assert res.graph is not None
    _assert_bit_identical(qm.graph, res,
                          qm.quantize_inputs(random_input(g)))

    # ---- headline: int8 fits 512 KB only with reorder+partial ----------
    cap = 512 * KB
    q = int8_scheduling_graph(mobilenet_v1_graph(alpha=1.0, resolution=192))
    base, res, plan = _case(report, "mobilenet_100_192_int8", q, cap=cap)
    assert base.peak > cap, "int8 reorder-only must NOT fit 512 KB"
    assert res.peak <= cap and plan.arena_size <= cap, "pex must fit 512 KB"
    report("pex.mobilenet_100_192_int8.fits_512K", 0.0,
           int(plan.arena_size <= cap), dtypes="int8")

    # ---- cascaded streaming: the same model fits 256 KB ----------------
    # (whole-externals pex has a ~280 KB floor here: the 108 KB input plus
    # a whole segment accumulator; ring-buffer cascading breaks it)
    cap = 256 * KB
    whole_pex_arena = plan.arena_size
    base, res, plan = _case(report, "mobilenet_100_192_int8_cascade", q,
                            cap=cap)
    assert "cascade" in res.method, "256 KB must need cascaded streaming"
    assert res.peak <= cap and plan.arena_size <= cap, \
        "cascade must fit 256 KB"
    assert plan.arena_size < whole_pex_arena, \
        "cascade must beat the whole-externals arena"
    report("pex.mobilenet_100_192_int8.fits_256K", 0.0,
           int(plan.arena_size <= cap), dtypes="int8")

    _run_cascade2d(report, q, int(plan.arena_size),
                   int(res.extra_macs or 0))

    # ---- stretch: 256 KB -----------------------------------------------
    cap = 256 * KB
    q = int8_scheduling_graph(mobilenet_v1_graph(alpha=0.5, resolution=192))
    base, res, plan = _case(report, "mobilenet_050_192_int8", q, cap=cap)
    assert base.peak > cap, "int8 reorder-only must NOT fit 256 KB"
    assert res.peak <= cap and plan.arena_size <= cap, "pex must fit 256 KB"
    report("pex.mobilenet_050_192_int8.fits_256K", 0.0,
           int(plan.arena_size <= cap), dtypes="int8")


def _run_cascade2d(report, q, row_ring_arena, row_ring_macs):
    # ---- 2-D tiled cascade: W-strips break the 243 KB row-ring floor ---
    # The same model under a 224 KB budget needs the +cascade2d rung: the
    # early stage streams in tile_rows x tile_cols patches (row chunks x
    # W-strips), trading column-halo recompute for the sub-row-ring arena.
    # The row carries the memory/latency front (extra MACs vs bytes) so
    # compare.py's front_covers gate pins all three points: reorder-only,
    # 1-D row rings, 2-D tiles.
    cap = 224 * KB
    base, res, plan = _case(report, "mobilenet_100_192_int8_cascade2d", q,
                            cap=cap)
    assert "cascade2d" in res.method, "224 KB must need 2-D tiles"
    assert res.peak <= cap and plan.arena_size <= cap, \
        "2-D cascade must fit 224 KB"
    assert plan.arena_size < row_ring_arena, \
        "2-D tiles must beat the row-ring arena"
    cr = cascade_graph(q, budget=cap, strips_choices=(2, 3, 4))
    c = cr.cascades[0]
    out_t = q.tensors[c.segments[-1][-1].output]
    tile_rows = -(-int(out_t.shape[0]) // c.k)
    tile_cols = -(-int(out_t.shape[1]) // c.strips)
    front = sorted([[0, int(base.peak)],
                    [row_ring_macs, row_ring_arena],
                    [int(res.extra_macs or 0), int(plan.arena_size)]])
    report("pex.mobilenet_100_192_int8.fits_224K", 0.0,
           int(plan.arena_size <= cap), dtypes="int8",
           tile_rows=tile_rows, tile_cols=tile_cols, pareto=front)
